"""Distributed FSP == host FSP (paper future-work parallelization), plus
data-plane factorized store and pipeline properties."""
from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import gfsp
from repro.core.distributed import gfsp_distributed, sweep_drop_one, pad_rows
from repro.core.star import ami, num_edges
from repro.data.factorized_store import FactorizedStore
from repro.data.synthetic import SensorGraphSpec, generate


def test_distributed_matches_host_sensor_graph():
    store = generate(SensorGraphSpec(n_observations=800, seed=3))
    for cname in ("ssn:Observation", "ssn:Measurement"):
        cid = store.dict.lookup(cname)
        host = gfsp(store, cid)
        dist = gfsp_distributed(store, cid)
        assert set(host.props) == set(dist.props)
        assert host.edges == dist.edges
        assert host.ami == dist.ami


@settings(max_examples=10, deadline=None)
@given(n=st.integers(6, 60), k=st.integers(3, 5), card=st.integers(2, 6),
       seed=st.integers(0, 99))
def test_sweep_matches_host_formula(n, k, card, seed):
    """Device drop-one sweep == host AMI/#Edges for random matrices."""
    rng = np.random.default_rng(seed)
    mat = rng.integers(0, card, (n, k)).astype(np.int32)
    padded, n_real = pad_rows(mat, 4)
    import jax.numpy as jnp
    valid = jnp.arange(padded.shape[0]) < n_real
    edges, amis = sweep_drop_one(jnp.asarray(padded), valid,
                                 jnp.int32(n), k)
    for j in range(k):
        sub = np.delete(mat, j, axis=1)
        a = ami(sub)
        assert int(amis[j]) == a, (j, mat)
        assert int(edges[j]) == num_edges(a, n, k - 1, k)


def test_factorized_store_roundtrip_and_savings():
    rng = np.random.default_rng(0)
    base = rng.integers(0, 100, (8, 32), dtype=np.int32)
    rows = base[rng.integers(0, 8, (500,))]
    st_ = FactorizedStore.build(rows)
    assert st_.savings_pct > 80
    idx = rng.integers(0, 500, (64,))
    np.testing.assert_array_equal(st_.batch(idx), rows[idx])


def test_factorized_store_overhead_fallback():
    """Unique rows: factorization would only add pointers (Fig. 7)."""
    rng = np.random.default_rng(1)
    rows = rng.integers(0, 1 << 30, (100, 16), dtype=np.int32)
    st_ = FactorizedStore.build(rows)
    assert st_.flat is not None
    assert st_.savings_pct == 0.0
    np.testing.assert_array_equal(st_.batch(np.arange(100)), rows)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 200), m=st.integers(1, 20), seed=st.integers(0, 9))
def test_factorized_store_property(n, m, seed):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 50, (m, 8), dtype=np.int32)
    rows = base[rng.integers(0, m, (n,))]
    st_ = FactorizedStore.build(rows)
    np.testing.assert_array_equal(st_.batch(np.arange(n)), rows)
    assert st_.bytes_stored <= st_.bytes_original


def test_factorized_store_batch_sends_unique_molecules_once():
    """The device-transfer payload of a batch is one copy of each
    distinct molecule the batch references -- not one row per sample."""
    rng = np.random.default_rng(7)
    base = rng.integers(0, 100, (6, 16), dtype=np.int32)
    rows = base[rng.integers(0, 6, (300,))]
    st_ = FactorizedStore.build(rows)
    idx = rng.integers(0, 300, (64,))
    mols, inv = st_.batch_parts(idx)
    # payload rows are pairwise distinct and exactly the referenced set
    assert np.unique(mols, axis=0).shape[0] == mols.shape[0]
    assert mols.shape[0] == np.unique(st_.instance_of[idx]).shape[0]
    assert mols.shape[0] <= 6 < idx.shape[0]
    np.testing.assert_array_equal(mols[inv], rows[idx])
    np.testing.assert_array_equal(st_.batch(idx), rows[idx])
    # device path: same values, expansion happens after the transfer
    jnp_batch = st_.batch(idx, device=True)
    np.testing.assert_array_equal(np.asarray(jnp_batch), rows[idx])


def test_factorized_store_batch_parts_flat_fallback():
    rng = np.random.default_rng(8)
    rows = rng.integers(0, 1 << 30, (40, 8), dtype=np.int32)  # all unique
    st_ = FactorizedStore.build(rows)
    assert st_.flat is not None
    idx = rng.integers(0, 40, (16,))
    mols, inv = st_.batch_parts(idx)
    np.testing.assert_array_equal(mols[inv], rows[idx])
    np.testing.assert_array_equal(st_.batch(idx, device=True), rows[idx])

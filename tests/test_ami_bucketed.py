"""Hash-bucket distributed AMI == host AMI, including real multi-shard
routing (subprocess with 8 host devices)."""
import json
import subprocess
import sys

import numpy as np
import jax.numpy as jnp

from repro.core.distributed import ami_bucketed, pad_rows
from repro.core.star import ami
from repro.launch.mesh import make_test_mesh

_MULTI = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "src")
from repro.core.distributed import ami_bucketed, pad_rows, shard_rows
from repro.core.star import ami
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((4, 2), ("data", "model"))
rng = np.random.default_rng(7)
out = []
for n, k, card in [(1000, 4, 13), (97, 3, 2), (4096, 2, 300)]:
    mat = rng.integers(0, card, (n, k)).astype(np.int32)
    padded, n_real = pad_rows(mat, 4)
    dev = shard_rows(padded, mesh)
    valid = jnp.arange(padded.shape[0]) < n_real
    with mesh:
        a = int(ami_bucketed(dev, valid, mesh, dp_axes=("data",)))
    out.append([a, ami(mat)])
print(json.dumps(out))
'''


def test_single_device_exact():
    mesh = make_test_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(1)
    for n, k, card in [(64, 3, 4), (513, 4, 11)]:
        mat = rng.integers(0, card, (n, k)).astype(np.int32)
        padded, n_real = pad_rows(mat, 4)
        valid = jnp.arange(padded.shape[0]) < n_real
        with mesh:
            a = int(ami_bucketed(jnp.asarray(padded), valid, mesh))
        assert a == ami(mat)


def test_multi_shard_exact():
    r = subprocess.run([sys.executable, "-c", _MULTI], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-1500:]
    pairs = json.loads(r.stdout.strip().splitlines()[-1])
    for a, b in pairs:
        assert a == b, pairs

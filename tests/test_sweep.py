"""Shape-bucketed sweep workspaces: backend parity from one parent
buffer, bounded jit tracing, and mask-aware signature ops."""
import numpy as np
import pytest

from repro.api import Compactor, get_backend
from repro.core import sweep as core_sweep
from repro.core.star import ami, num_edges
from repro.core.sweep import (BUCKET_MIN_COLS, BUCKET_MIN_ROWS,
                              DeviceSweepWorkspace, HostSweepWorkspace,
                              SweepWorkspace, bucket_cols, bucket_rows)
from repro.core.triples import TripleStore
from repro.data.synthetic import SensorGraphSpec, generate

jax = pytest.importorskip("jax")


def _sensor(n=300, seed=3, **kw):
    return generate(SensorGraphSpec(n_observations=n, seed=seed, **kw))


# ---------------------------------------------------------------------------
# bucket ladder
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    assert bucket_rows(0) == BUCKET_MIN_ROWS
    assert bucket_rows(64) == 64
    assert bucket_rows(65) == 128
    assert bucket_rows(800) == 1024
    assert bucket_rows(100, multiple=3) == 129       # pow2 then dp-rounded
    assert bucket_cols(1) == BUCKET_MIN_COLS
    assert bucket_cols(5) == 8
    assert bucket_cols(8) == 8


# ---------------------------------------------------------------------------
# workspace semantics: slices of ONE parent matrix on every backend
# ---------------------------------------------------------------------------

def _workspace_for(backend_name, store, cid):
    be = get_backend(backend_name)
    stats = store.class_stats(cid)
    props = tuple(int(p) for p in stats.properties)
    n_s, am = len(props), stats.n_instances
    return be.workspace(store, cid, props, n_s, am), n_s, am


@pytest.mark.parametrize("backend", ["host", "device", "sharded"])
def test_workspace_sweep_matches_parent_matrix_formula(backend):
    store = _sensor(200, seed=9)
    cid = int(store.dict.lookup("ssn:Observation"))
    ws, n_s, am = _workspace_for(backend, store, cid)
    assert isinstance(ws, SweepWorkspace)
    cur = ws.evaluate_current()
    assert cur.props == ws.props
    mat = ws.matrix
    edges, amis = ws.sweep()
    assert edges.shape == amis.shape == (len(cur.props),)
    for j in range(len(cur.props)):
        sub = np.delete(mat, j, axis=1)
        a = ami(sub)
        assert int(amis[j]) == a, (backend, j)
        assert int(edges[j]) == num_edges(a, am, n_s - 1, n_s)


@pytest.mark.parametrize("backend", ["device", "sharded"])
def test_workspace_descend_drops_on_device_no_reextraction(backend):
    store = _sensor(150, seed=4)
    cid = int(store.dict.lookup("ssn:Observation"))
    ws, n_s, am = _workspace_for(backend, store, cid)
    assert ws._dev is None        # upload is lazy: first sweep pays it
    edges, amis = ws.sweep()
    buf_before = ws._dev          # uploaded parent buffer
    assert buf_before is not None
    j = int(np.argmin(edges))
    dropped = ws.props[j]
    ws.descend(j)
    assert dropped not in ws.props and len(ws.props) == n_s - 1
    assert ws._dev is buf_before  # same device buffer: no re-upload
    # post-descent sweep still agrees with host arithmetic on the view
    edges2, amis2 = ws.sweep()
    active = [i for i, p in enumerate(ws._all_props) if p in ws.props]
    for jj in range(len(ws.props)):
        cols = active[:jj] + active[jj + 1:]
        assert int(amis2[jj]) == ami(ws.matrix[:, cols])


def test_all_backends_share_one_entity_universe():
    """Incomplete molecules: every backend sweeps the same parent matrix
    (entities complete over the FULL property set S) -- the seed's host
    loop re-decided the universe per subset, devices did not."""
    t = []
    for i in range(6):
        e = f"e{i}"
        t += [(e, "rdf:type", "C"), (e, "a", "x"), (e, "b", f"y{i % 2}")]
    t += [("partial", "rdf:type", "C"), ("partial", "a", "x")]  # misses b
    store = TripleStore.from_triples(t)
    C = int(store.dict.lookup("C"))
    results = {}
    for be in ("host", "device", "sharded"):
        r = Compactor(detector="gfsp", backend=be).detect(store, C)
        results[be] = (tuple(sorted(r.props)), r.edges, r.ami,
                       r.evaluations)
    assert len(set(results.values())) == 1, results


# ---------------------------------------------------------------------------
# bounded tracing: one compile per bucket shape, cache-hit afterwards
# ---------------------------------------------------------------------------

def test_trace_count_bounded_by_distinct_bucket_shapes():
    """A multi-class gfsp run (two classes, several descent levels each,
    then a REPEAT run and a second same-bucket graph) must trace the
    sweep once per distinct bucket shape -- not once per (class, descent
    level, instance) triple."""
    core_sweep.clear_compile_cache()     # deterministic cold start
    store = _sensor(300, seed=21)
    comp = Compactor(detector="gfsp", backend="device")
    rep = comp.run(store)
    assert len(rep.plan) == 2            # Observation + Measurement
    first = core_sweep.trace_count()
    assert first == core_sweep.distinct_bucket_shapes()
    assert 0 < first <= 2                # <= one bucket per class
    # warm: same graph, fresh Compactor -- zero new traces
    Compactor(detector="gfsp", backend="device").run(store)
    assert core_sweep.trace_count() == first
    # a different graph landing in the same buckets is also free
    Compactor(detector="gfsp", backend="device").run(_sensor(280, seed=5))
    assert core_sweep.trace_count() == first
    # mesh-less sharded shares the single-device bucket cache
    Compactor(detector="gfsp", backend="sharded").run(store)
    assert core_sweep.trace_count() == first
    # a graph in a NEW row bucket traces exactly the new shapes
    Compactor(detector="gfsp", backend="device").run(_sensor(700, seed=8))
    after = core_sweep.trace_count()
    assert after == core_sweep.distinct_bucket_shapes() > first


# ---------------------------------------------------------------------------
# mask-aware signature op
# ---------------------------------------------------------------------------

def test_row_signature_valid_mask_sentinel():
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    rng = np.random.default_rng(0)
    mat = jnp.asarray(rng.integers(0, 50, (16, 4)).astype(np.int32))
    valid = jnp.asarray(np.arange(16) < 11)
    sig = np.asarray(kops.row_signature(mat, valid=valid, use_kernel=False))
    ref = np.asarray(kops.row_signature(mat, use_kernel=False))
    np.testing.assert_array_equal(sig[:11], ref[:11])
    assert (sig[11:] == kops.SIG_SENTINEL).all()


def test_ami_device_masked_equals_host_on_valid_rows():
    import jax.numpy as jnp
    from repro.core.star import ami_device
    rng = np.random.default_rng(7)
    mat = rng.integers(0, 4, (40, 3)).astype(np.int32)
    padded = np.concatenate([mat, np.zeros((24, 3), np.int32)])
    valid = np.arange(64) < 40
    got = int(ami_device(jnp.asarray(padded), valid=jnp.asarray(valid),
                         use_kernel=False))
    assert got == ami(mat)

"""Algorithm 1 (E.FSP) and Algorithm 2 (G.FSP): agreement, optimality,
Theorem 4.1 behaviour, and the Figure-5 walkthrough."""
import itertools

import numpy as np
import pytest

from repro.core import TripleStore, efsp, evaluate_subset, gfsp
from repro.data.synthetic import (SensorGraphSpec, figure1_graph,
                                  figure7b_graph, generate,
                                  property_set_ids)


def _fig1():
    store = figure1_graph()
    C = store.dict.lookup("C")
    p = {k: store.dict.lookup(k) for k in ["p1", "p2", "p3", "p4"]}
    return store, C, p


def test_gfsp_figure5():
    """G.FSP on Figure 1a finds SP = {p1,p2,p3} with one FSP of 4 entities."""
    store, C, p = _fig1()
    res = gfsp(store, C)
    assert set(res.props) == {p["p1"], p["p2"], p["p3"]}
    assert res.ami == 1
    assert res.edges == 8
    assert res.n_fsp == 1
    members, objs = res.fsp[0]
    assert members.shape[0] == 4


def test_efsp_figure5():
    store, C, p = _fig1()
    res = efsp(store, C)
    assert set(res.props) == {p["p1"], p["p2"], p["p3"]}
    assert res.ami == 1
    assert res.edges == 8
    # BFS levels: cardinalities 4, 3, 2
    assert res.iterations == 3


def test_efsp_equals_bruteforce():
    """E.FSP's gSpan-counted AMI matches direct evaluation on all subsets."""
    store, C, p = _fig1()
    props = sorted(p.values())
    best = None
    for k in range(2, 5):
        for combo in itertools.combinations(props, k):
            r = evaluate_subset(store, C, combo, n_total_props=4)
            if best is None or r.edges < best.edges:
                best = r
    res = efsp(store, C)
    assert res.edges == best.edges
    assert set(res.props) == set(best.props)


def test_gfsp_matches_efsp_on_sensor_graph():
    """Paper Table 3: both algorithms detect the same FSP; the greedy one
    evaluates far fewer subsets."""
    store = generate(SensorGraphSpec(n_observations=300, seed=1,
                                     include_result_links=False))
    for cname in ["ssn:Observation", "ssn:Measurement"]:
        C = store.dict.lookup(cname)
        e = efsp(store, C)
        g = gfsp(store, C)
        assert e.edges == g.edges
        assert set(e.props) == set(g.props)
        assert g.evaluations <= e.evaluations


def test_gfsp_finds_a5_and_a8():
    """Paper §5.1: the detected FSPs are over A5 (Observation) and A8
    (Measurement)."""
    store = generate(SensorGraphSpec(n_observations=1500, n_sensors=10,
                                     seed=3))
    C_obs, a5 = property_set_ids(store, "A5")
    res = gfsp(store, C_obs)
    assert set(res.props) == set(a5)
    C_meas, a8 = property_set_ids(store, "A8")
    res = gfsp(store, C_meas)
    assert set(res.props) == set(a8)


def test_gfsp_objective_monotone():
    """The greedy descent only ever improves the objective."""
    store = generate(SensorGraphSpec(n_observations=400, seed=7))
    C = store.dict.lookup("ssn:Observation")
    res = gfsp(store, C)
    # final objective must beat (or equal) the full set S
    stats = store.class_stats(C)
    full = evaluate_subset(store, C, stats.properties,
                           n_total_props=stats.properties.shape[0])
    assert res.edges <= full.edges


def test_gfsp_overhead_graph_keeps_full_set():
    """Figure 7b flavor: no subset improves -> greedy stops at S."""
    store = figure7b_graph()
    C = store.dict.lookup("C")
    res = gfsp(store, C)
    assert len(res.props) == 2            # S itself ({p1, p2})
    assert res.ami == 9                   # every entity its own pattern


def test_gfsp_device_sweep_equivalent():
    """The batched TPU sweep gives the same result as the host loop."""
    pytest.importorskip("jax")
    store = generate(SensorGraphSpec(n_observations=300, seed=11,
                                     include_result_links=False))
    C = store.dict.lookup("ssn:Observation")
    host = gfsp(store, C, device_sweep=False)
    dev = gfsp(store, C, device_sweep=True)
    assert host.edges == dev.edges
    assert set(host.props) == set(dev.props)


def test_empty_class():
    store = TripleStore.from_triples([("a", "p", "b")])
    res = gfsp(store, store.dict.id("nonexistent"))
    assert res.props == ()
    assert res.n_fsp == 0

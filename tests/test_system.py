"""End-to-end behaviour: detect -> factorize -> query, on a realistic graph."""
import numpy as np

from repro.core import (factorize_classes, gfsp, match_star,
                        semantic_triples)
from repro.data.synthetic import SensorGraphSpec, generate


def test_end_to_end_detect_factorize_query():
    store = generate(SensorGraphSpec(n_observations=1500, seed=42))
    plans = []
    for cname in ["ssn:Observation", "ssn:Measurement"]:
        C = store.dict.lookup(cname)
        res = gfsp(store, C)
        assert res.n_fsp >= 1
        plans.append((C, res.props))
    gprime, results = factorize_classes(store, plans)

    # 1. the factorized graph is smaller
    assert gprime.n_triples < store.n_triples
    total_before = sum(r.nle_before for r in results)
    total_after = sum(r.nle_after for r in results)
    assert total_after < total_before

    # 2. information is preserved (Def. 4.10 + Def. 4.11 closure)
    a = semantic_triples(store)
    b = semantic_triples(gprime)
    assert a.shape == b.shape and (a == b).all()

    # 3. queries over G' (rewritten) match queries over G
    v = store.dict.lookup("val/0")
    p_val = store.dict.lookup("ssn:value")
    orig = match_star(store, [(p_val, v)], rewrite=False)
    new = match_star(gprime, [(p_val, v)], rewrite=True)
    assert (np.sort(orig) == np.sort(new)).all()
    assert orig.size > 0

"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
this repo's property tests use.

Loaded by ``tests/conftest.py`` ONLY when the real package is absent
(the CI/dev ``test`` extra installs real hypothesis; air-gapped runners
fall back here).  Semantics: deterministic example generation -- the
first examples are the boundary values of every strategy, the rest are
drawn from an RNG seeded by the test's qualified name, so runs are
reproducible and min/max edge cases are always exercised.  No shrinking;
the falsifying example is printed instead.
"""
from __future__ import annotations

import inspect
import random
import sys
import zlib

__version__ = "0.0-repro-vendored"
__all__ = ["given", "settings", "strategies", "assume", "example",
           "HealthCheck"]


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Attribute sink -- suppress lists are accepted and ignored."""
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


class settings:
    """Decorator form only (``@settings(max_examples=..., deadline=...)``)."""
    def __init__(self, max_examples: int = 100, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._hypothesis_settings = self
        return fn


_DEFAULT_SETTINGS = settings(max_examples=50)


class SearchStrategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self._boundary = tuple(boundary)

    def draw(self, rng: random.Random, index: int):
        if index < len(self._boundary):
            return self._boundary[index]
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              tuple(f(b) for b in self._boundary))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption("filter predicate too strict")
        return SearchStrategy(draw, tuple(b for b in self._boundary
                                          if pred(b)))


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.*``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                              (min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float,
               **_ignored) -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                              (min_value, max_value))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.random() < 0.5, (False, True))

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        if not elements:
            raise ValueError("sampled_from requires a non-empty sequence")
        return SearchStrategy(lambda rng: rng.choice(elements),
                              tuple(elements))

    @staticmethod
    def just(value) -> SearchStrategy:
        return SearchStrategy(lambda rng: value, (value,))

    @staticmethod
    def one_of(*strats) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: rng.choice(strats)._draw(rng),
            tuple(b for s in strats for b in s._boundary[:1]))

    @staticmethod
    def lists(elem: SearchStrategy, *, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem._draw(rng) for _ in range(n)]
        # boundary = the minimal VALID list; element strategies without
        # boundary values contribute no boundary rather than an example
        # that violates min_size
        if elem._boundary:
            boundary = ([elem._boundary[0]] * min_size,)
        elif min_size == 0:
            boundary = ([],)
        else:
            boundary = ()
        return SearchStrategy(draw, boundary)

    @staticmethod
    def tuples(*strats) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(s._draw(rng) for s in strats))


def example(**kwargs):
    """Pin an explicit example; runs before generated ones."""
    def deco(fn):
        pinned = list(getattr(fn, "_hypothesis_examples", []))
        pinned.append(kwargs)
        fn._hypothesis_examples = pinned
        return fn
    return deco


def given(*args, **strategies_kw):
    if args:
        raise TypeError("vendored hypothesis shim supports keyword "
                        "strategies only: @given(x=st.integers(...))")

    def deco(fn):
        # outer params (fixtures / parametrize) = fn's signature minus
        # the given-supplied names; expose them so pytest injects them
        sig = inspect.signature(fn)
        outer = [p for n, p in sig.parameters.items()
                 if n not in strategies_kw]

        def wrapper(*args, **outer_kw):
            bound = dict(zip((p.name for p in outer), args))
            bound.update(outer_kw)
            s = (getattr(wrapper, "_hypothesis_settings", None)
                 or getattr(fn, "_hypothesis_settings", None)
                 or _DEFAULT_SETTINGS)
            rng = random.Random(zlib.crc32(
                (fn.__module__ + "." + fn.__qualname__).encode()))
            pinned = getattr(fn, "_hypothesis_examples", [])
            for kw in pinned:
                _run_one(fn, {**bound, **kw})
            for i in range(s.max_examples):
                kw = {name: strat.draw(rng, i)
                      for name, strat in strategies_kw.items()}
                _run_one(fn, {**bound, **kw})

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = sig.replace(parameters=outer)
        # pytest plugins (anyio, hypothesis's own) introspect
        # ``fn.hypothesis.inner_test`` -- mirror that shape
        wrapper.hypothesis = type("HypothesisHandle", (),
                                  {"inner_test": staticmethod(fn)})()
        if hasattr(fn, "_hypothesis_settings"):
            wrapper._hypothesis_settings = fn._hypothesis_settings
        return wrapper

    return deco


def _run_one(fn, kwargs):
    try:
        fn(**kwargs)
    except UnsatisfiedAssumption:
        return
    except Exception:
        print(f"Falsifying example: {fn.__name__}(" +
              ", ".join(f"{k}={v!r}" for k, v in kwargs.items()) + ")",
              file=sys.stderr)
        raise

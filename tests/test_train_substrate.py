"""Training substrate: optimizers, fused CE, grad accumulation,
compression -- values and invariants."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.dist.compression import (compressed, dequantize_int8,
                                    make_pod_compress_fn, quantize_int8)
from repro.models.blocks import Ctx
from repro.models.common import (causal_cross_entropy,
                                 causal_cross_entropy_ref)
from repro.models.lm import LM
from repro.train import adafactor, adamw, cosine_schedule, make_train_step
from repro.train.optimizer import Optimizer, global_norm
from repro.train.train_step import init_train_state


# -- fused CE ---------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), t=st.integers(1, 9), v=st.integers(2, 33),
       masked=st.booleans())
def test_fused_ce_matches_ref(b, t, v, masked):
    key = jax.random.PRNGKey(b * 100 + t * 10 + v)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = jax.random.normal(k1, (b, t, v), jnp.float32) * 4
    labels = jax.random.randint(k2, (b, t), 0, v)
    mask = ((jax.random.uniform(k3, (b, t)) > 0.4).astype(jnp.float32)
            if masked else None)
    a = causal_cross_entropy_ref(logits, labels, mask)
    c = causal_cross_entropy(logits, labels, mask)
    np.testing.assert_allclose(c, a, rtol=1e-5)
    ga = jax.grad(lambda l: causal_cross_entropy_ref(l, labels, mask))(logits)
    gc = jax.grad(lambda l: causal_cross_entropy(l, labels, mask))(logits)
    np.testing.assert_allclose(gc, ga, atol=1e-5)


# -- optimizers ---------------------------------------------------------------

def _quadratic_target():
    w_star = jnp.asarray([1.5, -2.0, 0.5])

    def loss(p):
        return jnp.sum((p["w"] - w_star) ** 2)
    return loss, {"w": jnp.zeros(3)}


@pytest.mark.parametrize("mk", [
    lambda: adamw(0.1, weight_decay=0.0),
    # adafactor's rms-clipped update needs a decaying lr to settle
    lambda: adafactor(cosine_schedule(0.3, warmup=5, total=300,
                                      floor=0.01)),
    lambda: compressed(adamw(0.1, weight_decay=0.0)),
])
def test_optimizer_converges_quadratic(mk):
    loss, params = _quadratic_target()
    opt = mk()
    state = opt.init(params)
    for step in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(step))
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_skips_vectors():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "ln": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _ = opt.update(zeros, state, params, jnp.int32(0))
    assert float(jnp.abs(p2["w"] - 1).max()) > 0      # decayed
    np.testing.assert_allclose(p2["ln"], params["ln"])  # not decayed


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.1)   # (step+1)/warmup
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


# -- grad accumulation ---------------------------------------------------------

def test_grad_accum_equivalent():
    cfg = reduced(get_arch("llama3.2-1b"), n_layers=1)
    model = LM(cfg)
    ctx = Ctx(cfg=cfg)

    captured = {}

    def capture_opt() -> Optimizer:
        def init(params):
            return {}

        def update(grads, state, params, step):
            captured[int(jnp.asarray(len(captured)))] = grads
            return params, state
        return Optimizer(init, update)

    tokens = jax.random.randint(jax.random.PRNGKey(0), (4, 16), 1,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    for i, accum in enumerate((1, 2)):
        step = make_train_step(model, capture_opt(), ctx=ctx,
                               grad_accum=accum)
        state = init_train_state(model, capture_opt(),
                                 jax.random.PRNGKey(1))
        step(state, batch)
    g1, g2 = captured[0], captured[1]
    flat1, flat2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=1e-4, rtol=1e-3)


def test_loss_decreases_on_learnable_data():
    """Half the synthetic batch is noise (ln V floor) -- compare windowed
    means, not endpoints."""
    from repro.launch.train import main
    out = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "30",
                "--batch", "8", "--seq", "32", "--lr", "1e-2",
                "--log-every", "100"])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# -- compression -----------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(2, 64))
def test_int8_quant_error_bound(scale, n):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal((4, n)) * scale, jnp.float32)
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s)
    absmax = np.abs(np.asarray(g)).max(axis=-1, keepdims=True)
    assert float(jnp.abs(deq - g).max()) <= float(absmax.max()) / 127.0 + 1e-6


def test_error_feedback_residual_carried():
    opt = compressed(adamw(0.0, weight_decay=0.0))   # lr 0: params frozen
    params = {"w": jnp.zeros((2, 4))}
    state = opt.init(params)
    g = {"w": jnp.full((2, 4), 1e-4)}
    g["w"] = g["w"].at[0, 0].set(1.0)    # tiny grads quantize to 0...
    _, state = opt.update(g, state, params, jnp.int32(0))
    # ...but the residual keeps them for later steps
    assert float(jnp.abs(state["ef"]["w"]).sum()) > 0


# -- pod-boundary compression routing ----------------------------------------

def test_pod_compress_fn_engages_only_across_pods():
    """No pod boundary -> None (intra-pod grads MUST stay uncompressed);
    a real boundary -> exactly the int8 codec round the DCN hop carries."""
    import types
    assert make_pod_compress_fn() is None
    assert make_pod_compress_fn(n_pods=1) is None
    no_pod = types.SimpleNamespace(axis_names=("data", "model"),
                                   devices=np.zeros((4, 2)))
    assert make_pod_compress_fn(no_pod) is None
    one_pod = types.SimpleNamespace(axis_names=("pod", "data"),
                                    devices=np.zeros((1, 8)))
    assert make_pod_compress_fn(one_pod) is None
    two_pods = types.SimpleNamespace(axis_names=("pod", "data"),
                                     devices=np.zeros((2, 4)))
    fn = make_pod_compress_fn(two_pods)
    assert fn is not None
    assert make_pod_compress_fn(n_pods=2) is not None
    g = {"w": jnp.asarray([[0.5, -3.0, 1e-5], [7.0, 0.0, -0.25]],
                          jnp.float32)}
    out = fn(g)
    np.testing.assert_array_equal(
        np.asarray(out["w"]),
        np.asarray(dequantize_int8(*quantize_int8(g["w"]))))


def test_train_step_hook_intra_pod_grads_uncompressed():
    """Routing --compress-grads through the compress_fn hook: with no pod
    boundary the step function is bit-identical to the uncompressed
    baseline; with a boundary the optimizer sees exactly the int8
    codec's output of the raw gradients."""
    cfg = reduced(get_arch("qwen2-0.5b"), grad_accum=1)
    model = LM(cfg)
    ctx = Ctx(cfg=cfg)
    captured = {}

    def capture_opt(tag):
        def init(params):
            return {}

        def update(grads, state, params, step):
            captured[tag] = grads
            return params, state
        return Optimizer(init, update)

    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 1,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    for tag, fn in (("plain", None),
                    ("intra", make_pod_compress_fn(n_pods=1)),
                    ("cross", make_pod_compress_fn(n_pods=2))):
        step = make_train_step(model, capture_opt(tag), ctx=ctx,
                               compress_fn=fn)
        state = init_train_state(model, capture_opt(tag),
                                 jax.random.PRNGKey(1))
        step(state, batch)
    plain = jax.tree.leaves(captured["plain"])
    intra = jax.tree.leaves(captured["intra"])
    cross = jax.tree.leaves(captured["cross"])
    for a, b in zip(plain, intra):       # no boundary: bit-identical
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    saw_change = False
    for a, c in zip(plain, cross):       # boundary: the codec, exactly
        want = np.asarray(dequantize_int8(*quantize_int8(a)).astype(a.dtype))
        np.testing.assert_array_equal(np.asarray(c), want)
        saw_change |= not np.array_equal(np.asarray(a), want)
    assert saw_change                    # compression actually happened


def test_train_main_compress_grads_routes_by_pods():
    from repro.launch.train import main
    base = ["--arch", "qwen2-0.5b", "--reduced", "--steps", "3",
            "--batch", "2", "--seq", "16", "--log-every", "100"]
    off = main(base)
    intra = main(base + ["--compress-grads"])          # --pods 1 default
    cross = main(base + ["--compress-grads", "--pods", "2"])
    assert off["grad_compression"] == "off"
    assert intra["grad_compression"] == "off"          # nothing to compress
    np.testing.assert_allclose(off["losses"], intra["losses"])
    assert cross["grad_compression"] == "pod-boundary"
    assert np.isfinite(cross["losses"]).all()

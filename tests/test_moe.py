"""MoE: shard_map expert-parallel path == dropless ragged path (when
capacity admits every token), capacity drop behaviour, router invariants."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.launch.mesh import make_test_mesh
from repro.models import blocks
from repro.models.blocks import Ctx


def _setup(e=4, k=2, d=32, f=16):
    cfg = reduced(get_arch("dbrx-132b"), d_model=d, moe_d_ff=f,
                  n_experts=e, experts_per_token=k, n_heads=2,
                  n_kv_heads=1, head_dim=16)
    p = jax.tree.map(
        lambda s: jax.random.normal(jax.random.PRNGKey(hash(s.shape) % 100),
                                    s.shape, jnp.float32) * 0.3,
        blocks.moe_specs(cfg), is_leaf=lambda t: hasattr(t, "shape"))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, d), jnp.float32)
    return cfg, p, x


def test_shard_map_matches_ragged():
    cfg, p, x = _setup()
    mesh = make_test_mesh((1, 1), ("data", "model"))
    ragged = Ctx(cfg=cfg, moe_impl="ragged")
    manual = Ctx(cfg=cfg, moe_impl="shard_map", mesh=mesh,
                 moe_capacity_factor=float(cfg.n_experts))  # no drops
    with mesh:
        o1, a1 = blocks.moe_apply(ragged, p, x)
        o2, a2 = jax.jit(lambda p_, x_: blocks.moe_apply(manual, p_, x_))(
            p, x)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(float(a2), float(a1), rtol=1e-5)


def test_shard_map_grads_match():
    cfg, p, x = _setup()
    mesh = make_test_mesh((1, 1), ("data", "model"))
    ragged = Ctx(cfg=cfg, moe_impl="ragged")
    manual = Ctx(cfg=cfg, moe_impl="shard_map", mesh=mesh,
                 moe_capacity_factor=float(cfg.n_experts))

    def loss(ctx):
        def f(p_, x_):
            o, a = blocks.moe_apply(ctx, p_, x_)
            return jnp.sum(o * o) + a
        return f

    with mesh:
        g1 = jax.grad(loss(ragged))(p, x)
        g2 = jax.jit(jax.grad(loss(manual)))(p, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-3, rtol=2e-3)


def test_capacity_drops_are_bounded():
    """With capacity factor 1.0 and adversarial routing, output degrades
    gracefully (dropped tokens fall back to the residual stream only)."""
    cfg, p, x = _setup()
    mesh = make_test_mesh((1, 1), ("data", "model"))
    tight = Ctx(cfg=cfg, moe_impl="shard_map", mesh=mesh,
                moe_capacity_factor=0.5)
    with mesh:
        o, _ = jax.jit(lambda p_, x_: blocks.moe_apply(tight, p_, x_))(p, x)
    assert bool(jnp.isfinite(o).all())


def test_router_topk_weights_normalized():
    cfg, p, x = _setup()
    xf = x.reshape(-1, x.shape[-1])
    topw, tope, aux = blocks._router(cfg, p, xf)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, rtol=1e-5)
    assert int(tope.max()) < cfg.n_experts
    assert float(aux) >= 1.0 - 1e-3     # e * sum(f_i p_i) >= 1 at balance

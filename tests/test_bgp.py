"""Full BGP engine tests: algebra validation, molecule-level joins,
filter pushdown, the cost-based planner, strategy parity under random
multi-star queries (hypothesis), the batched device join path, and the
serving endpoint."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Compactor
from repro.core.triples import TripleStore
from repro.data.synthetic import (MEASUREMENT, OBSERVATION, P_MODEL,
                                  P_PROCEDURE, P_RESULT, P_TIME, P_VALUE,
                                  SENSOR, SensorGraphSpec, generate)
from repro.query import (BGPQuery, Filter, QueryEngine, StarPattern,
                         eval_bgp_reference, plan_bgp)
from repro.query.bgp import is_var


def _sensor(n=400, seed=3, metadata=True):
    return generate(SensorGraphSpec(n_observations=n, seed=seed,
                                    include_sensor_metadata=metadata))


def _engine(store):
    comp = Compactor()
    comp.run(store)
    return QueryEngine(comp.fgraph)


@pytest.fixture(scope="module")
def sensor_engine():
    eng = _engine(_sensor())
    return eng, eng.fgraph.expand()


def _ids(eng, *terms):
    d = eng.fgraph.store.dict
    return tuple(d.lookup(t) for t in terms)


# ---------------------------------------------------------------------------
# algebra
# ---------------------------------------------------------------------------

def test_star_pattern_requires_var_subject():
    with pytest.raises(ValueError, match="subject"):
        StarPattern("obs/0", ((1, 2),))


def test_filter_validation():
    with pytest.raises(ValueError, match="op"):
        Filter("?v", "~", 3)
    with pytest.raises(ValueError, match="var"):
        Filter("v", "==", 3)


def test_bgp_validation():
    s = StarPattern("?s", ((1, "?v"),))
    with pytest.raises(ValueError, match="at least one star"):
        BGPQuery(stars=())
    with pytest.raises(ValueError, match="unbound"):
        BGPQuery(stars=(s,), filters=(Filter("?w", "==", 0),))
    q = BGPQuery(stars=(s, StarPattern("?v", ((2, "?w"),))))
    assert q.variables == ("?s", "?v", "?w")


def test_filter_apply_vectorized():
    col = np.array([1, 5, 5, 9])
    assert Filter("?v", "==", 5).apply(col).tolist() == \
        [False, True, True, False]
    assert Filter("?v", "<", 5).apply(col).tolist() == \
        [True, False, False, False]
    assert Filter("?v", ">=", 5).apply(col).tolist() == \
        [False, True, True, True]


# ---------------------------------------------------------------------------
# joins: molecule granularity + parity on the sensor schema
# ---------------------------------------------------------------------------

def test_two_star_join_is_molecule_to_molecule(sensor_engine):
    """The obs-sensor join over ``procedure`` runs AMI x AMI: the
    factorized intermediate is bounded by molecule counts, the raw one
    by entity counts."""
    eng, exp = sensor_engine
    obs, sen, p_proc, p_model, m0 = _ids(
        eng, OBSERVATION, SENSOR, P_PROCEDURE, P_MODEL, "model/1")
    q = BGPQuery(stars=(
        StarPattern("?o", ((p_proc, "?s"),), class_id=obs),
        StarPattern("?s", ((p_model, m0),), class_id=sen)))
    ref = eval_bgp_reference(exp, q)
    assert ref.n_rows > 0
    got_f, st_f = eng.query_bgp(q, strategy="factorized",
                                return_stats=True)
    got_r, st_r = eng.query_bgp(q, strategy="raw", return_stats=True)
    assert got_f.same_as(ref) and got_r.same_as(ref)
    assert st_f["deferred_stars"] == 2
    # AMI x AMI vs AM x AM: molecule frontier strictly below entity
    # frontier (20 obs molecules vs 400 observations on this spec)
    assert st_f["max_intermediate"] < st_r["max_intermediate"]
    ami = eng.fgraph.ami(obs) + eng.fgraph.ami(sen)
    assert st_f["max_intermediate"] <= ami


def test_three_star_chain_parity(sensor_engine):
    eng, exp = sensor_engine
    obs, sen, meas, p_proc, p_res, p_model, p_val, m0 = _ids(
        eng, OBSERVATION, SENSOR, MEASUREMENT, P_PROCEDURE, P_RESULT,
        P_MODEL, P_VALUE, "model/0")
    q = BGPQuery(stars=(
        StarPattern("?o", ((p_proc, "?s"), (p_res, "?m")), class_id=obs),
        StarPattern("?s", ((p_model, m0),), class_id=sen),
        StarPattern("?m", ((p_val, "?v"),), class_id=meas)))
    ref = eval_bgp_reference(exp, q)
    assert ref.n_rows > 0
    for strat in ("auto", "raw", "factorized"):
        got = eng.query_bgp(q, strategy=strat)
        assert got.same_as(ref), strat


def test_repeated_var_within_star(sensor_engine):
    """procedure/generatedBy share the sensor object, so binding both
    arms to ONE variable must keep every row (and a fresh variable pair
    must agree with the reference too)."""
    eng, exp = sensor_engine
    obs, p_proc, p_gen = _ids(eng, OBSERVATION, P_PROCEDURE,
                              "ssn:generatedBy")
    q = BGPQuery(stars=(StarPattern(
        "?o", ((p_proc, "?s"), (p_gen, "?s")), class_id=obs),))
    ref = eval_bgp_reference(exp, q)
    assert ref.n_rows > 0
    for strat in ("auto", "raw", "factorized"):
        assert eng.query_bgp(q, strategy=strat).same_as(ref), strat


# ---------------------------------------------------------------------------
# filter pushdown
# ---------------------------------------------------------------------------

def test_filter_pushdown_shrinks_molecule_frontier(sensor_engine):
    """A pushed-down value filter evaluates ONCE per molecule and prunes
    the frontier BEFORE emission; post-hoc filtering carries the full
    frontier through the join."""
    eng, exp = sensor_engine
    meas, p_val, v2 = _ids(eng, MEASUREMENT, P_VALUE, "val/2")
    q = BGPQuery(
        stars=(StarPattern("?m", ((p_val, "?v"),), class_id=meas),),
        filters=(Filter("?v", "<", v2),))
    ref = eval_bgp_reference(exp, q)
    assert ref.n_rows > 0
    pushed, st_p = eng.query_bgp(q, strategy="factorized",
                                 return_stats=True)
    posthoc, st_h = eng.query_bgp(q, strategy="factorized",
                                  posthoc_filters=True, return_stats=True)
    assert pushed.same_as(ref) and posthoc.same_as(ref)
    assert st_p["filters_pushed"] > 0 and st_h["filters_pushed"] == 0
    assert st_p["max_intermediate"] < st_h["max_intermediate"]


def test_filter_ops_parity(sensor_engine):
    eng, exp = sensor_engine
    meas, p_val, v = _ids(eng, MEASUREMENT, P_VALUE, "val/1")
    for op in ("==", "!=", "<", "<=", ">", ">="):
        q = BGPQuery(
            stars=(StarPattern("?m", ((p_val, "?v"),), class_id=meas),),
            filters=(Filter("?v", op, v),))
        ref = eval_bgp_reference(exp, q)
        for strat in ("auto", "raw", "factorized"):
            assert eng.query_bgp(q, strategy=strat).same_as(ref), (op,
                                                                   strat)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_planner_prefers_factorized_for_insp_ground(sensor_engine):
    """In-SP ground lookup: one sorted-row probe on the molecule table
    beats scanning the raw class population."""
    eng, _ = sensor_engine
    meas = _ids(eng, MEASUREMENT)[0]
    t = eng.fgraph.tables[meas]
    arms = tuple((int(p), int(o)) for p, o in zip(t.props, t.objects[0]))
    q = BGPQuery(stars=(StarPattern("?m", arms, class_id=meas),))
    plan = plan_bgp(eng.fgraph, q)
    assert plan.stars[0].strategy == "factorized"
    assert plan.stars[0].deferred


def test_planner_prefers_raw_for_offsp_var_arm(sensor_engine):
    """observationResult is residual (off every Observation SP), so a
    var arm over it must pay per-pair residual probes under the
    factorized strategy -- raw wins."""
    eng, _ = sensor_engine
    obs, p_res = _ids(eng, OBSERVATION, P_RESULT)
    q = BGPQuery(stars=(StarPattern("?o", ((p_res, "?m"),),
                                    class_id=obs),))
    plan = plan_bgp(eng.fgraph, q)
    assert plan.stars[0].strategy == "raw"


def test_planner_join_order_smallest_frontier_first(sensor_engine):
    """The ground-constrained sensor star (12 molecules) enters the join
    before the unconstrained observation star."""
    eng, _ = sensor_engine
    obs, sen, p_proc, p_model, m0 = _ids(
        eng, OBSERVATION, SENSOR, P_PROCEDURE, P_MODEL, "model/1")
    q = BGPQuery(stars=(
        StarPattern("?o", ((p_proc, "?s"),), class_id=obs),
        StarPattern("?s", ((p_model, m0),), class_id=sen)))
    plan = plan_bgp(eng.fgraph, q)
    assert plan.order[0] == 1      # the sensor star leads
    assert plan.stars[1].est_frontier <= plan.stars[0].est_frontier


def test_planner_strategy_override(sensor_engine):
    eng, _ = sensor_engine
    meas, p_val = _ids(eng, MEASUREMENT, P_VALUE)
    q = BGPQuery(stars=(StarPattern("?m", ((p_val, "?v"),),
                                    class_id=meas),))
    assert set(plan_bgp(eng.fgraph, q, strategy="raw").strategies) \
        == {"raw"}
    assert set(plan_bgp(eng.fgraph, q,
                        strategy="factorized").strategies) \
        == {"factorized"}
    with pytest.raises(ValueError, match="strategy"):
        plan_bgp(eng.fgraph, q, strategy="molecular")


def test_fgraph_accessors(sensor_engine):
    eng, _ = sensor_engine
    fg = eng.fgraph
    meas = _ids(eng, MEASUREMENT)[0]
    t = fg.tables[meas]
    assert fg.ami(meas) == t.n_molecules
    assert fg.am(meas) == int(fg.support(meas).sum())
    ents, _ = fg.members_of(int(t.surrogates[0]))
    got = fg.molecule_of(meas, ents)
    assert (got == t.surrogates[0]).all()
    assert fg.molecule_of(meas, np.array([10**6]))[0] == -1


# ---------------------------------------------------------------------------
# hypothesis: random multi-star BGPs x random graphs x random deletes
# ---------------------------------------------------------------------------

def _random_graph(rng, n_ent, n_props, n_obj, n_cls):
    triples = []
    for i in range(n_ent):
        e = f"e{i}"
        for c in range(n_cls):
            if c == 0 or rng.random() < 0.4:
                triples.append((e, "rdf:type", f"C{c}"))
        for p in range(n_props):
            if rng.random() < 0.85:
                triples.append((e, f"p{p}", f"o{rng.integers(0, n_obj)}"))
    return TripleStore.from_triples(triples)


def _random_bgp(rng, store, n_props, n_obj, n_cls):
    """1-3 stars chained by shared variables (star i links to star i+1's
    subject through a var arm), random ground/var objects, random class
    constraints, random filters over any bound variable."""
    n_stars = int(rng.integers(1, 4))
    stars = []
    for i in range(n_stars):
        arms = []
        n_arms = int(rng.integers(1, min(n_props, 3) + 1))
        for k, p in enumerate(rng.choice(n_props, size=n_arms,
                                         replace=False)):
            pid = store.dict.lookup(f"p{p}")
            if pid is None:
                continue
            r = rng.random()
            if r < 0.35:
                arms.append((pid, f"?v{i}_{k}"))
            else:
                o = store.dict.lookup(f"o{rng.integers(0, n_obj + 1)}")
                if o is None:
                    continue
                arms.append((pid, o))
        if i + 1 < n_stars:        # chain: this star joins the next
            pid = store.dict.lookup(f"p{rng.integers(0, n_props)}")
            if pid is not None:
                arms.append((pid, f"?s{i + 1}"))
        if not arms:
            return None
        cid = None
        if rng.random() < 0.7:
            cid = store.dict.lookup(f"C{rng.integers(0, n_cls)}")
        stars.append(StarPattern(f"?s{i}", tuple(arms), class_id=cid))
    q = BGPQuery(stars=tuple(stars))
    filters = []
    for v in q.variables:
        if rng.random() < 0.3:
            val = store.dict.lookup(f"o{rng.integers(0, n_obj)}")
            if val is not None:
                op = ("==", "!=", "<", "<=", ">", ">=")[
                    int(rng.integers(0, 6))]
                filters.append(Filter(v, op, val))
    return BGPQuery(stars=tuple(stars), filters=tuple(filters))


@settings(max_examples=20, deadline=None)
@given(n_ent=st.integers(2, 14), n_props=st.integers(2, 4),
       n_obj=st.integers(1, 3), n_cls=st.integers(1, 2),
       seed=st.integers(0, 10_000), with_deletes=st.booleans())
def test_bgp_strategy_parity_property(n_ent, n_props, n_obj, n_cls, seed,
                                      with_deletes):
    """EVERY random multi-star BGP -- planner-chosen, fixed-raw and
    fixed-factorized, filters pushed AND post-hoc -- answers identically
    to the reference evaluation on expand(), including post-delete
    states, incomplete molecules and multi-typed entities."""
    rng = np.random.default_rng(seed)
    store = _random_graph(rng, n_ent, n_props, n_obj, n_cls)
    comp = Compactor(min_predicted_savings=-10**9)
    comp.run(store)
    if with_deletes and store.n_triples:
        k = int(rng.integers(1, min(4, store.n_triples) + 1))
        rows = store.spo[rng.choice(store.n_triples, size=k,
                                    replace=False)]
        comp.delete(triples=rows)
    eng = QueryEngine(comp.fgraph)
    expanded = comp.fgraph.expand()
    for _ in range(4):
        q = _random_bgp(rng, store, n_props, n_obj, n_cls)
        if q is None:
            continue
        ref = eval_bgp_reference(expanded, q)
        for strat in ("auto", "raw", "factorized"):
            for posthoc in (False, True):
                got = eng.query_bgp(q, strategy=strat,
                                    posthoc_filters=posthoc)
                assert got.columns == ref.columns
                assert got.same_as(ref), (strat, posthoc, q)


# ---------------------------------------------------------------------------
# batched device path
# ---------------------------------------------------------------------------

def test_bgp_device_path_zero_warm_retraces(sensor_engine):
    pytest.importorskip("jax")
    from repro.core import sweep as core_sweep
    eng, exp = sensor_engine
    obs, sen, meas, p_proc, p_res, p_model, p_val, m0 = _ids(
        eng, OBSERVATION, SENSOR, MEASUREMENT, P_PROCEDURE, P_RESULT,
        P_MODEL, P_VALUE, "model/1")
    q = BGPQuery(stars=(
        StarPattern("?o", ((p_proc, "?s"), (p_res, "?m")), class_id=obs),
        StarPattern("?s", ((p_model, m0),), class_id=sen),
        StarPattern("?m", ((p_val, "?v"),), class_id=meas)))
    ref = eval_bgp_reference(exp, q)
    core_sweep.reset_trace_stats()
    first = eng.query_bgp(q, strategy="factorized", backend="device")
    cold = core_sweep.trace_count()
    again = eng.query_bgp(q, strategy="factorized", backend="device")
    warm = core_sweep.trace_count()
    assert first.same_as(ref) and again.same_as(ref)
    assert warm == cold, f"warm rerun retraced: {cold} -> {warm}"


# ---------------------------------------------------------------------------
# serving endpoint
# ---------------------------------------------------------------------------

def test_serving_bgp_endpoint():
    from repro.serving import BGPQueryRequest, GraphQueryService
    store = _sensor(200, seed=7)
    comp = Compactor()
    comp.run(store)
    svc = GraphQueryService(comp.fgraph)
    stars = (("?o", ((P_PROCEDURE, "?s"), (P_TIME, "time/3")),
              OBSERVATION),
             ("?s", ((P_MODEL, "model/1"),), SENSOR))
    for rid, strat in enumerate(("auto", "raw", "factorized")):
        svc.submit(BGPQueryRequest(rid=rid, stars=stars, strategy=strat))
    svc.submit(BGPQueryRequest(        # unknown term: empty, not an error
        rid=3, stars=(("?m", ((P_VALUE, "val/nope"),), MEASUREMENT),)))
    out = svc.run()
    assert out[0].n_rows > 0
    assert sorted(out[0].rows) == sorted(out[1].rows) \
        == sorted(out[2].rows)
    assert out[0].variables == ("?o", "?s")
    assert all(s in ("raw", "factorized") for s in out[0].strategies)
    assert out[3].n_rows == 0 and out[3].rows == []


# ---------------------------------------------------------------------------
# cost model: mixed-slot re-pricing + calibration
# ---------------------------------------------------------------------------

def _chain_query(eng):
    obs, meas, sen = _ids(eng, OBSERVATION, MEASUREMENT, SENSOR)
    p_proc, p_res, p_model, p_val = _ids(
        eng, P_PROCEDURE, P_RESULT, P_MODEL, P_VALUE)
    d = eng.fgraph.store.dict
    return BGPQuery(stars=(
        StarPattern("?o", ((p_proc, "?s"), (p_res, "?m")), class_id=obs),
        StarPattern("?s", ((p_model, d.lookup("model/1")),),
                    class_id=sen),
        StarPattern("?m", ((p_val, "?v"),), class_id=meas)))


def test_mixed_slot_repricing_flips_and_preserves_semantics(sensor_engine):
    """With an unbounded granularity-crossing price no deferred star may
    keep a non-deferred join partner after the fixpoint pass; with the
    price at zero the second pass is a no-op; every variant returns the
    same bindings (planning changes cost, never semantics)."""
    from repro.query.bgp import CostModel, execute_bgp
    from repro.query.bgp.planner import CostModel as CM
    eng, _ = sensor_engine
    fg = eng.fgraph
    q = _chain_query(eng)

    free = plan_bgp(fg, q, cost_model=CostModel(c_mix=0.0))
    priced = plan_bgp(fg, q, cost_model=CostModel(c_mix=1e9))
    var_sets = [set(s.variables) for s in q.stars]
    for i, sp in enumerate(priced.stars):
        if sp.deferred:
            assert not any(var_sets[i] & var_sets[j]
                           for j, o in enumerate(priced.stars)
                           if j != i and not o.deferred), \
                "mixed edge survived an infinite c_mix"
    ref, _ = execute_bgp(fg, q, plan_bgp(fg, q, strategy="raw"),
                         raw_store=eng.raw_store)
    for plan in (free, priced, plan_bgp(fg, q)):
        got, _ = execute_bgp(fg, q, plan, raw_store=eng.raw_store)
        assert got.same_as(ref)


def test_mixed_partner_count_raises_deferred_cost(sensor_engine):
    from repro.query.bgp.planner import plan_star
    eng, _ = sensor_engine
    fg = eng.fgraph
    q = _chain_query(eng)
    for si in range(len(q.stars)):
        base = plan_star(fg, q, si, strategy="factorized")
        if not base.deferred:
            continue
        c0 = plan_star(fg, q, si, mixed_partners=0)
        c2 = plan_star(fg, q, si, mixed_partners=2)
        assert c2.cost >= c0.cost


def test_single_star_plan_cost_matches_features(sensor_engine):
    """planner cost and calibrate features are the same linear form:
    cost(plan) == COST . features(mode) for an isolated star."""
    from repro.query.bgp import calibrate as cal
    from repro.query.bgp.planner import COST, plan_star
    eng, _ = sensor_engine
    fg = eng.fgraph
    obs, = _ids(eng, OBSERVATION)
    p_proc, = _ids(eng, P_PROCEDURE)
    q = BGPQuery(stars=(StarPattern("?o", ((p_proc, "?s"),),
                                    class_id=obs),))
    for strategy, mode in (("raw", "raw"), ("factorized", None)):
        sp = plan_star(fg, q, 0, strategy=strategy)
        m = mode or ("deferred" if sp.deferred else "factorized")
        feats = cal.star_features(fg, q, 0, m)
        assert sp.cost == pytest.approx(
            float(COST.as_array() @ feats), rel=1e-9)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_fit_cost_model_recovers_planted_constants(seed):
    """y = A @ c_true with a well-conditioned A and a weak prior: the
    ridge fit must recover c_true up to the c_mol normalization."""
    from repro.query.bgp import CostModel, fit_cost_model
    rng = np.random.default_rng(seed)
    c_true = rng.uniform(0.5, 8.0, size=6)
    A = rng.uniform(0.0, 1000.0, size=(40, 6))
    samples = [(A[i], float(A[i] @ c_true)) for i in range(len(A))]
    fitted = fit_cost_model(samples, prior=CostModel(), l2=1e-9)
    np.testing.assert_allclose(fitted.as_array(),
                               c_true / c_true[0], rtol=1e-3)


def test_fit_cost_model_pins_unidentified_features_to_prior():
    """A feature no sample exercises must come back at (the normalized)
    prior, not at an arbitrary least-norm value."""
    from repro.query.bgp import CostModel, fit_cost_model
    rng = np.random.default_rng(0)
    c_true = np.array([2.0, 4.0, 1.0, 0.5, 3.0, 6.0])
    A = rng.uniform(0.0, 1000.0, size=(40, 6))
    A[:, 5] = 0.0                       # mix never exercised
    samples = [(A[i], float(A[i] @ c_true)) for i in range(len(A))]
    prior = CostModel()
    fitted = fit_cost_model(samples, prior=prior, l2=1e-6)
    # identified columns recovered; the dead column stays a positive
    # prior-derived cost instead of collapsing to a least-norm zero
    np.testing.assert_allclose(fitted.as_array()[:5],
                               c_true[:5] / c_true[0], rtol=1e-3)
    assert fitted.c_mix > 0


def test_calibration_report_shape(sensor_engine):
    from repro.query.bgp import calibration_report
    eng, _ = sensor_engine
    obs, = _ids(eng, OBSERVATION)
    p_proc, p_time = _ids(eng, P_PROCEDURE, P_TIME)
    d = eng.fgraph.store.dict
    w = {"probe": [BGPQuery(stars=(StarPattern(
        "?o", ((p_proc, "?s"), (p_time, d.lookup("time/3"))),
        class_id=obs),))]}
    rep = calibration_report(eng, w)
    assert rep["n_samples"] == 2        # raw + factorized
    assert set(rep["fitted"]) == set(rep["committed"]) \
        == {"mol", "residual", "emit", "scan", "pair", "mix"}
    assert rep["rel_l1_error"] >= 0.0

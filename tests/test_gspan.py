"""gSpan baseline: DFS-code mining correctness on small graphs."""
import numpy as np

from repro.core.gspan import DBGraph, is_min, mine, molecules_of_class
from repro.data.synthetic import figure1_graph


def test_single_edge_patterns():
    g1 = DBGraph.from_edges([0, 1], [(0, 1, 7)])
    g2 = DBGraph.from_edges([0, 1], [(0, 1, 7)])
    pats = mine([g1, g2], min_support=2)
    assert len(pats) == 1
    assert pats[0].support == 2
    assert pats[0].code == ((0, 1, 0, 7, 1, 1),)


def test_star_molecule_enumeration():
    """A 3-edge star yields all 2^3 - 1 = 7 connected sub-stars."""
    g = DBGraph.from_edges([10, 1, 2, 3],
                           [(0, 1, 100), (0, 2, 101), (0, 3, 102)])
    pats = mine([g], min_support=1)
    assert len(pats) == 7


def test_support_counting():
    """Pattern in 2 of 3 graphs has support 2."""
    mk = lambda o: DBGraph.from_edges([5, o], [(0, 1, 9)])
    pats = mine([mk(1), mk(1), mk(2)], min_support=1)
    supp = {p.code[0][5]: p.support for p in pats}
    assert supp[1] == 2 and supp[2] == 1
    assert mine([mk(1), mk(1), mk(2)], min_support=2)[0].code[0][5] == 1


def test_chain_and_direction():
    """Directed chain a->b->c is found; direction bits preserved."""
    g = DBGraph.from_edges([0, 1, 2], [(0, 1, 5), (1, 2, 6)])
    pats = mine([g], min_support=1)
    codes = {p.code for p in pats}
    # the 2-edge chain pattern exists
    two_edge = [c for c in codes if len(c) == 2]
    assert len(two_edge) == 1


def test_triangle_cycle():
    """Backward-edge handling: a directed triangle is mined as one 3-edge
    pattern (plus its sub-patterns)."""
    g = DBGraph.from_edges([0, 0, 0], [(0, 1, 1), (1, 2, 1), (2, 0, 1)])
    pats = mine([g], min_support=1)
    assert any(len(p.code) == 3 for p in pats)


def test_minimality_filter():
    """is_min accepts canonical codes and the miner emits only those."""
    g = DBGraph.from_edges([1, 2, 3], [(0, 1, 4), (0, 2, 5)])
    for p in mine([g], min_support=1):
        assert is_min(p.code)


def test_molecules_of_class():
    store = figure1_graph()
    C = store.dict.lookup("C")
    ents, graphs = molecules_of_class(store, C)
    assert len(graphs) == 4
    for g in graphs:
        assert len(g.edges) == 4          # p1..p4 per entity
        assert g.vlabels[0] == C


def test_pattern_space_is_exponential_in_star_width():
    """The cost E.FSP pays: pattern count doubles per shared property."""
    def star(width):
        vl = [99] + list(range(1, width + 1))
        return DBGraph.from_edges(vl, [(0, i + 1, 50 + i)
                                       for i in range(width)])
    c4 = len(mine([star(4)], min_support=1))
    c6 = len(mine([star(6)], min_support=1))
    assert c4 == 2 ** 4 - 1
    assert c6 == 2 ** 6 - 1

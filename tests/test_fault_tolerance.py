"""Fault tolerance: checkpoint/restart, heartbeat/straggler, retry,
elastic re-shard, data-pipeline rebalance."""
from __future__ import annotations

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer, latest_step
from repro.data.lm_pipeline import LMPipeline, PipelineSpec
from repro.dist.elastic import choose_mesh_shape
from repro.dist.fault import (SITES, FaultPlan, InjectedFault, Monitor,
                              retry)


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"m": jnp.ones((2, 3))}, "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    s = _state()
    ck.save(s, 7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), s)
    restored, step = ck.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_retention_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=True)
    s = _state()
    for step in (1, 2, 3, 4):
        ck.save(s, step)
    ck.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_000003", "step_000004"]
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_crash_leftover_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(_state(), 1)
    os.makedirs(tmp_path / "step_000002.tmp")     # simulated crash
    assert latest_step(str(tmp_path)) == 1
    ck.save(_state(), 3)                          # gc cleans the leftover
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_preempt_resume_identical_losses(tmp_path):
    """Crash at step 6, resume, final state == uninterrupted run."""
    from repro.launch.train import main
    args = ["--arch", "qwen2-0.5b", "--reduced", "--steps", "10",
            "--batch", "4", "--seq", "32", "--log-every", "100",
            "--ckpt-every", "3"]
    full = main(args + ["--ckpt-dir", str(tmp_path / "a")])
    part = main(args + ["--ckpt-dir", str(tmp_path / "b"),
                        "--preempt-at", "6"])
    assert part["preempted"] and part["steps_done"] == 6
    resumed = main(args + ["--ckpt-dir", str(tmp_path / "b")])
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=1e-5)


def test_monitor_detects_dead_and_straggler():
    clock = [0.0]
    dead, slow = [], []
    mon = Monitor(deadline_s=5.0, straggler_factor=3,
                  on_dead=dead.append, on_straggler=slow.append,
                  clock=lambda: clock[0])
    for w in ("h0", "h1", "h2"):
        mon.record(w, step=10)
    clock[0] = 2.0
    mon.record("h0", 13)
    mon.record("h1", 13)
    mon.record("h2", 10)           # 3 steps behind -> straggler
    mon.check()
    assert slow == ["h2"] and not dead
    clock[0] = 9.0                 # h2 stops beating entirely
    mon.record("h0", 14)
    mon.record("h1", 14)
    mon.check()
    assert dead == ["h2"]
    assert mon.healthy_workers() == ["h0", "h1"]


def test_retry_backoff():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=4, sleep=lambda _: None)() == "ok"
    assert len(calls) == 3
    with pytest.raises(OSError):
        retry(lambda: (_ for _ in ()).throw(OSError()), attempts=2,
              sleep=lambda _: None)()


def test_retry_decorrelated_jitter_bounds():
    """Jittered delays stay in [base, min(max, 3 * prev)] and are
    reproducible for a seeded rng."""
    import random

    def runs(seed):
        delays = []
        fn = retry(lambda: (_ for _ in ()).throw(OSError()), attempts=6,
                   base_s=0.5, max_s=4.0, sleep=delays.append,
                   rng=random.Random(seed))
        with pytest.raises(OSError):
            fn()
        return delays

    delays = runs(7)
    assert len(delays) == 5              # attempts - 1 sleeps
    prev = 0.5
    for d in delays:
        assert 0.5 <= d <= min(4.0, prev * 3.0) + 1e-9
        prev = d
    assert runs(7) == delays             # seeded: reproducible
    assert runs(8) != delays


def test_retry_deadline_budget_and_attempt_attribution():
    """The overall deadline stops retrying early, clips the final
    sleep, and the raised exception carries the attempt count."""
    clock = [0.0]
    slept = []

    def sleep(s):
        slept.append(s)
        clock[0] += s

    def always():
        clock[0] += 4.0                  # each attempt burns 4s
        raise OSError("down")

    with pytest.raises(OSError) as ei:
        retry(always, attempts=50, base_s=10.0, jitter=False,
              deadline_s=9.0, sleep=sleep, clock=lambda: clock[0])()
    e = ei.value
    assert e.retry_attempts == 2         # 4s + sleep(5) + 4s > 9s budget
    assert e.retry_elapsed_s >= 9.0
    assert slept == [5.0]                # 10s backoff clipped to budget
    # without a deadline the attempt count still rides the exception
    with pytest.raises(OSError) as ei:
        retry(always, attempts=3, sleep=lambda _: None)()
    assert ei.value.retry_attempts == 3


def test_retry_on_retry_hook_and_injected_fault_passthrough():
    seen = []

    def flaky():
        if len(seen) < 2:
            raise OSError("transient")
        return "ok"

    out = retry(flaky, attempts=5, sleep=lambda _: None,
                on_retry=lambda a, d, e: seen.append((a, type(e))))()
    assert out == "ok"
    assert [a for a, _ in seen] == [1, 2]
    assert all(t is OSError for _, t in seen)

    # an injected fault models process death: retry must NOT absorb it
    calls = []

    def dies():
        calls.append(1)
        raise InjectedFault("apply", 0)

    with pytest.raises(InjectedFault):
        retry(dies, attempts=5, sleep=lambda _: None)()
    assert len(calls) == 1


def test_fault_plan_seeded_deterministic_and_one_shot():
    a = FaultPlan.seeded(3)
    b = FaultPlan.seeded(3)
    assert (a.site, a.occurrence) == (b.site, b.occurrence)
    assert a.site in SITES
    c = FaultPlan.seeded(4, sites=("apply",), max_occurrence=0)
    assert c.site == "apply" and c.occurrence == 0
    with pytest.raises(InjectedFault):
        c.fire("apply")
    c.fire("apply")                      # one-shot: never trips again
    assert c.seen("apply") == 2
    c.fire("redetect")                   # other sites just count
    assert c.seen("redetect") == 1
    with pytest.raises(ValueError):
        FaultPlan("no.such.site")
    with pytest.raises(ValueError):
        FaultPlan("apply", mode="explode")


def test_pipeline_rebalance_preserves_batch():
    spec = PipelineSpec(vocab_size=101, seq_len=8, global_batch=12)
    pipe = LMPipeline(spec)
    full = pipe.batch_at(5)["tokens"]
    shares = LMPipeline.reassign(4, 12, slow={1})
    assert shares.sum() == 12 and shares[1] < 3
    parts = [pipe.host_slice(5, h, 4, shares)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # determinism / skip-ahead
    np.testing.assert_array_equal(pipe.batch_at(5)["tokens"], full)
    assert not np.array_equal(pipe.batch_at(6)["tokens"], full)


def test_elastic_mesh_choice():
    assert choose_mesh_shape(512) == (32, 16)
    assert choose_mesh_shape(256) == (16, 16)
    assert choose_mesh_shape(192) == (12, 16)
    assert choose_mesh_shape(100) == (25, 4)
    assert choose_mesh_shape(7) == (7, 1)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint -> restore with explicit shardings on a 1-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.elastic import remesh
    ck = Checkpointer(str(tmp_path), async_write=False)
    s = _state()
    ck.save(s, 1)
    mesh = remesh(1, tp_pref=1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = ck.restore(jax.tree.map(jnp.zeros_like, s), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))

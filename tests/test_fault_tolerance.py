"""Fault tolerance: checkpoint/restart, heartbeat/straggler, retry,
elastic re-shard, data-pipeline rebalance."""
from __future__ import annotations

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer, latest_step
from repro.data.lm_pipeline import LMPipeline, PipelineSpec
from repro.dist.elastic import choose_mesh_shape
from repro.dist.fault import Monitor, retry


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"m": jnp.ones((2, 3))}, "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    s = _state()
    ck.save(s, 7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), s)
    restored, step = ck.restore(like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_retention_and_atomicity(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=True)
    s = _state()
    for step in (1, 2, 3, 4):
        ck.save(s, step)
    ck.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_000003", "step_000004"]
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 4


def test_checkpoint_crash_leftover_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_write=False)
    ck.save(_state(), 1)
    os.makedirs(tmp_path / "step_000002.tmp")     # simulated crash
    assert latest_step(str(tmp_path)) == 1
    ck.save(_state(), 3)                          # gc cleans the leftover
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_preempt_resume_identical_losses(tmp_path):
    """Crash at step 6, resume, final state == uninterrupted run."""
    from repro.launch.train import main
    args = ["--arch", "qwen2-0.5b", "--reduced", "--steps", "10",
            "--batch", "4", "--seq", "32", "--log-every", "100",
            "--ckpt-every", "3"]
    full = main(args + ["--ckpt-dir", str(tmp_path / "a")])
    part = main(args + ["--ckpt-dir", str(tmp_path / "b"),
                        "--preempt-at", "6"])
    assert part["preempted"] and part["steps_done"] == 6
    resumed = main(args + ["--ckpt-dir", str(tmp_path / "b")])
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=1e-5)


def test_monitor_detects_dead_and_straggler():
    clock = [0.0]
    dead, slow = [], []
    mon = Monitor(deadline_s=5.0, straggler_factor=3,
                  on_dead=dead.append, on_straggler=slow.append,
                  clock=lambda: clock[0])
    for w in ("h0", "h1", "h2"):
        mon.record(w, step=10)
    clock[0] = 2.0
    mon.record("h0", 13)
    mon.record("h1", 13)
    mon.record("h2", 10)           # 3 steps behind -> straggler
    mon.check()
    assert slow == ["h2"] and not dead
    clock[0] = 9.0                 # h2 stops beating entirely
    mon.record("h0", 14)
    mon.record("h1", 14)
    mon.check()
    assert dead == ["h2"]
    assert mon.healthy_workers() == ["h0", "h1"]


def test_retry_backoff():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=4, sleep=lambda _: None)() == "ok"
    assert len(calls) == 3
    with pytest.raises(OSError):
        retry(lambda: (_ for _ in ()).throw(OSError()), attempts=2,
              sleep=lambda _: None)()


def test_pipeline_rebalance_preserves_batch():
    spec = PipelineSpec(vocab_size=101, seq_len=8, global_batch=12)
    pipe = LMPipeline(spec)
    full = pipe.batch_at(5)["tokens"]
    shares = LMPipeline.reassign(4, 12, slow={1})
    assert shares.sum() == 12 and shares[1] < 3
    parts = [pipe.host_slice(5, h, 4, shares)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # determinism / skip-ahead
    np.testing.assert_array_equal(pipe.batch_at(5)["tokens"], full)
    assert not np.array_equal(pipe.batch_at(6)["tokens"], full)


def test_elastic_mesh_choice():
    assert choose_mesh_shape(512) == (32, 16)
    assert choose_mesh_shape(256) == (16, 16)
    assert choose_mesh_shape(192) == (12, 16)
    assert choose_mesh_shape(100) == (25, 4)
    assert choose_mesh_shape(7) == (7, 1)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint -> restore with explicit shardings on a 1-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.elastic import remesh
    ck = Checkpointer(str(tmp_path), async_write=False)
    s = _state()
    ck.save(s, 1)
    mesh = remesh(1, tp_pref=1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = ck.restore(jax.tree.map(jnp.zeros_like, s), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))

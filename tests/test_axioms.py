"""Def. 4.11 axioms: losslessness and query rewriting without decompression."""
import numpy as np

from repro.core import (TripleStore, expand, factorize, gfsp, match_star,
                        semantic_triples)
from repro.data.synthetic import (SensorGraphSpec, figure1_graph, generate,
                                  property_set_ids)


def test_expansion_restores_figure1():
    store = figure1_graph()
    C = store.dict.lookup("C")
    p = [store.dict.lookup(k) for k in ["p1", "p2", "p3"]]
    res = factorize(store, C, p)
    # semantic closure of G' == semantic closure of G (losslessness)
    a = semantic_triples(store)
    b = semantic_triples(res.graph)
    assert a.shape == b.shape
    assert (a == b).all()


def test_expansion_axiom1_type():
    """(s instanceOf sg) & (sg type C) => (s type C)."""
    store = figure1_graph()
    C = store.dict.lookup("C")
    p = [store.dict.lookup(k) for k in ["p1", "p2", "p3"]]
    res = factorize(store, C, p)
    closed = expand(res.graph)
    for c in ["c1", "c2", "c3", "c4"]:
        cid = store.dict.lookup(c)
        assert ((closed.spo[:, 0] == cid) & (closed.spo[:, 1] == closed.TYPE)
                & (closed.spo[:, 2] == C)).any()


def test_losslessness_sensor_graph():
    store = generate(SensorGraphSpec(n_observations=600, seed=21))
    C, a5 = property_set_ids(store, "A5")
    res = factorize(store, C, a5)
    a = semantic_triples(store)
    b = semantic_triples(res.graph)
    assert a.shape == b.shape and (a == b).all()


def test_query_rewriting_equivalence():
    """Star queries answered over G' (with rewriting) match answers over G --
    'no decompression, no customized engine'."""
    store = generate(SensorGraphSpec(n_observations=500, seed=2,
                                     include_result_links=False))
    C = store.dict.lookup("ssn:Observation")
    res_fsp = gfsp(store, C)
    fact = factorize(store, C, res_fsp.props)
    gprime = fact.graph
    # probe queries: each detected star pattern's conditions + mixed queries
    rng = np.random.default_rng(0)
    for members, objs in res_fsp.fsp[:10]:
        conds = list(zip(res_fsp.props, objs.tolist()))
        orig = match_star(store, conds, rewrite=False)
        new = match_star(gprime, conds, rewrite=True)
        assert (np.sort(orig) == np.sort(new)).all()
        # partial star (subset of conditions)
        k = max(1, len(conds) - 1)
        sub = [conds[i] for i in rng.choice(len(conds), k, replace=False)]
        orig = match_star(store, sub, rewrite=False)
        new = match_star(gprime, sub, rewrite=True)
        assert (np.sort(orig) == np.sort(new)).all()


def test_query_without_rewriting_loses_answers():
    """Sanity: the rewrite is actually needed on the factorized graph."""
    store = figure1_graph()
    C = store.dict.lookup("C")
    p1 = store.dict.lookup("p1")
    e1 = store.dict.lookup("e1")
    res = factorize(store, C, [store.dict.lookup(k)
                               for k in ["p1", "p2", "p3"]])
    assert match_star(res.graph, [(p1, e1)], rewrite=False).size == 0
    assert match_star(res.graph, [(p1, e1)], rewrite=True).size == 4

"""ShardedFactorizedGraph: partition disjointness, plan balance /
chunk-splitting, shard-local detection digest parity (sequential and
fork-parallel), cross-shard AMI, query fan-out parity, the planner's
``sharded_graph=`` paths, atomic swap discipline, and the
``ShardedQueryService`` request surface."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import CompactionPlanner
from repro.core.triples import TripleStore
from repro.data.synthetic import SensorGraphSpec, generate
from repro.dist.graph import (ShardedFactorizedGraph, ShardedQueryEngine,
                              ShardPlan)
from repro.query import QueryEngine, StarQuery


def _sensor(n=200, seed=7, **kw):
    return generate(SensorGraphSpec(n_observations=n, seed=seed, **kw))


def _detected(store, n_shards, *, parallel=False, oversplit=2):
    sharded = ShardedFactorizedGraph.partition(store, n_shards,
                                               oversplit=oversplit)
    report = sharded.detect_all(backend="host", parallel=parallel)
    return sharded, report


def _repl(store):
    snap, rep = CompactionPlanner("gfsp", "host").run(store.copy())
    return snap, rep


# ---------------------------------------------------------------------------
# partition + plan
# ---------------------------------------------------------------------------

def test_partition_rows_disjoint_and_complete():
    store = _sensor()
    sharded = ShardedFactorizedGraph.partition(store, 3)
    parts = [s.fgraph.store.spo for s in sharded.snapshots]
    assert sum(p.shape[0] for p in parts) == store.n_triples
    union = np.unique(np.concatenate(parts, axis=0), axis=0)
    assert union.shape[0] == store.n_triples          # disjoint rows
    assert np.array_equal(union, np.unique(store.spo, axis=0))


def test_typed_subject_star_never_straddles_shards():
    store = _sensor()
    sharded = ShardedFactorizedGraph.partition(store, 3)
    plan = sharded.plan
    for sid, snap in enumerate(sharded.snapshots):
        subs = snap.fgraph.store.spo[:, 0].astype(np.int64)
        pos = np.searchsorted(plan.owner_entities, subs)
        pos_c = np.minimum(pos, plan.owner_entities.shape[0] - 1)
        typed = (pos < plan.owner_entities.shape[0]) & \
            (plan.owner_entities[pos_c] == subs)
        # every typed row in this shard is owned by exactly this shard
        assert (plan.owner_shard[pos_c[typed]] == sid).all()


def test_plan_balances_on_edge_counts_and_chunk_splits():
    store = _sensor(400)
    plan = ShardPlan.build(store, 4, oversplit=4)
    w = np.asarray(plan.shard_weights)
    assert w.sum() == store.n_triples
    assert w.max() <= 2 * max(1, w.min())    # LPT on chunked items
    # the sensor shape has few big classes: filling 4 shards forces
    # chunk-splitting, which is what split_classes reports
    assert plan.n_chunks > len(store.classes())
    assert plan.split_classes
    for cid in plan.split_classes:
        assert len(plan.class_shards[cid]) > 1


def test_route_rows_matches_partition():
    store = _sensor()
    plan = ShardPlan.build(store, 3)
    sids = plan.route_rows(store.spo)
    assert sids.shape == (store.n_triples,)
    assert set(np.unique(sids)) <= set(range(3))
    # routing is deterministic and row-order independent
    perm = np.random.default_rng(0).permutation(store.n_triples)
    assert np.array_equal(plan.route_rows(store.spo[perm]), sids[perm])


# ---------------------------------------------------------------------------
# shard-local detection: digest parity (Def. 4.10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_detect_sequential_digest_parity(n_shards):
    store = _sensor()
    snap, rep = _repl(store)
    sharded, report = _detected(store, n_shards)
    assert sharded.digest() == snap.digest()
    assert sharded.n_triples <= store.n_triples       # compaction paid
    assert set(report["shards"]) == set(range(n_shards))
    for r in report["shards"].values():
        assert r["n_after"] <= r["n_before"]
        assert r["detect_ms"] >= 0.0


def test_detect_fork_parallel_digest_parity_and_shared_dict():
    store = _sensor()
    snap, _ = _repl(store)
    sharded, report = _detected(store, 3, parallel=True)
    assert sharded.digest() == snap.digest()
    # workers minted surrogates through the fork boundary; the parent
    # re-minted them into the ONE shared dictionary
    for s in sharded.snapshots:
        assert s.fgraph.store.dict is store.dict
        for t in s.fgraph.tables.values():
            for sur in t.surrogates[:2]:
                assert store.dict.term(int(sur)).startswith("repro:sg/s")
    assert any(r["classes"] for r in report["shards"].values())


def test_detect_bumps_epoch_and_swaps_atomically():
    store = _sensor()
    sharded = ShardedFactorizedGraph.partition(store, 2)
    before = sharded.snapshots
    assert sharded.epoch == 0
    sharded.detect_all(backend="host")
    after = sharded.snapshots
    assert after is not before            # one tuple store, not mutation
    assert sharded.epoch == 1
    assert all(s.epoch == 1 for s in after)


def test_cross_shard_ami_exact():
    store = _sensor(400)
    sharded = ShardedFactorizedGraph.partition(store, 4, oversplit=4)
    assert sharded.plan.split_classes
    for cid in sharded.plan.split_classes:
        props = np.asarray(sharded.plan.class_props[int(cid)], np.int32)
        _, mat = store.copy().object_matrix(int(cid), props)
        want = int(np.unique(mat, axis=0).shape[0])
        assert sharded.cross_shard_ami(cid) == want


def test_swap_shard_replaces_exactly_one():
    store = _sensor()
    sharded, _ = _detected(store, 3)
    before = sharded.snapshots
    sharded.swap_shard(1, before[1])       # identity swap still re-tuples
    after = sharded.snapshots
    assert after is not before
    assert after[0] is before[0] and after[2] is before[2]


# ---------------------------------------------------------------------------
# planner sharded paths
# ---------------------------------------------------------------------------

def test_planner_plan_sharded_graph_returns_per_shard_plans():
    store = _sensor()
    sharded = ShardedFactorizedGraph.partition(store, 2)
    plans = CompactionPlanner("gfsp", "host").plan(sharded_graph=sharded)
    assert set(plans) == {0, 1}
    for p in plans.values():
        assert all(e.predicted_edges >= 0 for e in p)


def test_planner_redetect_sharded_graph_keeps_digest():
    store = _sensor()
    sharded, _ = _detected(store, 2)
    digest = sharded.digest()
    dirty = [int(c) for c in store.classes()][:1]
    before = sharded.snapshots
    out, reports = CompactionPlanner("gfsp", "host").redetect(
        None, dirty, sharded_graph=sharded)
    assert out is sharded
    assert sharded.snapshots is not before     # single atomic tuple swap
    assert sharded.digest() == digest
    assert reports                             # some shard held the class
    touched = {sid for sid in reports}
    for cid in dirty:
        assert touched & set(sharded.plan.shards_for_class(cid))


# ---------------------------------------------------------------------------
# query fan-out parity
# ---------------------------------------------------------------------------

def _queries(fg, per_class=6):
    qs = []
    for cid, t in sorted(fg.tables.items()):
        for row in t.objects[:per_class]:
            qs.append(StarQuery(arms=tuple(
                (int(p), int(o)) for p, o in zip(t.props, row)),
                class_id=cid))
            qs.append(StarQuery(arms=((int(t.props[0]), int(row[0])),
                                      (int(t.props[-1]), None)),
                      class_id=cid))
        # classless variant of the same star: coordinator-merged
        qs.append(StarQuery(arms=((int(t.props[0]), None),),
                            class_id=None))
    return qs


def test_sharded_query_engine_star_parity():
    store = _sensor()
    snap, _ = _repl(store)
    sharded, _ = _detected(store, 3)
    repl = QueryEngine(snap.fgraph)
    eng = ShardedQueryEngine(sharded)
    for q in _queries(snap.fgraph):
        a = repl.query(q)
        b = eng.query(q)
        assert a.same_as(b), q
    assert sharded.traffic["query_bytes"] > 0


def test_sharded_query_engine_batch_parity():
    store = _sensor()
    snap, _ = _repl(store)
    sharded, _ = _detected(store, 3)
    qs = _queries(snap.fgraph)
    ra = QueryEngine(snap.fgraph).query_batch(qs)
    rb = ShardedQueryEngine(sharded).query_batch(qs)
    for q, a, b in zip(qs, ra, rb):
        assert a.same_as(b), q


def test_sharded_bgp_parity():
    from repro.query.bgp.algebra import BGPQuery, Filter, StarPattern
    store = _sensor()
    snap, _ = _repl(store)
    sharded, _ = _detected(store, 3)
    d = store.dict
    cid = d.lookup("ssn:Observation")
    t = snap.fgraph.tables[cid]
    p0, p1 = int(t.props[0]), int(t.props[-1])
    q = BGPQuery(
        stars=(StarPattern("?s", ((p0, "?v"), (p1, "?w")), cid),),
        filters=(Filter("?v", "!=", -1),))
    a = QueryEngine(snap.fgraph).query_bgp(q)
    b = ShardedQueryEngine(sharded).query_bgp(q)
    assert a.columns == b.columns
    assert np.array_equal(np.unique(a.rows, axis=0),
                          np.unique(b.rows, axis=0))


def test_sharded_engine_rebind_follows_swap():
    store = _sensor()
    sharded, _ = _detected(store, 2)
    eng = ShardedQueryEngine(sharded)
    q = _queries(sharded.snapshots[0].fgraph
                 if sharded.snapshots[0].fgraph.tables
                 else sharded.snapshots[1].fgraph, per_class=1)[0]
    before = eng.query(q)
    CompactionPlanner("gfsp", "host").redetect(
        None, [int(c) for c in store.classes()], sharded_graph=sharded)
    eng.rebind()
    for e, s in zip(eng.engines, sharded.snapshots):
        assert e.fgraph is s.fgraph
    assert eng.query(q).same_as(before)


# ---------------------------------------------------------------------------
# ShardedQueryService: fan-out request surface
# ---------------------------------------------------------------------------

def _term_requests(store, fg, d):
    from repro.serving import GraphQueryRequest
    reqs = []
    rid = 0
    for cid, t in sorted(fg.tables.items()):
        cterm = d.term(cid)
        row = t.objects[0]
        reqs.append(GraphQueryRequest(
            rid=rid, arms=tuple((d.term(int(p)), d.term(int(o)))
                                for p, o in zip(t.props, row)),
            class_term=cterm))
        rid += 1
        reqs.append(GraphQueryRequest(
            rid=rid, arms=((d.term(int(t.props[0])), None),),
            class_term=cterm))
        rid += 1
        reqs.append(GraphQueryRequest(          # classless: coordinator
            rid=rid, arms=((d.term(int(t.props[0])), None),),
            class_term=None))
        rid += 1
    return reqs


def test_sharded_service_parity_with_replicated_service():
    from repro.serving import GraphQueryService, ShardedQueryService
    store = _sensor()
    snap, _ = _repl(store)
    sharded, _ = _detected(store, 3)
    reqs = _term_requests(store, snap.fgraph, store.dict)

    ref = GraphQueryService(snap.fgraph)
    svc = ShardedQueryService(sharded)
    for r in reqs:
        assert ref.submit(r)
        assert svc.submit(r)
    want = ref.run()
    got = svc.run()
    assert set(got) == set(want)
    for rid in want:
        a, b = want[rid], got[rid]
        assert a.status == b.status == "ok"
        assert sorted(zip(a.subjects, a.var_objects)) == \
            sorted(zip(b.subjects, b.var_objects)), rid


def test_sharded_service_all_or_nothing_admission():
    from repro.serving import GraphQueryRequest, ShardedQueryService
    store = _sensor(400)
    sharded, _ = _detected(store, 4, oversplit=4)
    assert sharded.plan.split_classes      # some class fans out wide
    svc = ShardedQueryService(sharded, max_pending=1)
    d = store.dict
    cid = sharded.plan.split_classes[0]
    owners = sharded.plan.shards_for_class(cid)
    assert len(owners) > 1
    fg = sharded.snapshots[owners[0]].fgraph
    t = fg.tables[int(cid)]
    mk = lambda rid: GraphQueryRequest(
        rid=rid, arms=((d.term(int(t.props[0])), None),),
        class_term=d.term(int(cid)))
    assert svc.submit(mk(0))               # fills every owner queue
    assert not svc.submit(mk(1))           # ANY full owner -> whole shed
    # no torn fan-out: rid 1 is queued on NO shard
    assert all(all(r.rid != 1 for r in s.queue) for s in svc.shards)
    assert svc.metrics.summary()["admission.shed"]["count"] >= 1
    out = svc.run()
    assert out[0].status == "ok"


def test_sharded_service_coordinator_bgp_and_deadline():
    from repro.serving import BGPQueryRequest, ShardedQueryService
    store = _sensor()
    sharded, _ = _detected(store, 2)
    d = store.dict
    cterm = "ssn:Observation"
    fg = sharded.snapshots[0].fgraph
    if not fg.tables:
        fg = sharded.snapshots[1].fgraph
    t = next(iter(fg.tables.values()))
    star = ("?s", ((d.term(int(t.props[0])), "?v"),), cterm)
    svc = ShardedQueryService(sharded)
    assert svc.submit(BGPQueryRequest(rid=9, stars=(star,)))
    assert svc.queue and not any(s.queue for s in svc.shards)
    out = svc.run()
    assert out[9].status == "ok" and out[9].n_rows > 0

    # an already-expired deadline sheds the coordinator wave
    tick = iter([0.0, 10.0, 20.0, 30.0])
    svc2 = ShardedQueryService(sharded, wave_deadline_s=0.5,
                               clock=lambda: next(tick))
    assert svc2.submit(BGPQueryRequest(rid=1, stars=(star,)))
    out2 = svc2.run()
    assert out2[1].status == "shed"
    assert svc2.metrics.summary()["wave.deadline_shed"]["count"] >= 1

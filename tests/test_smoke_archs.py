"""Per-architecture smoke tests (assignment requirement).

For every assigned arch: instantiate the REDUCED same-family config, run
one forward pass + one train step on CPU, assert output shapes and no
NaNs; then validate the serving path by checking prefill+decode logits
agree with the full forward (cache-state handoff correctness for every
mixer family: GQA ring cache, SSD state, RG-LRU state, whisper enc-dec,
VLM frontend)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch, reduced
from repro.models.blocks import Ctx
from repro.models.lm import LM
from repro.train import make_optimizer, make_train_step
from repro.train.train_step import init_train_state

ALL_ARCHS = sorted(ARCHS)
B, T = 2, 32


def _setup(name):
    cfg = reduced(get_arch(name))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ctx = Ctx(cfg=cfg)
    fe = None
    if cfg.frontend != "none":
        fd = cfg.frontend_dim or cfg.d_model
        fe = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.frontend_tokens, fd), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 1,
                                cfg.vocab_size)
    return cfg, model, params, ctx, fe, tokens


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_no_nan(name):
    cfg, model, params, ctx, fe, tokens = _setup(name)
    logits, aux = model.forward(params, tokens, ctx=ctx,
                                frontend_embeds=fe)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step(name):
    cfg, model, params, ctx, fe, tokens = _setup(name)
    opt = make_optimizer(cfg, warmup=1, total=10)
    step = jax.jit(make_train_step(model, opt, ctx=ctx))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if fe is not None:
        batch["frontend"] = fe
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg, model, params, ctx, fe, tokens = _setup(name)
    logits_full, _ = model.forward(params, tokens, ctx=ctx,
                                   frontend_embeds=fe)
    # VLM: the cache must also cover the image-token positions
    clen = T + (cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0)
    logits_pre, cache = model.prefill(params, tokens[:, :T - 1], ctx=ctx,
                                      cache_len=clen, frontend_embeds=fe)
    # prefill's last-position logits == forward at T-2
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(logits_full[:, T - 2]),
                               atol=2e-3, rtol=2e-3)
    # one decode step for token T-1; positions account for vision prefix
    pos_off = cfg.frontend_tokens if cfg.frontend == "vision_stub" else 0
    pos = jnp.full((B, 1), pos_off + T - 1, jnp.int32)
    logits_dec, _ = model.decode_step(params, tokens[:, T - 1:],
                                      cache, pos, ctx=ctx)
    np.testing.assert_allclose(np.asarray(logits_dec[:, -1]),
                               np.asarray(logits_full[:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_full_config_shapes_consistent(name):
    """FULL config param specs are well-formed (exercised without
    allocation -- the dry-run compiles them)."""
    cfg = get_arch(name)
    model = LM(cfg)
    shapes = model.input_shapes()
    n = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    approx = cfg.n_params
    assert 0.5 < n / approx < 2.0, (n, approx)

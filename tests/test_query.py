"""Star-query engine: factorized <-> raw <-> original-graph parity
(unit + hypothesis property tests), the batched device molecule match,
and the serving endpoint."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Compactor
from repro.core import sweep as core_sweep
from repro.core.triples import TripleStore
from repro.data.synthetic import SensorGraphSpec, generate
from repro.query import (QUERY_EXEC, QueryEngine, StarQuery,
                         eval_factorized, eval_raw, reset_query_stats)


def _sensor(n=300, seed=7, **kw):
    return generate(SensorGraphSpec(n_observations=n, seed=seed, **kw))


def _compact(store, **kw):
    comp = Compactor(**kw)
    comp.run(store)
    return comp


def _assert_triple_parity(fg, store, q):
    """factorized-on-G' == raw-on-expand() == raw-on-original-G."""
    bf = eval_factorized(fg, q)
    br = eval_raw(fg.expand(), q)
    b0 = eval_raw(store, q)
    assert bf.same_as(br), q
    assert br.same_as(b0), q
    return bf


# ---------------------------------------------------------------------------
# unit parity
# ---------------------------------------------------------------------------

def test_ground_arm_molecule_lookup_parity():
    store = _sensor()
    comp = _compact(store)
    fg = comp.fgraph
    for cid, t in fg.tables.items():
        for r in (0, t.n_molecules // 2, t.n_molecules - 1):
            q = StarQuery(arms=tuple(
                (p, int(o)) for p, o in zip(t.props, t.objects[r])),
                class_id=cid)
            b = _assert_triple_parity(fg, store, q)
            assert b.n_rows == fg.members(int(t.surrogates[r])).shape[0]


def test_variable_arm_and_residual_arm_parity():
    store = _sensor()
    fg = _compact(store).fgraph
    cid = store.dict.lookup("ssn:Observation")
    t = fg.tables[cid]
    row = t.objects[0]
    pr = store.dict.lookup("ssn:observationResult")   # residual (non-SP)
    queries = [
        StarQuery(arms=((t.props[0], int(row[0])), (t.props[-1], None)),
                  class_id=cid),
        StarQuery(arms=((t.props[0], None),), class_id=cid),
        StarQuery(arms=((t.props[0], int(row[0])), (pr, None)),
                  class_id=cid),
        StarQuery(arms=((t.props[0], int(row[0])),)),          # no class
        StarQuery(arms=(), class_id=cid),                       # class scan
        StarQuery(arms=((t.props[0], 10**6),), class_id=cid),   # miss
    ]
    for q in queries:
        _assert_triple_parity(fg, store, q)


def test_query_without_class_or_arm_rejected():
    fg = _compact(_sensor(80)).fgraph
    with pytest.raises(ValueError):
        eval_factorized(fg, StarQuery(arms=()))
    with pytest.raises(ValueError):
        eval_raw(fg.expand(), StarQuery(arms=()))


def test_unfactorized_class_falls_back_to_raw_triples():
    """Classes the planner skipped have no molecule table; the factorized
    strategy must still answer queries about them."""
    t = [(f"e{i}", "rdf:type", "Rare") for i in range(3)]
    t += [(f"e{i}", "p", f"u{i}") for i in range(3)]     # all distinct
    store = TripleStore.from_triples(t)
    comp = Compactor()
    comp.run(store)        # nothing factorizes (overhead case)
    fg = comp.fgraph
    assert not fg.tables
    cid = store.dict.lookup("Rare")
    p = store.dict.lookup("p")
    q = StarQuery(arms=((p, store.dict.lookup("u1")),), class_id=cid)
    b = _assert_triple_parity(fg, store, q)
    assert b.n_rows == 1


def test_multi_typed_entity_cross_class_arms():
    """An entity absorbed into TWO classes: a query about class A with an
    arm whose property lives in class B's SP must follow the instanceOf
    rewriting through B's molecule."""
    t = []
    for i in range(3):
        e = f"e{i}"
        t += [(e, "rdf:type", "A"), (e, "rdf:type", "B"),
              (e, "p1", "x"), (e, "p2", "y"),
              (e, "q1", "v"), (e, "q2", "w")]
    for i in range(3, 5):
        e = f"e{i}"
        t += [(e, "rdf:type", "B"), (e, "q1", "v"), (e, "q2", "w")]
    store = TripleStore.from_triples(t)
    comp = Compactor(min_predicted_savings=-10_000)
    comp.run(store)
    fg = comp.fgraph
    d = store.dict
    A, B = d.lookup("A"), d.lookup("B")
    assert A in fg.tables and B in fg.tables
    # class A + q1 arm (q1 in B's SP): e0..e2 answer through B molecules
    q = StarQuery(arms=((d.lookup("q1"), d.lookup("v")),), class_id=A)
    b = _assert_triple_parity(fg, store, q)
    assert b.n_rows == 3
    # class B + p1 variable arm: only the multi-typed members bind
    q2 = StarQuery(arms=((d.lookup("p1"), None),), class_id=B)
    b2 = _assert_triple_parity(fg, store, q2)
    assert b2.n_rows == 3


def test_query_parity_after_deletes():
    store = _sensor(250, seed=9)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    t = comp.fgraph.tables[cid]
    ents, objmat = store.object_matrix(cid, t.props)
    comp.delete(triples=np.asarray(
        [[int(ents[0]), t.props[0], int(objmat[0, 0])],
         [int(ents[7]), store.TYPE, cid]]))
    comp.delete(entities=np.asarray([int(ents[12])]))
    fg = comp.fgraph
    raw = fg.expand()
    row = t.objects[0]
    for q in (
            StarQuery(arms=tuple((p, int(o))
                                 for p, o in zip(t.props, row)),
                      class_id=cid),
            StarQuery(arms=((t.props[0], int(row[0])),
                            (t.props[-1], None)), class_id=cid),
            StarQuery(arms=(), class_id=cid)):
        assert eval_factorized(fg, q).same_as(eval_raw(raw, q)), q


# ---------------------------------------------------------------------------
# hypothesis: random graphs x random queries x random edits
# ---------------------------------------------------------------------------

def _random_graph(rng, n_ent, n_props, n_obj, n_cls):
    """Random small RDF graph: multi-typed entities, incomplete molecules
    (missing arms), shared and distinct object tuples."""
    triples = []
    for i in range(n_ent):
        e = f"e{i}"
        for c in range(n_cls):
            if c == 0 or rng.random() < 0.4:       # multi-typed sometimes
                triples.append((e, "rdf:type", f"C{c}"))
        for p in range(n_props):
            if rng.random() < 0.85:                # incomplete sometimes
                triples.append((e, f"p{p}", f"o{rng.integers(0, n_obj)}"))
    return TripleStore.from_triples(triples)


def _random_query(rng, store, n_props, n_obj, n_cls):
    arms = []
    n_arms = int(rng.integers(1, min(n_props, 3) + 1))
    for p in rng.choice(n_props, size=n_arms, replace=False):
        pid = store.dict.lookup(f"p{p}")
        if pid is None:
            continue
        if rng.random() < 0.35:
            arms.append((pid, None))               # variable object
        else:
            o = store.dict.lookup(f"o{rng.integers(0, n_obj + 1)}")
            if o is None:
                continue                           # miss-by-unknown-term
            arms.append((pid, o))
    cid = None
    if rng.random() < 0.7:
        cid = store.dict.lookup(f"C{rng.integers(0, n_cls)}")
    if not arms and cid is None:
        return None
    return StarQuery(arms=tuple(arms), class_id=cid)


@settings(max_examples=25, deadline=None)
@given(n_ent=st.integers(2, 14), n_props=st.integers(2, 4),
       n_obj=st.integers(1, 3), n_cls=st.integers(1, 2),
       seed=st.integers(0, 10_000), with_deletes=st.booleans())
def test_query_expand_parity_property(n_ent, n_props, n_obj, n_cls, seed,
                                      with_deletes):
    """EVERY star query answered on the FactorizedGraph equals the same
    query on expand() and on the original graph (with the same edits
    applied raw) -- including variable-object arms, multi-typed
    entities, incomplete molecules, and post-delete states."""
    rng = np.random.default_rng(seed)
    store = _random_graph(rng, n_ent, n_props, n_obj, n_cls)
    comp = Compactor(min_predicted_savings=-10**9)
    comp.run(store)
    reference = store
    if with_deletes and store.n_triples:
        k = int(rng.integers(1, min(4, store.n_triples) + 1))
        rows = store.spo[rng.choice(store.n_triples, size=k,
                                    replace=False)]
        comp.delete(triples=rows)
        keep = np.ones(store.n_triples, bool)
        for s, p, o in rows.tolist():
            keep &= ~((store.spo[:, 0] == s) & (store.spo[:, 1] == p) &
                      (store.spo[:, 2] == o))
        reference = TripleStore.from_ids(store.dict, store.spo[keep],
                                         presorted=True)
    fg = comp.fgraph
    expanded = fg.expand()
    np.testing.assert_array_equal(expanded.spo, reference.spo)
    for _ in range(6):
        q = _random_query(rng, store, n_props, n_obj, n_cls)
        if q is None:
            continue
        bf = eval_factorized(fg, q)
        br = eval_raw(expanded, q)
        b0 = eval_raw(reference, q)
        assert bf.same_as(br), (q, bf.canonical(), br.canonical())
        assert br.same_as(b0), (q, br.canonical(), b0.canonical())


# ---------------------------------------------------------------------------
# batched device path
# ---------------------------------------------------------------------------

def test_query_batch_device_matches_host():
    pytest.importorskip("jax")
    store = _sensor(400, seed=5)
    eng = QueryEngine(_compact(store).fgraph)
    fg = eng.fgraph
    queries = []
    for cid, t in fg.tables.items():
        for row in t.objects:
            queries.append(StarQuery(
                arms=tuple((p, int(o)) for p, o in zip(t.props, row)),
                class_id=cid))
        queries.append(StarQuery(          # var arm rides the same batch
            arms=((t.props[0], int(t.objects[0, 0])), (t.props[-1], None)),
            class_id=cid))
    queries.append(StarQuery(arms=((fg.tables[cid].props[0], 10**6),),
                             class_id=cid))                     # miss
    host = eng.query_batch(queries, backend="host")
    dev = eng.query_batch(queries, backend="device")
    assert len(host) == len(dev) == len(queries)
    for h, d in zip(host, dev):
        assert h.same_as(d)


def test_query_batch_one_lowering_per_chunk_no_warm_retrace():
    pytest.importorskip("jax")
    store = _sensor(300, seed=6)
    eng = QueryEngine(_compact(store).fgraph)
    fg = eng.fgraph
    cid, t = next(iter(fg.tables.items()))
    queries = [StarQuery(
        arms=tuple((p, int(o)) for p, o in zip(t.props, row)),
        class_id=cid) for row in t.objects]
    assert len(queries) <= core_sweep.MAX_SWEEP_CANDIDATES
    core_sweep.reset_trace_stats()
    reset_query_stats()
    eng.query_batch(queries, backend="device")
    assert QUERY_EXEC["lowerings"] == 1          # one class, one chunk
    cold = core_sweep.trace_count()
    eng.query_batch(queries, backend="device")
    assert QUERY_EXEC["lowerings"] == 2
    assert core_sweep.trace_count() == cold      # warm pass: zero retraces


def test_graph_query_service_endpoint():
    from repro.serving import GraphQueryRequest, GraphQueryService
    store = _sensor(200, seed=8)
    fg = _compact(store).fgraph
    cid, t = next(iter(sorted(fg.tables.items())))
    term = store.dict.term
    row = t.objects[0]
    reqs = [
        GraphQueryRequest(rid=0, arms=tuple(
            (term(p), term(int(o))) for p, o in zip(t.props, row)),
            class_term=term(cid)),
        GraphQueryRequest(rid=1, arms=((term(t.props[0]), None),),
                          class_term=term(cid)),
        GraphQueryRequest(rid=2, arms=(("no:such:prop", "x"),),
                          class_term=term(cid)),
    ]
    outs = {}
    for strategy in ("factorized", "raw"):
        svc = GraphQueryService(fg)
        for r in reqs:
            import dataclasses
            svc.submit(dataclasses.replace(r, strategy=strategy))
        outs[strategy] = svc.run()
    for rid in (0, 1, 2):
        a, b = outs["factorized"][rid], outs["raw"][rid]
        assert sorted(a.subjects) == sorted(b.subjects)
        assert sorted(a.var_objects) == sorted(b.var_objects)
    assert outs["factorized"][2].n_rows == 0          # unknown term
    assert outs["factorized"][0].n_rows > 0
    assert set(outs["factorized"][1].var_props) == {term(t.props[0])}


def test_core_reset_clears_query_exec_counters():
    """Regression: ``core.sweep.reset_trace_stats()`` must also zero the
    query-layer QUERY_EXEC counters (the query module registers its
    reset hook centrally), so per-cell bench accounting resets with ONE
    call and online soak counters never bleed across phases."""
    QUERY_EXEC["lowerings"] = 5
    QUERY_EXEC["batches"] = 3
    core_sweep.reset_trace_stats()
    assert QUERY_EXEC == {"lowerings": 0, "batches": 0}
    # the registration is idempotent: re-registering must not stack
    from repro.core.sweep import register_stats_reset
    from repro.query.batch import reset_query_stats as rqs
    register_stats_reset(rqs)
    register_stats_reset(rqs)
    from repro.core.sweep import _EXTRA_STAT_RESETS
    assert _EXTRA_STAT_RESETS.count(rqs) == 1


def test_buffer_cache_bounded_to_two_epochs():
    """Regression: the epoch-keyed device buffer cache must keep only
    the latest two epochs on rebind (a reader may hold the previous
    snapshot mid-wave; anything older is unreachable) and count what it
    evicts in the ``query.buffer_evictions`` channel."""
    pytest.importorskip("jax")
    from repro.online.metrics import MetricsHub

    store = _sensor(200, seed=9)
    fg = _compact(store).fgraph
    metrics = MetricsHub()
    eng = QueryEngine(fg, epoch=0, metrics=metrics)
    cid, t = next(iter(sorted(fg.tables.items())))
    q = StarQuery(arms=tuple(
        (p, int(o)) for p, o in zip(t.props, t.objects[0])),
        class_id=cid)
    for epoch in range(4):
        eng.rebind(fg, epoch)
        eng.query_batch([q], backend="device")    # populates (epoch, cid)
        held = {e for e, _ in eng._bufs}
        assert held <= {epoch, epoch - 1}, (epoch, held)
    assert eng.buffer_evictions >= 2
    summary = metrics.summary()["query.buffer_evictions"]
    assert summary["count"] >= 2
    # same-epoch rebind with the same fgraph is a no-op: nothing evicts
    n = eng.buffer_evictions
    eng.rebind(fg, 3)
    assert eng.buffer_evictions == n

"""GraphIndex substrate: index joins == full-graph scans, merge-on-append,
dedup skipping on provably-sorted paths, and the TermDict dtype contract."""
import numpy as np
import pytest

from repro.core import triples as triples_mod
from repro.core.index import (GraphIndex, PSO_PERM, SPO_PERM, in_sorted,
                              merge_disjoint, setdiff_rows, sort_unique)
from repro.core.triples import TermDict, TripleStore
from repro.data.synthetic import SensorGraphSpec, generate


def _random_store(seed=0, n=250):
    return generate(SensorGraphSpec(n_observations=n, seed=seed))


# ---------------------------------------------------------------------------
# index joins reproduce the seed's scan semantics exactly
# ---------------------------------------------------------------------------

def _scan_entities(store, c):
    spo = store.spo
    m = (spo[:, 1] == store.TYPE) & (spo[:, 2] == c)
    return np.unique(spo[m, 0])


def test_index_matches_scans_on_sensor_graph():
    store = _random_store(seed=7)
    spo = store.spo
    for c in store.classes().tolist():
        ents = _scan_entities(store, c)
        np.testing.assert_array_equal(store.entities_of_class(c), ents)
        m = np.isin(spo[:, 0], ents)
        props = np.unique(spo[m, 1])
        props = props[(props != store.TYPE) & (props != store.INSTANCE_OF)]
        np.testing.assert_array_equal(store.class_properties(c), props)
        assert store.labeled_edge_count(c) == \
            int((m & (spo[:, 1] != store.TYPE)).sum())
        assert store.labeled_edge_count(c, props[:2]) == \
            int((m & np.isin(spo[:, 1], props[:2])).sum())


def test_object_matrix_join_excludes_incomplete_and_nonfunctional():
    t = [("c1", "rdf:type", "C"), ("c1", "p1", "e1"), ("c1", "p2", "e2"),
         ("c2", "rdf:type", "C"), ("c2", "p1", "e1"),            # misses p2
         ("c3", "rdf:type", "C"), ("c3", "p1", "a"), ("c3", "p1", "b"),
         ("c3", "p2", "e2")]                                     # p1 x2
    store = TripleStore.from_triples(t)
    C = store.dict.lookup("C")
    p1, p2 = store.dict.lookup("p1"), store.dict.lookup("p2")
    ents, objmat = store.object_matrix(C, [p1, p2])
    assert ents.tolist() == [store.dict.lookup("c1")]
    assert objmat.tolist() == [[store.dict.lookup("e1"),
                                store.dict.lookup("e2")]]
    with pytest.raises(ValueError, match="violate"):
        store.object_matrix(C, [p1, p2], strict=True)
    # unsorted property order is preserved column-wise
    ents2, objmat2 = store.object_matrix(C, [p2, p1])
    np.testing.assert_array_equal(objmat2[:, ::-1], objmat)


def test_pred_slice_is_sorted_vertical_partition():
    store = _random_store(seed=3, n=100)
    idx = store.index
    total = 0
    for p in idx.preds.tolist():
        sl = idx.pred_slice(p)
        total += sl.shape[0]
        assert (sl[:, 1] == p).all()
        key = sl[:, 0].astype(np.int64) << 32 | sl[:, 2]
        assert (np.diff(key) > 0).all()      # strictly (s, o)-sorted
    assert total == store.n_triples
    assert idx.pred_slice(10**6).shape[0] == 0


# ---------------------------------------------------------------------------
# merge primitives + merge-on-append
# ---------------------------------------------------------------------------

def test_merge_primitives_roundtrip():
    rng = np.random.default_rng(0)
    old = sort_unique(rng.integers(0, 40, (300, 3)).astype(np.int32))
    new = rng.integers(0, 40, (120, 3)).astype(np.int32)
    fresh = setdiff_rows(sort_unique(new), old)
    merged = merge_disjoint(old, fresh)
    expect = np.unique(np.concatenate([old, new]), axis=0)
    np.testing.assert_array_equal(merged, expect)
    # PSO order variant used by the index
    old_p = sort_unique(old, PSO_PERM)
    merged_p = merge_disjoint(old_p, setdiff_rows(
        sort_unique(new, PSO_PERM), old_p, PSO_PERM), PSO_PERM)
    assert merged_p.shape == expect.shape


def test_add_ids_merges_index_and_matches_rebuild():
    store = _random_store(seed=11, n=150)
    _ = store.index                      # force build, then merge into it
    rng = np.random.default_rng(1)
    extra = rng.integers(0, 400, (500, 3)).astype(np.int32)
    expect = np.unique(np.concatenate([store.spo, extra]), axis=0)
    store.add_ids(extra)
    np.testing.assert_array_equal(store.spo, expect)
    # the merged index answers like a fresh one
    fresh = GraphIndex(store.spo, store.TYPE, store.INSTANCE_OF)
    for c in store.classes().tolist():
        np.testing.assert_array_equal(store.entities_of_class(c),
                                      fresh.entities_of_class(c))
        np.testing.assert_array_equal(store.class_properties(c),
                                      fresh.class_properties(c))
    np.testing.assert_array_equal(store.index.rows, fresh.rows)


def test_merged_index_cache_carryover_is_safe():
    store = _random_store(seed=13, n=120)
    classes = store.classes().tolist()
    for c in classes:                    # warm every cache
        store.entities_of_class(c)
        store.class_properties(c)
    c0 = classes[0]
    ent0 = int(store.entities_of_class(c0)[0])
    # append a new property edge on an entity of c0 AND a new member
    newp = store.dict.id("p/appended")
    newe = store.dict.id("ent/appended")
    store.add_ids(np.array([[ent0, newp, ent0],
                            [newe, store.TYPE, c0]], np.int32))
    assert newp in store.class_properties(c0).tolist()
    assert newe in store.entities_of_class(c0).tolist()


def test_copy_shares_index_and_diverges_on_append():
    store = _random_store(seed=2, n=80)
    _ = store.index
    clone = store.copy()
    assert clone._index is store._index
    clone.add_ids(np.array([[5, store.TYPE, 7]], np.int32))
    assert clone._index is not store._index
    assert store.n_triples == clone.n_triples - 1


# ---------------------------------------------------------------------------
# dedup skipping (satellite): provably-sorted paths never re-dedup
# ---------------------------------------------------------------------------

def test_restrict_subjects_skips_dedup_and_matches_isin(monkeypatch):
    store = _random_store(seed=5, n=100)
    subs = store.entities_of_class(store.classes()[0].item())
    expect = store.spo[np.isin(store.spo[:, 0], subs)]

    calls = []
    orig = triples_mod.sort_unique

    def counting(rows, perm=SPO_PERM):
        calls.append(rows.shape[0])
        return orig(rows, perm)

    monkeypatch.setattr(triples_mod, "sort_unique", counting)
    sub = store.restrict_subjects(subs)
    np.testing.assert_array_equal(sub.spo, expect)
    assert calls == []                   # presorted slice: no dedup pass


def test_add_ids_dedups_only_the_appended_block(monkeypatch):
    store = _random_store(seed=6, n=100)
    n_before = store.n_triples
    rows = np.concatenate([store.spo[:10],                 # duplicates
                           np.array([[9, 9, 9]], np.int32)])
    calls = []
    orig = triples_mod.sort_unique

    def counting(r, perm=SPO_PERM):
        calls.append(r.shape[0])
        return orig(r, perm)

    monkeypatch.setattr(triples_mod, "sort_unique", counting)
    store.add_ids(rows)
    assert store.n_triples == n_before + 1
    assert calls and max(calls) == rows.shape[0]   # never the full graph


# ---------------------------------------------------------------------------
# TermDict dtype contract (satellite): minted ids match spo's int32
# ---------------------------------------------------------------------------

def test_termdict_ids_dtype_matches_spo():
    d = TermDict()
    got = d.ids([f"t/{i}" for i in range(10)])
    assert got.dtype == np.int32
    store = TripleStore()
    assert store.spo.dtype == got.dtype


def test_surrogate_minting_roundtrip_through_from_ids():
    """Regression: TermDict.ids used to return int64 while spo is int32 --
    minted surrogate rows silently upcast every concatenation.  The bulk-
    minted block must flow into from_ids/add_ids without casts and
    round-trip by name."""
    store = _random_store(seed=1, n=50)
    d = store.dict
    names = [f"repro:sg/test/{i}" for i in range(7)]
    sgs = d.ids(names)
    assert sgs.dtype == store.spo.dtype == np.int32
    c0 = int(store.classes()[0])
    rows = np.stack([sgs, np.full(7, store.TYPE, np.int32),
                     np.full(7, c0, np.int32)], axis=1)
    assert rows.dtype == np.int32        # no silent upcast in the stack
    g = TripleStore.from_ids(d, np.concatenate([store.spo, rows]))
    ents = g.entities_of_class(c0)
    assert np.isin(sgs, ents).all()
    assert [g.dict.term(int(s)) for s in sgs] == names
    # second mint of the same names is a pure lookup, same ids, same dtype
    again = d.ids(names)
    assert again.dtype == np.int32
    np.testing.assert_array_equal(again, sgs)

"""Property coverage for the dist substrate beyond the seed contract:
compression round-trip error bounds on full trees, elastic mesh-shape
invariants, and plan internal consistency."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.dist import sharding as sh
from repro.dist.compression import (compress_tree, compressed,
                                    dequantize_int8, quantize_int8)
from repro.dist.elastic import choose_mesh_shape
from repro.train import adamw


# -- compression round-trip ---------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 5), cols=st.integers(1, 48),
       log_scale=st.floats(-6.0, 6.0), seed=st.integers(0, 99))
def test_int8_roundtrip_error_within_half_quantum(rows, cols, log_scale,
                                                  seed):
    """|deq - g| <= scale/2 per element, per row (tighter than the seed's
    global bound): round-to-nearest can be off by at most half a step."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((rows, cols)) * 10.0 ** log_scale,
                    jnp.float32)
    q, scale = quantize_int8(g)
    assert q.dtype == jnp.int8 and scale.shape == (rows, 1)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - g))
    bound = np.asarray(scale) / 2.0 + 1e-7 * np.asarray(scale)
    assert (err <= bound).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99), n=st.integers(1, 16))
def test_compress_tree_residual_accounts_for_all_error(seed, n):
    """decoded + residual == grads + old residual, exactly: error
    feedback loses nothing, it only defers."""
    rng = np.random.default_rng(seed)
    grads = {"a": jnp.asarray(rng.standard_normal((2, n)), jnp.float32),
             "b": {"c": jnp.asarray(rng.standard_normal(n), jnp.float32)},
             # bf16 grads: the decode->bf16 cast error must feed back too
             "d": jnp.asarray(rng.standard_normal(n), jnp.bfloat16)}
    res = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    decoded, new_res = compress_tree(grads, res)
    for g, d, r in zip(jax.tree.leaves(grads), jax.tree.leaves(decoded),
                       jax.tree.leaves(new_res)):
        assert d.dtype == g.dtype
        np.testing.assert_allclose(
            np.asarray(d, np.float32) + np.asarray(r),
            np.asarray(g, np.float32), rtol=1e-6, atol=1e-7)


def test_compressed_state_structure_is_stable_under_jit():
    """jit requires update() to return the same tree structure it was
    given -- the wrapper's {"inner", "ef"} layout must survive a step."""
    opt = compressed(adamw(0.01, weight_decay=0.0))
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    step = jax.jit(opt.update)
    g = {"w": jnp.full((4, 4), 0.1)}
    p1, s1 = step(g, state, params, jnp.int32(0))
    p2, s2 = step(g, s1, p1, jnp.int32(1))
    assert jax.tree.structure(s2) == jax.tree.structure(state)
    assert float(jnp.abs(p2["w"] - 1.0).max()) > 0


# -- elastic mesh shapes ------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 4096))
def test_choose_mesh_shape_divides_survivors(n):
    data, model = choose_mesh_shape(n)
    assert data * model == n            # every surviving chip is placed
    assert model & (model - 1) == 0     # TP degree stays a power of two
    assert 1 <= model <= 16


def test_choose_mesh_shape_rejects_empty():
    with pytest.raises(ValueError):
        choose_mesh_shape(0)


# -- plan consistency ---------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_never_double_book_a_mesh_axis(name):
    """For every arch, every parameter tensor's spec uses each mesh axis
    at most once (GSPMD rejects double-booking outright)."""
    from repro.models.common import TSpec
    from repro.models.lm import LM

    cfg = get_arch(name)
    plan = sh.make_plan(cfg, _FakeMesh((2, 16, 16),
                                       ("pod", "data", "model")))
    leaves = jax.tree.leaves(LM(cfg).param_specs(),
                             is_leaf=lambda x: isinstance(x, TSpec))
    for spec in (sh.spec_for(plan, t) for t in leaves):
        flat = [a for entry in spec if entry is not None
                for a in (entry if isinstance(entry, tuple) else (entry,))]
        assert len(flat) == len(set(flat)), spec


@settings(max_examples=40, deadline=None)
@given(batch=st.integers(1, 4096))
def test_batch_ladder_rungs_always_divide(batch):
    cfg = get_arch("qwen2-0.5b")
    plan = sh.make_plan(cfg, _FakeMesh((2, 16, 16),
                                       ("pod", "data", "model")))
    axes = sh.batch_axes_for(plan, batch)
    n = int(np.prod([plan.size(a) for a in axes])) if axes else 1
    assert batch % n == 0

"""Crash durability: WAL framing / rotation / GC, torn-tail recovery
(byte-level corruption property test), atomic snapshot checkpoints with
damaged-checkpoint fallback, full crash-point recovery parity over every
fault-injection site, and graceful query degradation (admission shed,
wave deadlines, factorized -> raw fallback)."""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import SensorGraphSpec, generate
from repro.dist.fault import SITES, FaultPlan, InjectedFault
from repro.dist.graph import ShardedFactorizedGraph
from repro.online import (DurableWAL, OnlineCompactionService,
                          SnapshotCheckpointer, recover)
from repro.online.recovery import wal_dir
from repro.online.wal import WAL_MAGIC, IngestBatch
from repro.serving import GraphQueryRequest, GraphQueryService


def _store(n=40, seed=5):
    return generate(SensorGraphSpec(n_observations=n, seed=seed))


def _batch(seq, n_ins=2, base=100):
    rng = np.random.default_rng(seq + base)
    return IngestBatch(
        seq=seq,
        inserts=rng.integers(0, 99, (n_ins, 3)).astype(np.int32),
        delete_triples=np.empty((0, 3), np.int32),
        delete_entities=np.asarray([seq * 7], np.int64))


def _novel_batches(store, n):
    """Deterministic term-level batches of complete typed entities with
    novel object tuples (each feeds support drift), every third batch
    deleting an earlier insert -- the drift-heavy shape the recovery
    sweep needs so re-detection genuinely runs."""
    term = store.dict.term
    cid = int(store.classes()[0])
    props = np.asarray(store.class_properties(cid))
    cterm, tterm = term(cid), term(store.TYPE)
    pterms = [term(int(p)) for p in props]
    out = []
    for i in range(n):
        ins = []
        for j in range(3):
            s = f"e:n/b{i}/{j}"
            ins.append((s, tterm, cterm))
            ins += [(s, p, f"o:novel/b{i}/{j}/{k}")
                    for k, p in enumerate(pterms)]
        dels = [f"e:n/b{i - 2}/0"] if i % 3 == 2 else None
        out.append((ins, dels))
    return out


_SVC_KW = dict(detector="gfsp", backend="host", raw_residue_threshold=4,
               support_drift_threshold=3, retry_sleep=lambda _: None)


# ---------------------------------------------------------------------------
# DurableWAL: framing, rotation, GC
# ---------------------------------------------------------------------------

def test_wal_roundtrip_in_write_order(tmp_path):
    wal = DurableWAL(str(tmp_path))
    wal.append_mints([(7, "ex:a"), (8, "lit:é")])   # non-ascii term
    b0, b1 = _batch(0), _batch(1, n_ins=0)
    wal.append_batch(b0)
    wal.append_applied([0])
    wal.append_batch(b1)
    wal.close()

    wal2 = DurableWAL(str(tmp_path))
    recs = list(wal2.replay())
    wal2.close()
    assert [k for k, _ in recs] == ["mint", "batch", "apply", "batch"]
    assert recs[0][1] == [(7, "ex:a"), (8, "lit:é")]
    assert recs[2][1] == [0]
    for got, want in ((recs[1][1], b0), (recs[3][1], b1)):
        assert got.seq == want.seq
        np.testing.assert_array_equal(got.inserts, want.inserts)
        np.testing.assert_array_equal(got.delete_entities,
                                      want.delete_entities)
    assert wal2.truncated_bytes == 0 and wal2.dropped_segments == 0


def test_wal_rotation_and_gc_keeps_uncovered(tmp_path):
    wal = DurableWAL(str(tmp_path), segment_max_bytes=256)
    for seq in range(10):
        wal.append_mints([(100 + seq, f"ex:m{seq}")])
        wal.append_batch(_batch(seq))
        wal.append_applied([seq])
    assert wal.n_segments > 2            # rotation actually happened

    # checkpoint covering seq <= 4 and mints < 105: covered non-active
    # segments go, everything later survives
    removed = wal.gc(applied_seq=4, n_terms=105)
    assert removed > 0
    survivors = {rec.seq for kind, rec in wal.replay() if kind == "batch"}
    assert survivors >= set(range(5, 10)), survivors
    # the prefix property: surviving seqs are a contiguous tail
    assert survivors == set(range(min(survivors), 10))
    # active segment never collected, even when fully covered
    n = wal.n_segments
    wal.gc(applied_seq=99, n_terms=10_000)
    assert wal.n_segments >= 1 and wal.nbytes() > 0
    wal.close()


def test_wal_fsync_interval_policy(tmp_path):
    clock = [0.0]
    wal = DurableWAL(str(tmp_path), fsync_policy="interval",
                     fsync_interval_s=5.0, clock=lambda: clock[0])
    wal.append_batch(_batch(0))
    clock[0] = 6.0
    wal.append_batch(_batch(1))          # interval elapsed -> fsync
    wal.close()
    wal2 = DurableWAL(str(tmp_path))
    assert sum(1 for k, _ in wal2.replay() if k == "batch") == 2
    wal2.close()
    with pytest.raises(ValueError):
        DurableWAL(str(tmp_path / "x"), fsync_policy="sometimes")


# ---------------------------------------------------------------------------
# torn-tail property: ANY byte-level truncation/corruption of the tail
# recovers to the longest valid record prefix
# ---------------------------------------------------------------------------

def _reference_journal(tmp_path):
    wal = DurableWAL(str(tmp_path))
    wal.append_mints([(50, "ex:mint")])
    for seq in range(4):
        wal.append_batch(_batch(seq))
        wal.append_applied([seq])
    wal.close()
    path = wal._segments[-1]
    with open(path, "rb") as f:
        data = f.read()
    w2 = DurableWAL(str(tmp_path))
    kinds = [k for k, _ in w2.replay()]
    w2.close()
    return path, data, kinds


@settings(max_examples=40)
@given(cut=st.integers(min_value=0, max_value=400),
       corrupt=st.booleans(), flip=st.integers(min_value=1, max_value=255))
def test_wal_torn_tail_recovers_longest_valid_prefix(tmp_path, cut,
                                                     corrupt, flip):
    sub = tmp_path / f"c{cut}_{int(corrupt)}_{flip}"
    os.makedirs(sub)
    path, data, full_kinds = _reference_journal(sub)
    cut = min(cut, len(data))
    if corrupt and cut < len(data):
        # flip one byte at ``cut``; everything before stays intact
        damaged = data[:cut] + bytes([data[cut] ^ flip]) + data[cut + 1:]
    else:
        damaged = data[:cut]             # plain truncation
    with open(path, "wb") as f:
        f.write(damaged)

    wal = DurableWAL(str(sub))
    recs = list(wal.replay())
    kinds = [k for k, _ in recs]
    # the recovered log is a PREFIX of the original record sequence
    assert kinds == full_kinds[:len(kinds)]
    if cut < len(data):
        assert wal.truncated_bytes > 0 or not corrupt
    # and the journal is append-ready again: a post-recovery write
    # survives its own reopen
    wal.append_batch(_batch(99))
    wal.close()
    wal2 = DurableWAL(str(sub))
    seqs = [rec.seq for k, rec in wal2.replay() if k == "batch"]
    wal2.close()
    assert seqs[-1] == 99 and seqs[:-1] == [
        rec.seq for k, rec in recs if k == "batch"]


def test_wal_bad_magic_drops_whole_segment_and_later_ones(tmp_path):
    wal = DurableWAL(str(tmp_path), segment_max_bytes=150)
    for seq in range(12):
        wal.append_batch(_batch(seq))
    assert wal.n_segments >= 3
    first, second = wal._segments[0], wal._segments[1]
    wal.close()
    with open(second, "r+b") as f:       # corrupt a MIDDLE segment's magic
        f.write(b"XXXXXXXX")
    wal2 = DurableWAL(str(tmp_path))
    seqs = [rec.seq for k, rec in wal2.replay() if k == "batch"]
    wal2.close()
    # prefix property across segments: only records before the damaged
    # segment survive; later segments were written later and are cut
    with open(first, "rb") as f:
        assert f.read(8) == WAL_MAGIC
    assert seqs == list(range(len(seqs)))
    assert wal2.dropped_segments >= 1


# ---------------------------------------------------------------------------
# checkpoints: atomic write, damaged-newest fallback
# ---------------------------------------------------------------------------

def _durable_service(root, store=None, **kw):
    kw = {**_SVC_KW, **kw}
    return OnlineCompactionService.durable(
        str(root), store if store is not None else _store(),
        checkpoint_every=3, checkpoint_async=False, **kw)


def test_checkpoint_roundtrip_digest_identical(tmp_path):
    svc = _durable_service(tmp_path / "root")
    seq = _novel_batches(_store(), 4)
    for ins, dels in seq:
        svc.submit(inserts=ins, delete_entities=dels)
        svc.drain()
    svc.checkpoint(wait=True)
    want = svc.snapshot.digest()
    svc.close()

    ck = SnapshotCheckpointer(str(tmp_path / "root" / "ckpt"))
    restored = ck.restore_latest()
    assert restored is not None
    assert restored.snapshot.digest() == want
    assert restored.applied_seq == svc.applied_seq
    assert restored.nbytes > 0


def test_checkpoint_damaged_newest_falls_back(tmp_path):
    svc = _durable_service(tmp_path / "root")
    seq = _novel_batches(_store(), 6)
    for ins, dels in seq:
        svc.submit(inserts=ins, delete_entities=dels)
        svc.drain()
    svc.checkpoint(wait=True)
    svc.close()
    ck = SnapshotCheckpointer(str(tmp_path / "root" / "ckpt"))
    steps = ck.steps()
    assert len(steps) >= 2
    newest = steps[-1]
    # corrupt one array of the newest checkpoint: sha1 mismatch
    victim = os.path.join(ck._step_dir(newest), "spo.npy")
    with open(victim, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        f.seek(size - 1)
        b = f.read(1)
        f.seek(size - 1)
        f.write(bytes([b[0] ^ 0xFF]))
    assert ck.validate(newest) is None
    assert ck.latest_valid() == steps[-2]
    # recovery survives: it restores the previous step and replays the
    # WAL suffix past it
    svc2 = recover(str(tmp_path / "root"), **_SVC_KW)
    svc2.drain()
    svc2.close()
    assert svc2.last_recovery.checkpoint_step == steps[-2]
    assert svc2.queue.depth == 0


def test_checkpoint_tmp_garbage_is_invisible_and_collected(tmp_path):
    ck = SnapshotCheckpointer(str(tmp_path), keep=2)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.steps() == [] and ck.latest_valid() is None


# ---------------------------------------------------------------------------
# crash-point recovery: every injection site, digest parity, exact seq
# accounting
# ---------------------------------------------------------------------------

def _reference_digest(seq):
    ref = OnlineCompactionService(_store(), **_SVC_KW)
    for ins, dels in seq:
        ref.submit(inserts=ins, delete_entities=dels)
        ref.drain()
    assert ref.queue.depth == 0
    return ref.snapshot.digest()


def _crash_run(root, seq, site, occurrence):
    """The validated sweep protocol: submit+drain each batch; on an
    injected crash, recover from disk and resubmit the interrupted
    batch once (idempotent under RDF set semantics)."""
    svc = _durable_service(root, fault_plan=FaultPlan(
        site, occurrence=occurrence))
    crashed = False
    for ins, dels in seq:
        for _ in range(2):
            try:
                svc.submit(inserts=ins, delete_entities=dels)
                svc.drain()
                break
            except InjectedFault:
                crashed = True
                svc = recover(str(root), **_SVC_KW)
        else:
            raise AssertionError(f"{site} kept crashing")
    svc.close()
    return svc, crashed


@pytest.mark.parametrize("site", SITES)
def test_crash_at_every_site_recovers_with_digest_parity(tmp_path, site):
    seq = _novel_batches(_store(), 8)
    want = _reference_digest(seq)
    svc, crashed = _crash_run(tmp_path / "root", seq, site, 0)
    assert crashed, f"fault site {site} never fired"
    assert svc.queue.depth == 0
    assert svc.snapshot.digest() == want, \
        f"recovered digest diverged after crash at {site}"

    # exact seq accounting from the journal itself: every journaled
    # batch seq is committed by exactly one surviving APPLY entry (no
    # lost writes, no double-applies)
    wal = DurableWAL(wal_dir(str(tmp_path / "root")))
    batch_seqs, applied = set(), []
    for kind, rec in wal.replay():
        if kind == "batch":
            batch_seqs.add(rec.seq)
        elif kind == "apply":
            applied.extend(rec)
    wal.close()
    # duplicates in the raw journal only ever come from recovery
    # re-journaling replayed runs; the EFFECTIVE apply sequence (first
    # occurrence each) must commit every batch exactly once, in order
    effective = list(dict.fromkeys(applied))
    assert sorted(effective) == effective
    assert set(effective) == batch_seqs
    assert svc.applied_seq == max(batch_seqs)


@settings(max_examples=6)
@given(site=st.sampled_from(SITES), occurrence=st.integers(0, 1))
def test_crash_recovery_parity_property(tmp_path, site, occurrence):
    sub = tmp_path / f"{site.replace('.', '_')}_{occurrence}"
    seq = _novel_batches(_store(), 6)
    want = _reference_digest(seq)
    svc, _ = _crash_run(sub, seq, site, occurrence)
    assert svc.queue.depth == 0
    assert svc.snapshot.digest() == want


def test_recovery_restart_of_restart(tmp_path):
    """A crash during the RECOVERED run (second fault) still converges:
    apply-run journaling dedupes already-replayed groups."""
    seq = _novel_batches(_store(), 8)
    want = _reference_digest(seq)
    root = tmp_path / "root"
    svc = _durable_service(root, fault_plan=FaultPlan("apply",
                                                      occurrence=0))
    crashes = 0
    for ins, dels in seq:
        for _ in range(3):
            try:
                svc.submit(inserts=ins, delete_entities=dels)
                svc.drain()
                break
            except InjectedFault:
                crashes += 1
                # re-arm a fresh fault on the FIRST recovery only
                plan = FaultPlan("apply", occurrence=1) \
                    if crashes == 1 else None
                svc = recover(str(root), fault_plan=plan, **_SVC_KW)
        else:
            raise AssertionError("crash loop")
    svc.close()
    assert crashes >= 2
    assert svc.queue.depth == 0
    assert svc.snapshot.digest() == want


def test_recovery_report_metrics_recorded(tmp_path):
    root = tmp_path / "root"
    seq = _novel_batches(_store(), 5)
    svc = _durable_service(root)
    for ins, dels in seq[:4]:
        svc.submit(inserts=ins, delete_entities=dels)
        svc.drain()
    # journal one more batch but do NOT apply it: it must come back
    # as the pending suffix
    svc.submit(inserts=seq[4][0], delete_entities=seq[4][1])
    svc.close()
    svc2 = recover(str(root), **_SVC_KW)
    rep = svc2.last_recovery
    assert rep is not None
    assert rep.checkpoint_bytes > 0
    assert rep.replay_ms >= 0.0
    assert rep.batches_pending >= 1         # the unapplied tail batch
    assert svc2.queue.depth >= 1
    m = svc2.metrics_summary()
    assert m["recovery.checkpoint_bytes"]["last"] == rep.checkpoint_bytes
    assert m["recovery.batches_replayed"]["last"] == rep.batches_pending
    svc2.drain()
    svc2.close()
    assert svc2.queue.depth == 0


def test_durable_reopen_without_crash_is_identity(tmp_path):
    """Clean close -> reopen restores the exact same state (epoch-level
    metadata included) with nothing pending."""
    root = tmp_path / "root"
    seq = _novel_batches(_store(), 6)
    svc = _durable_service(root)
    for ins, dels in seq:
        svc.submit(inserts=ins, delete_entities=dels)
        svc.drain()
    svc.checkpoint(wait=True)
    want, epoch = svc.snapshot.digest(), svc.snapshot.epoch
    svc.close()
    svc2 = OnlineCompactionService.durable(str(root), **_SVC_KW)
    assert svc2.snapshot.digest() == want
    assert svc2.snapshot.epoch == epoch
    assert svc2.queue.depth == 0
    assert svc2.last_recovery.batches_pending == 0
    svc2.close()


# ---------------------------------------------------------------------------
# shard-failure recovery: SIGKILL one shard's durable worker mid-soak,
# restart through recover(), swap back into the sharded graph
# ---------------------------------------------------------------------------

_SHARD_WORKER = """\
import json, sys
from repro.data.synthetic import SensorGraphSpec, generate
from repro.dist.fault import FaultPlan
from repro.dist.graph import ShardedFactorizedGraph
from repro.online import OnlineCompactionService

root, sid, batches_path = sys.argv[1], int(sys.argv[2]), sys.argv[3]
store = generate(SensorGraphSpec(n_observations=40, seed=5))
sharded = ShardedFactorizedGraph.partition(store, 3)
sub = sharded.snapshots[sid].fgraph.store
svc = OnlineCompactionService.durable(
    root, sub, checkpoint_every=3, checkpoint_async=False,
    detector="gfsp", backend="host", raw_residue_threshold=4,
    support_drift_threshold=3, retry_sleep=lambda _: None,
    fault_plan=FaultPlan("apply", occurrence=4, mode="kill"))
with open(batches_path) as f:
    batches = json.load(f)
for ins, dels in batches:
    svc.submit(inserts=[tuple(t) for t in ins], delete_entities=dels)
    svc.drain()
print("SURVIVED")          # the armed kill must preempt this line
"""


def test_shard_worker_sigkill_recovers_and_swaps_back(tmp_path):
    """One shard's durable worker dies by SIGKILL mid-soak (no atexit,
    no flush -- real process death).  The restart recovers it from its
    checkpoint + WAL, finishes the batch stream, and the recovered
    snapshot swaps back into the sharded graph with digest parity
    against a twin whose worker was never interrupted."""
    store = _store()
    sharded = ShardedFactorizedGraph.partition(store, 3)
    cid = int(store.classes()[0])
    sid = sharded.plan.shards_for_class(cid)[0]
    batches = _novel_batches(store, 8)
    bpath = tmp_path / "batches.json"
    bpath.write_text(json.dumps(batches))
    root = tmp_path / "shard_root"
    script = tmp_path / "worker.py"
    script.write_text(_SHARD_WORKER)

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    proc = subprocess.run(
        [sys.executable, str(script), str(root), str(sid), str(bpath)],
        cwd=repo, env=env, capture_output=True, timeout=300)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()
    assert b"SURVIVED" not in proc.stdout

    # uninterrupted twin over the SAME shard sub-store.  It must be the
    # first thing minting into the parent dictionary so its novel-term
    # ids line up with the ids the dead worker journaled.
    from repro.core.triples import TripleStore
    twin_sub = TripleStore.from_ids(
        store.dict, sharded.snapshots[sid].fgraph.store.spo.copy(),
        presorted=True)
    twin = OnlineCompactionService(twin_sub, **_SVC_KW)
    for ins, dels in batches:
        twin.submit(inserts=ins, delete_entities=dels)
        twin.drain()

    # restart: recover the shard from disk, apply the journaled-but-
    # unapplied tail, then resubmit what the dead worker never saw
    svc = recover(str(root), **_SVC_KW)
    assert svc.last_recovery is not None
    assert svc.last_recovery.checkpoint_bytes > 0
    svc.drain()
    applied = svc.applied_seq + 1
    assert 0 < applied < len(batches)      # it really died mid-soak
    for ins, dels in batches[applied:]:
        svc.submit(inserts=ins, delete_entities=dels)
        svc.drain()
    svc.close()
    assert svc.queue.depth == 0
    assert svc.snapshot.digest() == twin.snapshot.digest()

    # the recovered shard swaps back in: one atomic tuple store, and
    # the whole sharded graph matches the never-interrupted twin world
    other = ShardedFactorizedGraph.partition(store, 3)
    sharded.swap_shard(sid, svc.snapshot)
    other.swap_shard(sid, twin.snapshot)
    assert sharded.digest() == other.digest()


# ---------------------------------------------------------------------------
# graceful degradation of the query service
# ---------------------------------------------------------------------------

def _query_service(**kw):
    from repro.api import Compactor
    store = _store(n=80, seed=3)
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    cid, t = next(iter(sorted(fg.tables.items())))
    term = store.dict.term
    row = t.objects[0]
    arms = tuple((term(p), term(int(o))) for p, o in zip(t.props, row))
    svc = GraphQueryService(fg, **kw)
    return svc, arms, term(cid)


def test_admission_shed_on_full_queue():
    svc, arms, cterm = _query_service(max_pending=2)
    mk = lambda rid: GraphQueryRequest(rid=rid, arms=arms,
                                       class_term=cterm)
    assert svc.submit(mk(0)) and svc.submit(mk(1))
    assert not svc.submit(mk(2))         # full: shed, not queued
    assert svc.metrics.channel("admission.shed").count == 1
    out = svc.run()
    assert set(out) == {0, 1}
    assert all(r.status == "ok" for r in out.values())
    assert svc.submit(mk(3))             # wave drained: admission resumes


def test_wave_deadline_sheds_explicitly():
    tick = [0.0]

    def clock():
        tick[0] += 10.0
        return tick[0]

    svc, arms, cterm = _query_service(wave_deadline_s=5.0, clock=clock)
    for rid in range(3):
        svc.submit(GraphQueryRequest(rid=rid, arms=arms,
                                     class_term=cterm))
    out = svc.run()
    assert len(out) == 3                 # shed responses, never drops
    assert all(r.status == "shed" and r.n_rows == 0 for r in out.values())
    assert svc.metrics.channel("wave.deadline_shed").count == 3


def test_factorized_failure_falls_back_to_raw_with_parity():
    svc, arms, cterm = _query_service()
    reqs = [GraphQueryRequest(rid=rid, arms=arms, class_term=cterm)
            for rid in range(3)]
    for r in reqs:
        svc.submit(r)
    want = svc.run()

    svc2, _, _ = _query_service()

    def boom(*a, **k):
        raise RuntimeError("device lost")

    svc2.engine.query_batch = boom       # the batched path is dead
    for r in reqs:
        svc2.submit(dataclasses.replace(r))
    out = svc2.run()
    assert all(r.status == "degraded" and r.strategy == "raw"
               for r in out.values())
    ch = svc2.metrics.channel("wave.raw_fallback")
    assert ch.count == 1 and ch.total == 3      # counted, never silent
    for rid, r in out.items():
        assert r.n_rows == want[rid].n_rows
        assert sorted(r.subjects) == sorted(want[rid].subjects)


def test_bgp_fallback_marks_degraded_with_parity():
    from repro.serving import BGPQueryRequest
    svc, arms, cterm = _query_service()
    stars = (("?s", tuple((p, f"?o{i}") for i, (p, _) in
                          enumerate(arms[:2])), cterm),)
    svc.submit(BGPQueryRequest(rid=9, stars=stars))
    want = svc.run()[9]

    svc2, _, _ = _query_service()
    orig = svc2.engine.query_bgp
    tried = []

    def flaky(q, *, strategy, backend, return_stats):
        tried.append(strategy)
        if strategy != "raw":
            raise RuntimeError("kernel fault")
        return orig(q, strategy=strategy, backend="host",
                    return_stats=return_stats)

    svc2.engine.query_bgp = flaky
    svc2.submit(BGPQueryRequest(rid=9, stars=stars))
    out = svc2.run()[9]
    assert out.status == "degraded"
    assert tried == ["auto", "raw"]
    assert out.n_rows == want.n_rows
    assert sorted(out.rows) == sorted(want.rows)
    assert svc2.metrics.channel("wave.raw_fallback").count == 1

"""Chunked (flash-equivalent) attention vs the naive oracle: values and
gradients, across GQA ratios, history offsets, windows, chunk sizes --
plus hypothesis-driven random shapes."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.chunked_attention import chunked_attention, _pick_chunk
from repro.kernels.ref import mha_ref

CASES = [
    # (hq, hkv, t, s, causal, window, chunk)
    (4, 2, 64, 64, True, None, 16),
    (4, 4, 32, 96, True, None, 32),
    (6, 2, 128, 128, True, 32, 16),
    (4, 2, 17, 51, False, None, 17),
    (8, 1, 80, 80, True, 16, 16),
    (2, 2, 100, 100, True, None, 25),
]


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("hq,hkv,t,s,causal,window,chunk", CASES)
def test_matches_oracle(hq, hkv, t, s, causal, window, chunk):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v = (_rand(k1, (2, hq, t, 64)), _rand(k2, (2, hkv, s, 64)),
               _rand(k3, (2, hkv, s, 64)))
    ref = mha_ref(q, k, v, causal=causal, window=window)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    g = _rand(k4, ref.shape)
    gr = jax.grad(lambda *a: jnp.vdot(mha_ref(*a, causal=causal,
                                              window=window), g),
                  argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(lambda *a: jnp.vdot(chunked_attention(
        *a, causal=causal, window=window, chunk=chunk), g),
        argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(gr, gc, "qkv"):
        np.testing.assert_allclose(b, a, atol=3e-4, rtol=3e-4,
                                   err_msg=f"d{nm}")


@settings(max_examples=20, deadline=None)
@given(
    hkv=st.sampled_from([1, 2, 3]),
    group=st.sampled_from([1, 2, 4]),
    t=st.integers(4, 48),
    extra=st.integers(0, 32),
    causal=st.booleans(),
    chunk=st.sampled_from([8, 16, 1000]),
)
def test_property_random_shapes(hkv, group, t, extra, causal, chunk):
    s = t + extra
    key = jax.random.PRNGKey(t * 1000 + extra)
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, (1, hkv * group, t, 32))
    k = _rand(k2, (1, hkv, s, 32))
    v = _rand(k3, (1, hkv, s, 32))
    ref = mha_ref(q, k, v, causal=causal)
    out = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_pick_chunk():
    assert _pick_chunk(4096, 512) == 512
    assert _pick_chunk(1500, 512) == 500
    assert _pick_chunk(7, 512) == 7
    assert _pick_chunk(33024, 512) == 512 if 33024 % 512 == 0 else True
    assert 33024 % _pick_chunk(33024, 512) == 0


def test_bf16_dtype_preserved():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (1, 4, 32, 32)).astype(jnp.bfloat16)
    k = _rand(k2, (1, 2, 32, 32)).astype(jnp.bfloat16)
    v = _rand(k3, (1, 2, 32, 32)).astype(jnp.bfloat16)
    out = chunked_attention(q, k, v, chunk=16)
    assert out.dtype == jnp.bfloat16
    ref = mha_ref(q, k, v)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=3e-2, rtol=3e-2)


def _naive_decode(q, k, v, bias):
    sc = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    sc = sc + bias.astype(jnp.float32)[:, None, None, :]
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def test_decode_attention_matches_naive():
    from repro.kernels.chunked_attention import decode_attention
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(3), 4)
    q = _rand(k1, (2, 2, 3, 32))
    k = _rand(k2, (2, 2, 64, 32))
    v = _rand(k3, (2, 2, 64, 32))
    bias = jnp.where(jax.random.uniform(k4, (2, 64)) > 0.3, 0.0, -1e30)
    out = decode_attention(q, k, v, bias, chunk=16)
    np.testing.assert_allclose(out, _naive_decode(q, k, v, bias),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_sharded_matches_naive():
    from jax.sharding import PartitionSpec as P
    from repro.kernels.chunked_attention import decode_attention_sharded
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(4), 4)
    q = _rand(k1, (2, 2, 4, 32))
    k = _rand(k2, (2, 2, 48, 32))
    v = _rand(k3, (2, 2, 48, 32))
    bias = jnp.where(jax.random.uniform(k4, (2, 48)) > 0.5, 0.0, -1e30)
    with mesh:
        out = jax.jit(lambda *a: decode_attention_sharded(
            *a, mesh=mesh, q_spec=P(None, None, None, None),
            kv_spec=P(None, None, "model", None),
            bias_spec=P(None, "model")))(q, k, v, bias)
    np.testing.assert_allclose(out, _naive_decode(q, k, v, bias),
                               atol=2e-5, rtol=2e-5)

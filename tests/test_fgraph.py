"""FactorizedGraph: lossless expand, Def. 4.8 accounting, molecule
tables committed by the Compactor, and delete support (membership
dissolution + payoff decompaction)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api import Compactor
from repro.core import FactorizedGraph, factorize_classes, semantic_triples
from repro.core.star import num_edges
from repro.core.triples import TripleStore
from repro.data.synthetic import (SensorGraphSpec, generate,
                                  property_set_ids)


def _sensor(n=300, seed=3, **kw):
    return generate(SensorGraphSpec(n_observations=n, seed=seed, **kw))


def _compact(store, **kw):
    comp = Compactor(**kw)
    comp.run(store)
    return comp


# ---------------------------------------------------------------------------
# structure + losslessness
# ---------------------------------------------------------------------------

def test_expand_reconstructs_original_graph_exactly():
    store = _sensor(250, seed=5)
    comp = _compact(store)
    fg = comp.fgraph
    assert len(fg.tables) == 2
    fg.validate()
    np.testing.assert_array_equal(fg.expand().spo, store.spo)


def test_tables_align_with_factorization_results():
    store = _sensor(200, seed=9)
    cid, a8 = property_set_ids(store, "A8")
    g, results = factorize_classes(store, [(cid, a8)])
    fg = FactorizedGraph.from_compaction(g, results)
    t = fg.tables[cid]
    assert t.props == tuple(sorted(a8))
    assert t.n_molecules == len(results[0].surrogates)
    # sig map inverts the objects matrix
    for row, sg in zip(t.objects.tolist(), t.surrogates.tolist()):
        assert t.sig[tuple(row)] == sg
    # every entity of the class is a member of exactly one molecule
    assert int(fg.support(cid).sum()) == \
        store.entities_of_class(cid).shape[0]


def test_def48_edges_matches_detection_objective():
    store = _sensor(400, seed=11)
    comp = Compactor()
    rep = comp.run(store)
    for cid, det in rep.detections.items():
        # |S| measured from the structure (SP + residual raw props)
        # equals the detection-time |S|, so the realized Def. 4.8
        # objective is reproducible from the tables alone
        got = comp.fgraph.def48_edges(cid)
        assert got == det.edges
        t = comp.fgraph.tables[cid]
        am = int(comp.fgraph.support(cid).sum())
        assert got == num_edges(t.n_molecules, am, t.k,
                                t.k + comp.fgraph.residual_props(cid).size)


def test_members_of_vectorized_matches_scalar():
    store = _sensor(150, seed=2)
    fg = _compact(store).fgraph
    for t in fg.tables.values():
        ents, src = fg.members_of(t.surrogates)
        for r in range(t.n_molecules):
            np.testing.assert_array_equal(
                np.sort(ents[src == r]),
                np.sort(fg.members(int(t.surrogates[r]))))


def test_update_extends_molecule_tables():
    store = _sensor(200, seed=13, include_result_links=False)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    before = comp.fgraph.tables[cid].n_molecules
    up = comp.update([("obs/x", "rdf:type", "ssn:Observation"),
                      ("obs/x", "ssn:observedProperty", "phenom/NEW"),
                      ("obs/x", "ssn:procedure", "sensor/brand-new"),
                      ("obs/x", "ssn:generatedBy", "sensor/brand-new")])
    assert up.n_new_surrogates == 1
    t = comp.fgraph.tables[cid]
    assert t.n_molecules == before + 1
    comp.fgraph.validate()
    # the fresh molecule is queryable through the committed structure
    e = comp.graph.dict.lookup("obs/x")
    assert any(e in comp.fgraph.members(int(s)).tolist()
               for s in t.surrogates)


# ---------------------------------------------------------------------------
# deletes
# ---------------------------------------------------------------------------

def _delete_ref(store, rows=None, ents=None):
    """Reference semantics: the same delete applied to the raw graph."""
    spo = store.spo
    keep = np.ones(spo.shape[0], bool)
    if rows is not None:
        for s, p, o in np.asarray(rows).reshape(-1, 3).tolist():
            keep &= ~((spo[:, 0] == s) & (spo[:, 1] == p) & (spo[:, 2] == o))
    if ents is not None:
        keep &= ~np.isin(spo[:, 0], ents) & ~np.isin(spo[:, 2], ents)
    return TripleStore.from_ids(store.dict, spo[keep], presorted=True)


def test_delete_raw_residual_triple_keeps_molecules():
    store = _sensor(300, seed=4)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    # observationResult is never in the detected SP: a raw residual edge
    pr = store.dict.lookup("ssn:observationResult")
    row = store.spo[store.spo[:, 1] == pr][0]
    n_mol = comp.fgraph.tables[cid].n_molecules
    rep = comp.delete(triples=row[None, :])
    assert rep.stats.n_raw_removed == 1 and rep.stats.n_exits == 0
    assert comp.fgraph.tables[cid].n_molecules == n_mol
    np.testing.assert_array_equal(comp.fgraph.expand().spo,
                                  _delete_ref(store, rows=row[None, :]).spo)


def test_delete_molecule_arm_dissolves_membership():
    store = _sensor(200, seed=6)
    comp = _compact(store)
    fg = comp.fgraph
    cid = store.dict.lookup("ssn:Observation")
    t = fg.tables[cid]
    ents, objmat = store.object_matrix(cid, t.props)
    e0 = int(ents[0])
    arm = [e0, t.props[0], int(objmat[0, 0])]
    rep = comp.delete(triples=np.asarray(arm)[None, :])
    assert rep.stats.n_exits == 1
    fg2 = comp.fgraph
    # the entity left its molecule: no instanceOf, surviving arms raw
    assert not any(e0 in fg2.members(int(sg)).tolist()
                   for sg in fg2.surrogate_ids.tolist())
    np.testing.assert_array_equal(
        fg2.expand().spo, _delete_ref(store, rows=[arm]).spo)
    fg2.validate()


def test_delete_type_edge_of_absorbed_entity():
    store = _sensor(200, seed=8)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    t = comp.fgraph.tables[cid]
    ents, _ = store.object_matrix(cid, t.props)
    e0 = int(ents[0])
    row = [e0, store.TYPE, cid]
    comp.delete(triples=np.asarray(row)[None, :])
    np.testing.assert_array_equal(
        comp.fgraph.expand().spo, _delete_ref(store, rows=[row]).spo)


def test_delete_missing_triple_is_noop():
    store = _sensor(100, seed=10)
    comp = _compact(store)
    before = comp.graph.spo.copy()
    rep = comp.delete(triples=np.asarray([[1, 2, 3]], np.int32))
    assert rep.stats.n_raw_removed == 0 and rep.stats.n_exits == 0
    np.testing.assert_array_equal(comp.graph.spo, before)


def test_delete_storage_artifacts_rejected():
    store = _sensor(100, seed=12)
    comp = _compact(store)
    fg = comp.fgraph
    sg = int(fg.surrogate_ids[0])
    sg_row = fg.store.spo[fg.store.spo[:, 0] == sg][0]
    with pytest.raises(ValueError, match="surrogate"):
        fg.delete_triples(sg_row[None, :])
    inst = fg.store.spo[fg.store.spo[:, 1] == fg.store.INSTANCE_OF][0]
    with pytest.raises(ValueError, match="instanceOf"):
        fg.delete_triples(inst[None, :])
    with pytest.raises(ValueError, match="surrogate"):
        fg.delete_entities([sg])


def test_payoff_decompaction_below_support_two():
    """A molecule of 3 members survives one exit (support 2 still pays),
    then decompacts in place when support drops to 1."""
    t = []
    for i in range(3):
        t += [(f"e{i}", "rdf:type", "C"), (f"e{i}", "p1", "x"),
              (f"e{i}", "p2", "y"), (f"e{i}", "q", f"u{i}")]
    store = TripleStore.from_triples(t)
    C = store.dict.lookup("C")
    p1, p2 = store.dict.lookup("p1"), store.dict.lookup("p2")
    comp = Compactor(min_predicted_savings=-10_000)
    from repro.api import CompactionPlan
    comp.execute(store, CompactionPlan.explicit([(C, (p1, p2))]))
    fg = comp.fgraph
    assert fg.tables[C].n_molecules == 1
    x = store.dict.lookup("x")
    e0, e1 = store.dict.lookup("e0"), store.dict.lookup("e1")
    rep1 = comp.delete(triples=[["e0", "p1", "x"]])
    assert rep1.stats.n_exits == 1
    assert comp.fgraph.tables[C].n_molecules == 1     # support 2: stays
    rep2 = comp.delete(triples=[["e1", "p1", "x"]])
    assert comp.fgraph.tables[C].n_molecules == 0     # support 1: decompacts
    assert rep2.stats.n_molecules_removed == 1
    assert rep2.stats.n_decompacted == 1              # e2 re-materialized
    ref = _delete_ref(store, rows=[[e0, p1, x], [e1, p1, x]])
    np.testing.assert_array_equal(comp.fgraph.expand().spo, ref.spo)
    # no surrogates survive for C; e2's star is raw again
    assert not in_graph_instanceof(comp.graph)


def in_graph_instanceof(g) -> bool:
    return bool((g.spo[:, 1] == g.INSTANCE_OF).any())


def test_delete_entity_invalidates_referencing_molecules():
    """Deleting an entity that appears as a molecule *arm object*
    invalidates the molecule: members keep the surviving arms raw."""
    store = _sensor(200, seed=14)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    t = comp.fgraph.tables[cid]
    victim = int(t.objects[0, 0])          # an arm object of molecule 0
    assert victim not in comp.fgraph.surrogate_ids.tolist()
    rep = comp.delete(entities=np.asarray([victim]))
    assert rep.stats.n_molecules_removed >= 1
    ref = _delete_ref(store, ents=[victim])
    np.testing.assert_array_equal(comp.fgraph.expand().spo, ref.spo)
    comp.fgraph.validate()


def test_delete_member_entity_shrinks_support():
    store = _sensor(300, seed=16)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    fg = comp.fgraph
    # pick a molecule with >= 3 members so the payoff sweep keeps it
    sup = fg.support(cid)
    r = int(np.argmax(sup))
    assert sup[r] >= 3
    sg = int(fg.tables[cid].surrogates[r])
    e0 = int(fg.members(sg)[0])
    rep = comp.delete(entities=np.asarray([e0]))
    fg2 = comp.fgraph
    assert int(fg2.support(cid)[list(fg2.tables[cid].surrogates).index(sg)]
               if sg in fg2.tables[cid].surrogates else -1) == sup[r] - 1
    ref = _delete_ref(store, ents=[e0])
    np.testing.assert_array_equal(fg2.expand().spo, ref.spo)


def test_delete_is_transactional_on_compactor():
    store = _sensor(150, seed=18)
    comp = _compact(store)
    before = comp.graph.spo.copy()
    fg_before = comp.fgraph
    bad = np.asarray([[int(fg_before.surrogate_ids[0]), 0, 0]], np.int32)
    with pytest.raises(ValueError):
        comp.delete(triples=bad)
    assert comp.fgraph is fg_before
    np.testing.assert_array_equal(comp.graph.spo, before)


def test_semantic_triples_preserved_through_delete_and_update():
    store = _sensor(250, seed=20, include_result_links=False)
    comp = _compact(store)
    cid = store.dict.lookup("ssn:Observation")
    t = comp.fgraph.tables[cid]
    ents, objmat = store.object_matrix(cid, t.props)
    comp.delete(triples=np.asarray(
        [[int(ents[3]), t.props[0], int(objmat[3, 0])]]))
    comp.update([("obs/z", "rdf:type", "ssn:Observation"),
                 ("obs/z", "ssn:observedProperty", "phenom/Temperature"),
                 ("obs/z", "ssn:procedure", "sensor/1"),
                 ("obs/z", "ssn:generatedBy", "sensor/1")])
    # the factorized graph's semantic content equals the same edits
    # applied to the raw graph
    raw = _delete_ref(store, rows=[[int(ents[3]), t.props[0],
                                    int(objmat[3, 0])]])
    d = raw.dict
    raw.add_ids(np.asarray(
        [[d.id("obs/z"), d.id("rdf:type"), d.id("ssn:Observation")],
         [d.id("obs/z"), d.id("ssn:observedProperty"),
          d.id("phenom/Temperature")],
         [d.id("obs/z"), d.id("ssn:procedure"), d.id("sensor/1")],
         [d.id("obs/z"), d.id("ssn:generatedBy"), d.id("sensor/1")]],
        np.int32))
    a, b = semantic_triples(raw), semantic_triples(comp.graph)
    assert a.shape == b.shape and (a == b).all()


# ---------------------------------------------------------------------------
# amortized molecule-table growth (with_rows append buffer)
# ---------------------------------------------------------------------------

def _table(m=6, k=2, base=10):
    from repro.core.fgraph import MoleculeTable
    return MoleculeTable(
        class_id=1, props=(5, 7),
        surrogates=np.arange(base, base + m, dtype=np.int32),
        objects=np.arange(m * k, dtype=np.int32).reshape(m, k),
        next_ordinal=m)


def test_with_rows_amortized_chain_matches_rebuild():
    """A chain of ascending appends (the ingest hot path) lands in the
    shared growth buffer; contents, ordering and the sig index match a
    plain rebuild, and every intermediate table stays valid (its view
    covers only rows written before later appends)."""
    t = _table(m=4)
    t.sig                                   # prime: exercise transfer
    naive_s, naive_o = t.surrogates.copy(), t.objects.copy()
    frozen = []                             # (table, surr copy, obj copy)
    nxt = 100
    for b in range(6):
        s = np.arange(nxt, nxt + 3, dtype=np.int32)
        o = np.arange(nxt * 2, nxt * 2 + 6, dtype=np.int32).reshape(3, 2)
        frozen.append((t, t.surrogates.copy(), t.objects.copy()))
        t = t.with_rows(s, o, int(s[-1]) + 1)
        naive_s = np.concatenate([naive_s, s])
        naive_o = np.concatenate([naive_o, o])
        nxt += 3
    assert np.array_equal(t.surrogates, naive_s)
    assert np.array_equal(t.objects, naive_o)
    assert np.all(np.diff(t.surrogates) > 0)
    assert t.next_ordinal == int(naive_s[-1]) + 1
    # the transferred sig covers exactly the final rows
    assert len(t.sig) == t.n_molecules
    for row, sg in zip(t.objects.tolist(), t.surrogates.tolist()):
        assert t.sig[tuple(row)] == sg
    # earlier tables in the chain were not corrupted by later appends
    for old, s_copy, o_copy in frozen:
        assert np.array_equal(old.surrogates, s_copy)
        assert np.array_equal(old.objects, o_copy)
        assert len(old.sig) == old.n_molecules   # parent rebuilds lazily


def test_with_rows_branch_copies_on_write():
    """Two successors branched off one table must not share writable
    rows: the second branch falls back to a fresh buffer (used-counter
    guard), and appends continuing the first branch leave it intact."""
    base = _table(m=3)
    t1 = base.with_rows(np.asarray([50, 51], np.int32),
                        np.asarray([[1, 2], [3, 4]], np.int32), 52)
    a = t1.with_rows(np.asarray([60], np.int32),
                     np.asarray([[5, 6]], np.int32), 61)
    b = t1.with_rows(np.asarray([70, 71], np.int32),
                     np.asarray([[7, 8], [9, 10]], np.int32), 72)
    c = a.with_rows(np.asarray([80], np.int32),
                    np.asarray([[11, 12]], np.int32), 81)
    assert t1.surrogates.tolist()[-2:] == [50, 51]
    assert a.surrogates.tolist()[-1] == 60 and a.n_molecules == 6
    assert b.surrogates.tolist()[-2:] == [70, 71] and b.n_molecules == 7
    assert c.surrogates.tolist()[-1] == 80 and c.n_molecules == 7
    assert b.surrogates.tolist()[:5] == t1.surrogates.tolist()
    assert 60 not in b.surrogates.tolist()          # branches independent
    assert 70 not in c.surrogates.tolist()


def test_with_rows_non_ascending_falls_back_to_resort():
    """Surrogate-id reuse after a redetect appends BELOW the tail: the
    plain concatenate-and-resort path keeps the ascending invariant."""
    t = _table(m=3, base=20)                # surrogates 20, 21, 22
    out = t.with_rows(np.asarray([5, 40], np.int32),
                      np.asarray([[90, 91], [92, 93]], np.int32), 41)
    assert out.surrogates.tolist() == [5, 20, 21, 22, 40]
    assert out.objects[0].tolist() == [90, 91]      # rows follow the sort
    assert out.objects[-1].tolist() == [92, 93]
    assert out.sig[(90, 91)] == 5 and out.sig[(92, 93)] == 40


def test_with_rows_empty_append_refreshes_ordinal_only():
    t = _table(m=3)
    out = t.with_rows(np.empty((0,), np.int32),
                      np.empty((0, 2), np.int32), 99)
    assert out is not t and out.next_ordinal == 99
    assert np.array_equal(out.surrogates, t.surrogates)
    assert np.array_equal(out.objects, t.objects)

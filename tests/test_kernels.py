"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
executed in Pallas interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linear_scan import linear_scan
from repro.kernels.seg_count import seg_boundaries
from repro.kernels.sig_hash import sig_hash


# ---------------------------------------------------------------------------
# sig_hash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 1024, 1025, 5000])
@pytest.mark.parametrize("k", [1, 3, 8])
def test_sig_hash_matches_ref(n, k):
    rng = np.random.default_rng(n * 31 + k)
    mat = jnp.asarray(rng.integers(0, 1 << 30, (n, k)), jnp.int32)
    got = sig_hash(mat, interpret=True)
    want = ref.row_signature_ref(mat)
    assert got.dtype == jnp.uint32 and got.shape == (n, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sig_hash_distinguishes_rows():
    """Equal rows hash equal; hash-derived group count == true group count."""
    rng = np.random.default_rng(0)
    base = rng.integers(0, 50, (2000, 4)).astype(np.int32)  # many collisions
    sig = np.asarray(sig_hash(jnp.asarray(base), interpret=True))
    packed = sig[:, 0].astype(np.uint64) << np.uint64(32) | sig[:, 1]
    n_sig = len(np.unique(packed))
    n_true = len(np.unique(base, axis=0))
    assert n_sig == n_true


def test_sig_hash_order_sensitivity():
    """Row hash must depend on column order (star objects are positional)."""
    a = jnp.asarray([[1, 2]], jnp.int32)
    b = jnp.asarray([[2, 1]], jnp.int32)
    sa = np.asarray(sig_hash(a, interpret=True))
    sb = np.asarray(sig_hash(b, interpret=True))
    assert (sa != sb).any()


# ---------------------------------------------------------------------------
# seg_count
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 100, 2048, 2049, 10000])
def test_seg_boundaries_matches_ref(n):
    rng = np.random.default_rng(n)
    sig = rng.integers(0, 5, (n, 2)).astype(np.uint32)
    sig = sig[np.lexsort((sig[:, 1], sig[:, 0]))]
    sig = jnp.asarray(sig)
    bounds, count = seg_boundaries(sig, interpret=True)
    want = ref.seg_boundaries_ref(sig)
    np.testing.assert_array_equal(np.asarray(bounds), np.asarray(want))
    assert int(count) == int(want.sum())


def test_seg_boundaries_counts_groups():
    sig = jnp.asarray([[0, 0], [0, 0], [0, 1], [2, 0], [2, 0]], jnp.uint32)
    bounds, count = seg_boundaries(sig, interpret=True)
    assert bounds.tolist() == [1, 0, 1, 1, 0]
    assert int(count) == 3


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, hq, hkv, t, s, d, causal, window)
    (1, 4, 4, 128, 128, 64, True, None),          # MHA train
    (2, 8, 2, 128, 128, 64, True, None),          # GQA train
    (1, 4, 1, 64, 256, 32, True, None),           # decode-ish: T < S
    (1, 4, 2, 128, 128, 64, False, None),         # bidirectional (encoder)
    (1, 4, 2, 256, 256, 32, True, 64),            # sliding window (RG-LRU)
    (1, 2, 2, 100, 100, 48, True, None),          # ragged, non-tile-aligned
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, hq, hkv, t, s, d, causal, window = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (b, hq, t, d), dtype)
    k = jax.random.normal(k2, (b, hkv, s, d), dtype)
    v = jax.random.normal(k3, (b, hkv, s, d), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          tq=64, tkv=64, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shape_invariance():
    """Different VMEM tilings produce identical math."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(k1, (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 2, 256, 64), jnp.float32)
    a = flash_attention(q, k, v, tq=64, tkv=64, interpret=True)
    b = flash_attention(q, k, v, tq=128, tkv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 8, 16), (2, 256, 64), (3, 300, 32),
                                   (1, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_scan_matches_ref(shape, dtype):
    b, t, d = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
    x = jax.random.normal(k1, shape, dtype)
    a = jax.random.uniform(k2, shape, dtype, 0.7, 1.0)  # stable decay
    h0 = jax.random.normal(k3, (b, d), dtype)
    got_h, got_last = linear_scan(x, a, h0, tt=64, interpret=True)
    want_h, want_last = ref.linear_scan_ref(x, a, h0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got_h, np.float32),
                               np.asarray(want_h, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_last, np.float32),
                               np.asarray(want_last, np.float32),
                               rtol=tol, atol=tol)


def test_linear_scan_carries_across_blocks():
    """Pure decay (x = 0): h_T = a^T * h_0 -- exercises block-carry scratch."""
    b, t, d = 1, 512, 8
    a_val = 0.99
    x = jnp.zeros((b, t, d), jnp.float32)
    a = jnp.full((b, t, d), a_val, jnp.float32)
    h0 = jnp.ones((b, d), jnp.float32)
    _, last = linear_scan(x, a, h0, tt=128, interpret=True)
    np.testing.assert_allclose(np.asarray(last),
                               np.full((b, d), a_val ** t, np.float32),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# dispatch layer + device-side star math
# ---------------------------------------------------------------------------

def test_ops_ami_device_matches_host():
    from repro.core.star import ami as ami_host
    from repro.core.star import ami_device
    rng = np.random.default_rng(5)
    mat = rng.integers(0, 9, (3000, 3)).astype(np.int32)
    want = ami_host(mat)
    got = int(ami_device(jnp.asarray(mat)))
    assert got == want


def test_ops_multiplicities_device_matches_host():
    from repro.core.star import multiplicities, multiplicities_device
    rng = np.random.default_rng(6)
    mat = rng.integers(0, 6, (2500, 2)).astype(np.int32)
    want = multiplicities(mat)
    got = np.asarray(multiplicities_device(jnp.asarray(mat)))
    np.testing.assert_array_equal(np.sort(got), np.sort(want))
    # also positionally equal
    np.testing.assert_array_equal(got, want)


def test_ops_ami_device_with_padding_mask():
    from repro.core.star import ami_device
    rng = np.random.default_rng(8)
    mat = rng.integers(0, 4, (1000, 2)).astype(np.int32)
    valid = np.ones((1000,), bool)
    valid[800:] = False
    from repro.core.star import ami as ami_host
    want = ami_host(mat[:800])
    got = int(ami_device(jnp.asarray(mat), valid=jnp.asarray(valid)))
    assert got == want

"""Online compaction service: write-ahead queue semantics, atomic
snapshot swaps under concurrent readers, drift-tracked re-detection
(dirty classes only, fault-tolerant), metrics channels, and the
incremental == batch digest-parity property over random interleavings
of update/delete batches."""
from __future__ import annotations

import dataclasses
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Compactor, GraphSnapshot
from repro.core.fgraph import DeleteStats
from repro.data.synthetic import SensorGraphSpec, generate
from repro.online import (Channel, DriftTracker, IngestQueue, MetricsHub,
                          OnlineCompactionService)
from repro.serving import GraphQueryRequest, GraphQueryService


def _service(n=60, seed=5, **kw):
    store = generate(SensorGraphSpec(n_observations=n, seed=seed))
    kw.setdefault("detector", "gfsp")
    kw.setdefault("backend", "host")
    return store, OnlineCompactionService(store, **kw)


def _templates(store, cid):
    """(class term, type term, property terms, full object matrix) for
    minting complete entities of ``cid`` (paper §4.3 assumption (a))."""
    term = store.dict.term
    props = np.asarray(store.class_properties(cid))
    _, mat = store.object_matrix(cid, props)
    return term(cid), term(store.TYPE), [term(int(p)) for p in props], mat


def _clone_inserts(store, cid, tag, n, rng):
    """Term triples for ``n`` complete entities cloning existing rows."""
    cterm, type_term, pterms, mat = _templates(store, cid)
    term = store.dict.term
    out, names = [], []
    for j in range(n):
        row = mat[int(rng.integers(0, mat.shape[0]))]
        s = f"e:t/{tag}/{j}"
        names.append(s)
        out.append((s, type_term, cterm))
        out += [(s, p, term(int(o))) for p, o in zip(pterms, row)]
    return out, names


def _novel_inserts(store, cid, tag, n):
    """Complete entities with pairwise-distinct novel object tuples --
    each mints a fresh (support-1) surrogate, feeding support drift."""
    cterm, type_term, pterms, _ = _templates(store, cid)
    out, names = [], []
    for j in range(n):
        s = f"e:n/{tag}/{j}"
        names.append(s)
        out.append((s, type_term, cterm))
        out += [(s, p, f"o:novel/{tag}/{j}/{k}")
                for k, p in enumerate(pterms)]
    return out, names


# ---------------------------------------------------------------------------
# write-ahead queue
# ---------------------------------------------------------------------------

def test_queue_fifo_peek_and_commit_discipline():
    q = IngestQueue()
    assert not q and q.peek() is None
    a = q.append(inserts=np.zeros((1, 3), np.int32))
    b = q.append(delete_entities=np.asarray([7], np.int64))
    assert q.depth == 2 and bool(q)
    assert q.peek() is a        # peek does NOT remove: write-ahead
    assert q.peek() is a
    with pytest.raises(ValueError):
        q.mark_applied(b.seq)   # only the head can commit
    q.mark_applied(a.seq)
    assert q.peek() is b and q.depth == 1 and q.n_applied == 1
    q.mark_applied(b.seq)
    assert not q and q.n_applied == 2


def test_step_swaps_snapshot_and_preserves_old_epoch():
    store, svc = _service(60, seed=5)
    snap0 = svc.snapshot
    before = (snap0.epoch, snap0.n_triples, snap0.digest())
    ins, _ = _clone_inserts(store, store.dict.lookup("ssn:Observation"),
                            "swap", 2, np.random.default_rng(0))
    svc.submit(inserts=ins)
    rep = svc.step()
    assert rep is not None and rep.epoch_after > rep.epoch_before
    assert svc.snapshot is not snap0 and svc.queue.depth == 0
    # the old snapshot is immutable: a reader holding it is unaffected
    assert (snap0.epoch, snap0.n_triples, snap0.digest()) == before
    # and the new state equals a from-scratch compaction of the net graph
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(svc.snapshot.fgraph.expand())
    assert comp.snapshot.digest() == svc.snapshot.digest()


def test_failed_apply_leaves_head_queued_and_old_snapshot_live():
    store, svc = _service(40, seed=2)
    snap0 = svc.snapshot
    ins, _ = _clone_inserts(store, store.dict.lookup("ssn:Measurement"),
                            "boom", 1, np.random.default_rng(1))
    batch = svc.submit(inserts=ins)

    def boom(snapshot, new_triples):
        raise RuntimeError("injected apply failure")

    svc.planner.apply_update = boom
    with pytest.raises(RuntimeError, match="injected"):
        svc.step()
    # write-ahead ordering: nothing committed, nothing lost
    assert svc.snapshot is snap0
    assert svc.queue.peek() is batch and svc.queue.depth == 1
    del svc.planner.apply_update        # restore the real method
    rep = svc.step()
    assert rep is not None and rep.seq == batch.seq
    assert svc.queue.depth == 0 and svc.snapshot is not snap0


# ---------------------------------------------------------------------------
# ingest coalescing: insert runs merge into one apply
# ---------------------------------------------------------------------------

def test_queue_peek_coalesced_run_semantics():
    q = IngestQueue()
    a = q.append(inserts=np.zeros((1, 3), np.int32))
    b = q.append(inserts=np.zeros((2, 3), np.int32))
    c = q.append(inserts=np.zeros((1, 3), np.int32),
                 delete_entities=np.asarray([7], np.int64))
    d = q.append(inserts=np.zeros((1, 3), np.int32))
    run = q.peek_coalesced()
    # the delete-carrying batch TERMINATES the run (inside a batch
    # inserts apply before deletes, so it can close but never extend it)
    assert [x.seq for x in run] == [a.seq, b.seq, c.seq]
    assert q.depth == 4                      # write-ahead: nothing removed
    assert [x.seq for x in q.peek_coalesced(max_batches=2)] \
        == [a.seq, b.seq]
    q.mark_applied_through([x.seq for x in run])
    assert q.peek() is d and q.depth == 1
    with pytest.raises(ValueError):          # strict-head discipline kept
        q.mark_applied_through([d.seq + 1])


def test_coalesced_step_applies_run_in_one_apply():
    store = generate(SensorGraphSpec(n_observations=60, seed=8))
    svc = OnlineCompactionService(store, detector="gfsp", backend="host",
                                  coalesce=True)
    base = OnlineCompactionService(store, detector="gfsp", backend="host",
                                   coalesce=False)
    rng = np.random.default_rng(0)
    obs = store.dict.lookup("ssn:Observation")
    ins1, names = _clone_inserts(store, obs, "co1", 2, rng)
    ins2, _ = _novel_inserts(store, obs, "co2", 2)
    for s in (svc, base):
        s.submit(inserts=ins1)
        s.submit(inserts=ins2)
        s.submit(delete_entities=[names[0]])
    rep = svc.step()                         # ONE step: the whole run
    assert rep is not None and svc.queue.depth == 0
    assert svc.metrics.channel("ingest.coalesced_batches").last == 3
    steps = base.drain()                     # the twin pays three
    assert len(steps) == 3
    assert base.metrics.channel("ingest.coalesced_batches").max == 1
    # identical semantic state: coalescing only merges the applies
    assert np.array_equal(svc.snapshot.fgraph.expand().spo,
                          base.snapshot.fgraph.expand().spo)


def test_failed_coalesced_apply_leaves_whole_run_queued():
    store, svc = _service(40, seed=2)
    rng = np.random.default_rng(1)
    meas = store.dict.lookup("ssn:Measurement")
    b0 = svc.submit(inserts=_clone_inserts(store, meas, "c0", 1, rng)[0])
    b1 = svc.submit(inserts=_clone_inserts(store, meas, "c1", 1, rng)[0])
    snap0 = svc.snapshot

    def boom(snapshot, new_triples):
        raise RuntimeError("injected apply failure")

    svc.planner.apply_update = boom
    with pytest.raises(RuntimeError, match="injected"):
        svc.step()
    # nothing committed: the identical run reruns on the next step
    assert svc.snapshot is snap0
    assert svc.queue.peek() is b0 and svc.queue.depth == 2
    del svc.planner.apply_update
    rep = svc.step()
    assert rep is not None and rep.seq == b1.seq
    assert svc.queue.depth == 0 and svc.snapshot is not snap0


# ---------------------------------------------------------------------------
# concurrency: queries during an in-flight recompaction
# ---------------------------------------------------------------------------

def test_queries_during_inflight_redetect_serve_old_snapshot():
    """The acceptance guarantee: a query wave issued while re-detection
    is in flight is served from the OLD snapshot, digest-identical to a
    quiesced service pinned at that snapshot; the swap is one atomic
    reference flip (readers only ever observe whole snapshots); the next
    wave picks up the new epoch."""
    store, svc = _service(80, seed=7)
    snap0 = svc.snapshot
    live = GraphQueryService(svc, backend="host")

    real = svc.planner.redetect
    started, release = threading.Event(), threading.Event()

    def slow_redetect(snapshot, cids):
        out = real(snapshot, cids)      # successor fully built...
        started.set()
        assert release.wait(30)         # ...but the swap is held back
        return out

    svc.planner.redetect = slow_redetect
    seen: list[GraphSnapshot] = []
    stop = threading.Event()

    def sample():
        while not stop.is_set():
            seen.append(svc.snapshot)   # the whole consistency protocol

    sampler = threading.Thread(target=sample)
    worker = threading.Thread(
        target=svc.redetect, args=(sorted(snap0.fgraph.tables),))
    sampler.start()
    worker.start()
    try:
        assert started.wait(30)
        assert svc.snapshot is snap0    # in flight: old world still live

        term = store.dict.term
        reqs = []
        for rid, (cid, t) in enumerate(sorted(snap0.fgraph.tables.items())):
            reqs.append(GraphQueryRequest(
                rid=rid,
                arms=tuple((term(int(p)), term(int(o)))
                           for p, o in zip(t.props, t.objects[0])),
                class_term=term(cid)))
        for r in reqs:
            live.submit(r)
        mid_flight = live.run()
        assert live.engine.epoch == snap0.epoch

        quiesced_svc = GraphQueryService(snap0, backend="host")
        for r in reqs:
            quiesced_svc.submit(r)
        quiesced = quiesced_svc.run()
        for rid in quiesced:
            a, b = mid_flight[rid], quiesced[rid]
            assert sorted(a.subjects) == sorted(b.subjects), rid
            assert a.n_rows == b.n_rows, rid
    finally:
        release.set()
        worker.join(30)
        stop.set()
        sampler.join(30)

    # no torn reads: every sampled reference was a complete snapshot,
    # either the old epoch or the swapped-in successor
    final = svc.snapshot
    assert all(s is snap0 or s is final for s in seen)
    # semantics survived the pass, and the next wave tracks the swap
    assert final.digest() == snap0.digest()
    live.submit(reqs[0])
    live.run()
    assert live.engine.epoch == final.epoch


# ---------------------------------------------------------------------------
# drift-tracked re-detection
# ---------------------------------------------------------------------------

def test_redetect_considers_only_dirty_classes():
    """Support drift in ONE class re-evaluates exactly that class: the
    re-detection report names it alone, the sweep work spent on it is
    visible as an EXEC_STATS descent delta on the report (not
    wall-clock), and the clean class's molecule table survives by
    REFERENCE -- proof no detection work was redone for it."""
    store, svc = _service(60, seed=9, support_drift_threshold=4,
                          raw_residue_threshold=10**6)
    obs = store.dict.lookup("ssn:Observation")
    meas = store.dict.lookup("ssn:Measurement")
    ins, _ = _novel_inserts(store, obs, "drift", 4)   # 4 fresh surrogates
    svc.submit(inserts=ins)
    rep = svc.step()                    # step applies AND redetects
    assert rep.redetect is not None
    assert rep.redetect.considered == (obs,)          # dirty class ONLY
    assert rep.redetect.descents > 0

    # work proportional to the dirty set: re-running over the final
    # snapshot rebuilds the dirty class's table but passes the clean
    # class's through untouched (same object, zero sweeps spent on it)
    snap = svc.snapshot
    new_snap, again = svc.planner.redetect(snap, [obs])
    assert new_snap.fgraph.tables[meas] is snap.fgraph.tables[meas]
    assert new_snap.fgraph.tables[obs] is not snap.fgraph.tables[obs]


def test_clean_class_untouched_by_redetect_of_other():
    store, svc = _service(60, seed=9, support_drift_threshold=4,
                          raw_residue_threshold=10**6)
    obs = store.dict.lookup("ssn:Observation")
    meas = store.dict.lookup("ssn:Measurement")
    before = svc.snapshot.fgraph.tables[meas]
    ins, _ = _novel_inserts(store, obs, "clean", 4)
    svc.submit(inserts=ins)
    rep = svc.step()
    assert rep.redetect is not None and meas not in rep.redetect.considered
    after = svc.snapshot.fgraph.tables[meas]
    assert after.props == before.props
    assert np.array_equal(after.surrogates, before.surrogates)
    assert np.array_equal(after.objects, before.objects)


def test_redetect_retry_recovers_and_failure_keeps_state():
    store, svc = _service(40, seed=3, auto_redetect=False,
                          retry_attempts=3, retry_base_s=0.0,
                          retry_sleep=lambda s: None)
    obs = store.dict.lookup("ssn:Observation")
    real = svc.planner.redetect
    calls = []

    def flaky(snapshot, cids):
        calls.append(tuple(cids))
        if len(calls) == 1:
            raise RuntimeError("transient detection failure")
        return real(snapshot, cids)

    svc.planner.redetect = flaky
    rep = svc.redetect([obs])
    assert rep is not None and len(calls) == 2      # failed once, retried

    # exhaustion: the old snapshot stays live, the queue is untouched,
    # and the failure is visible on the metrics channel
    snap0 = svc.snapshot
    ins, _ = _clone_inserts(store, obs, "pend", 1, np.random.default_rng(4))
    svc.submit(inserts=ins)

    def always_dead(snapshot, cids):
        raise RuntimeError("permanent detection failure")

    svc.planner.redetect = always_dead
    assert svc.redetect([obs]) is None
    assert svc.snapshot is snap0 and svc.queue.depth == 1
    assert svc.metrics.channel("redetect.failures").count == 1


def test_drift_tracker_thresholds_and_rebaseline():
    store, svc = _service(40, seed=6)
    fg = svc.snapshot.fgraph
    obs = store.dict.lookup("ssn:Observation")
    tr = DriftTracker(raw_residue_threshold=10**6,
                      support_drift_threshold=3)
    tr.prime(fg)
    assert tr.dirty_classes(fg) == []

    class FakeUpdate:
        touched_classes = (obs,)
        per_class = {obs: {"new_surrogates": 2}}

    tr.observe_update(FakeUpdate())
    assert tr.dirty_classes(fg) == []               # 2 < 3: below threshold
    st_del = DeleteStats()
    st_del.note_class(obs, "exits", 1)
    tr.observe_delete(st_del)
    assert tr.dirty_classes(fg) == [obs]            # 2 + 1 crosses it
    tr.note_redetected(fg, [obs])
    assert tr.dirty_classes(fg) == []               # re-baselined


def test_drift_backoff_doubles_thresholds_and_resets():
    store, svc = _service(40, seed=6)
    fg = svc.snapshot.fgraph
    obs = store.dict.lookup("ssn:Observation")
    tr = DriftTracker(raw_residue_threshold=10**6,
                      support_drift_threshold=2, max_backoff=2)
    tr.prime(fg)

    class FakeUpdate:
        touched_classes = (obs,)
        per_class = {obs: {"new_surrogates": 2}}

    tr.observe_update(FakeUpdate())
    assert tr.dirty_classes(fg) == [obs]            # 2 >= 2
    tr.note_redetected(fg, [obs], rejected=True)
    assert tr.backoff(obs) == 1
    tr.observe_update(FakeUpdate())
    assert tr.dirty_classes(fg) == []               # needs 2*2 = 4 now
    tr.observe_update(FakeUpdate())
    assert tr.dirty_classes(fg) == [obs]            # 4 >= 4
    for _ in range(3):                              # capped at max_backoff
        tr.note_redetected(fg, [obs], rejected=True)
    assert tr.backoff(obs) == 2
    tr.note_redetected(fg, [obs])                   # accepted: reset
    assert tr.backoff(obs) == 0


def test_service_feeds_rejection_into_backoff():
    store, svc = _service(40, seed=9, auto_redetect=False)
    obs = store.dict.lookup("ssn:Observation")
    real = svc.planner.redetect

    def rejecting(snapshot, cids):
        snap, report = real(snapshot, cids)
        # force the realized-edges guard's verdict: old snapshot kept
        return snapshot, dataclasses.replace(report, rejected=True)

    svc.planner.redetect = rejecting
    assert svc.drift.backoff(obs) == 0
    svc.redetect([obs])
    svc.redetect([obs])
    assert svc.drift.backoff(obs) == 2              # two rejected passes
    svc.planner.redetect = real
    svc.redetect([obs])
    assert svc.drift.backoff(obs) == 0              # accepted pass resets


# ---------------------------------------------------------------------------
# metrics channels
# ---------------------------------------------------------------------------

def test_metrics_channel_accumulators():
    ch = Channel("x")
    for v in (3.0, 1.0, 2.0):
        ch.observe(v)
    assert ch.last == 2.0 and ch.count == 3 and ch.total == 6.0
    assert ch.min == 1.0 and ch.max == 3.0 and ch.mean == 2.0
    s = ch.summary()
    assert s["count"] == 3 and s["mean"] == 2.0 and s["last"] == 2.0

    hub = MetricsHub()
    hub.observe("b.two", 1)
    hub.observe("a.one", 5)
    hub.observe("a.one", 7)
    summ = hub.summary()
    assert list(summ) == ["a.one", "b.two"]         # sorted export
    assert summ["a.one"]["count"] == 2 and summ["a.one"]["last"] == 7


def test_service_exports_expected_channels():
    store, svc = _service(60, seed=11, support_drift_threshold=4,
                          raw_residue_threshold=10**6)
    cid = next(iter(svc.snapshot.fgraph.tables))   # a factorized class
    ins, _ = _novel_inserts(store, cid, "chan", 4)
    svc.submit(inserts=ins)
    svc.drain()
    summ = svc.metrics_summary()
    for name in ("queue.depth", "ingest.batch_ms", "swap.count",
                 "redetect.ms", "redetect.dirty_classes",
                 "fault.retries", "fault.dead_workers",
                 "ingest.unknown_deletes"):
        assert name in summ, name
    assert any(k.startswith("savings.") for k in summ)


def test_unknown_deletes_counted_not_silently_dropped():
    """A delete naming a term the dictionary has never seen cannot name
    an existing triple -- it drops as a no-op, but the drop is COUNTED
    in ``ingest.unknown_deletes`` (the regression this guards: submit
    used to discard such rows silently)."""
    store, svc = _service(60, seed=11)
    cid = next(iter(svc.snapshot.fgraph.tables))
    ins, names = _novel_inserts(store, cid, "ud", 3)
    svc.submit(inserts=ins)
    svc.drain()
    before = svc.snapshot.n_triples

    # 1 known + 2 unknown entity deletes, 1 unknown triple delete
    svc.submit(delete_entities=[names[0], "e:never/one", "e:never/two"])
    svc.submit(delete_triples=[("e:ghost", "p:ghost", "o:ghost")])
    svc.drain()
    ch = svc.metrics_summary()["ingest.unknown_deletes"]
    assert ch["total"] == 3 and ch["count"] == 2
    assert svc.snapshot.n_triples < before       # the known delete landed

    # id-level (ndarray) submissions bypass term decoding: no counting
    svc.submit(delete_entities=np.asarray([], np.int64))
    assert svc.metrics_summary()["ingest.unknown_deletes"]["count"] == 2
    svc.drain()


# ---------------------------------------------------------------------------
# incremental == batch: random interleavings (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16),
       ops=st.lists(st.tuples(st.integers(0, 2),      # reuse inserts
                              st.integers(0, 3),      # novel inserts
                              st.booleans()),         # delete earlier?
                    min_size=1, max_size=4))
def test_interleaved_edits_digest_parity(seed, ops):
    """Any interleaving of update/delete batches through the online
    service (auto re-detection on) leaves a final state expand()-digest
    identical to a single-batch from-scratch compaction of the net
    graph -- deletes drive support below payoff, so the interleavings
    exercise payoff-sweep decompaction too."""
    store, svc = _service(30, seed=4, support_drift_threshold=3,
                          raw_residue_threshold=4)
    rng = np.random.default_rng(seed)
    cids = [store.dict.lookup("ssn:Observation"),
            store.dict.lookup("ssn:Measurement")]
    inserted: list[str] = []
    for b, (n_reuse, n_novel, do_delete) in enumerate(ops):
        cid = cids[b % 2]
        ins = []
        if n_reuse:
            tri, names = _clone_inserts(store, cid, f"{seed}/{b}",
                                        n_reuse, rng)
            ins += tri
            inserted += names
        if n_novel:
            tri, names = _novel_inserts(store, cid, f"{seed}/{b}", n_novel)
            ins += tri
            inserted += names
        if ins:
            svc.submit(inserts=ins)
        if do_delete and inserted:
            k = min(len(inserted), 3)
            dels = [inserted.pop(int(rng.integers(0, len(inserted))))
                    for _ in range(k)]
            svc.submit(delete_entities=dels)
        svc.drain()
    assert svc.queue.depth == 0
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(svc.snapshot.fgraph.expand())
    assert comp.snapshot.digest() == svc.snapshot.digest()


# ---------------------------------------------------------------------------
# background recompression of the mutable tail (ROADMAP 3')
# ---------------------------------------------------------------------------

def test_background_recompression_soak_bounds_substrate():
    """A compressed-tier service whose plain tail outgrows
    ``recompress_threshold`` must re-pack off the hot path: substrate
    bytes stay bounded across 20 batches (each re-pack lands the store
    back on the compressed tier), the ``ingest.recompressions`` channel
    counts every re-pack, and the final state is digest-identical to a
    twin that never recompressed."""
    from repro.core.triples import TripleStore

    store = generate(SensorGraphSpec(n_observations=120, seed=3))
    svc = OnlineCompactionService(store.copy(), detector="gfsp",
                                  backend="host",
                                  recompress_threshold=40,
                                  retry_sleep=lambda _: None)
    twin = OnlineCompactionService(store.copy(), detector="gfsp",
                                   backend="host",
                                   retry_sleep=lambda _: None)
    rng = np.random.default_rng(0)
    cid = next(iter(svc.snapshot.fgraph.tables))
    seen, packed_bytes = 0, []
    for b in range(20):
        ins, _ = _clone_inserts(store, cid, f"rc{b}", 3, rng)
        svc.submit(inserts=ins)
        svc.drain()
        twin.submit(inserts=ins)
        twin.drain()
        cnt = svc.metrics_summary()["ingest.recompressions"]["count"]
        if cnt > seen:      # a re-pack landed this batch
            seen = cnt
            st = svc.snapshot.fgraph.store
            assert st.is_compressed
            packed_bytes.append(st.substrate_nbytes(include_dict=False))
    summ = svc.metrics_summary()
    assert summ["ingest.recompressions"]["count"] >= 2
    assert "ingest.recompress_ms" in summ
    # substrate stays bounded across the soak: every re-pack lands the
    # store back under half its plain-equivalent footprint
    st = svc.snapshot.fgraph.store
    plain_equiv = TripleStore.from_ids(
        st.dict, np.asarray(st.spo)).substrate_nbytes(include_dict=False)
    assert max(packed_bytes) < 0.5 * plain_equiv
    assert svc.snapshot.digest() == twin.snapshot.digest()
    # dict identity survived every re-pack (WAL mints depend on it)
    assert st.dict is store.dict


def test_recompression_disabled_by_default():
    """Without a threshold the service never re-packs: a compressed
    store migrates to the plain tier on first mutation and stays there
    (the pre-3' behavior, still the default)."""
    from repro.core.compress import compress_store

    store = generate(SensorGraphSpec(n_observations=60, seed=4))
    svc = OnlineCompactionService(compress_store(store.copy()),
                                  detector="gfsp", backend="host",
                                  retry_sleep=lambda _: None)
    rng = np.random.default_rng(1)
    cid = next(iter(svc.snapshot.fgraph.tables))
    ins, _ = _clone_inserts(store, cid, "norc", 3, rng)
    svc.submit(inserts=ins)
    svc.drain()
    assert not svc.snapshot.fgraph.store.is_compressed
    assert svc.metrics_summary()["ingest.recompressions"]["count"] == 0

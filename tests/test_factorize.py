"""Algorithm 3 / Def. 4.10: factorization structure, savings, overhead."""
import numpy as np

from repro.core import TripleStore, factorize, factorize_classes, gfsp
from repro.data.synthetic import (SensorGraphSpec, figure1_graph,
                                  figure7a_graph, figure7b_graph, generate,
                                  property_set_ids)


def _fig1():
    store = figure1_graph()
    C = store.dict.lookup("C")
    p = {k: store.dict.lookup(k) for k in ["p1", "p2", "p3", "p4"]}
    return store, C, p


def test_factorize_figure3c():
    """Factorizing Figure 1a over {p1,p2,p3} produces Figure 3c."""
    store, C, p = _fig1()
    res = factorize(store, C, [p["p1"], p["p2"], p["p3"]])
    g = res.graph
    assert len(res.surrogates) == 1       # one compact molecule (cM)
    sg = int(res.surrogates[0])
    # compact molecule: (cM p_i e_i) + (cM type C)
    for key in ["p1", "p2", "p3"]:
        pid = p[key]
        rows = g.spo[(g.spo[:, 0] == sg) & (g.spo[:, 1] == pid)]
        assert rows.shape[0] == 1
    assert ((g.spo[:, 0] == sg) & (g.spo[:, 1] == g.TYPE)
            & (g.spo[:, 2] == C)).any()
    # every original entity: one instanceOf edge to cM, no direct p1..p3
    for c in ["c1", "c2", "c3", "c4"]:
        cid = store.dict.lookup(c)
        inst = g.spo[(g.spo[:, 0] == cid) & (g.spo[:, 1] == g.INSTANCE_OF)]
        assert inst.shape[0] == 1 and inst[0, 2] == sg
        for key in ["p1", "p2", "p3"]:
            assert not ((g.spo[:, 0] == cid) & (g.spo[:, 1] == p[key])).any()
        # p4 edges preserved verbatim (line 19-23 of Alg. 3)
        assert ((g.spo[:, 0] == cid) & (g.spo[:, 1] == p["p4"])).any()
    # entities of G preserved in G' (Def. 4.10 bullet 1)
    assert np.isin(store.nodes(), g.nodes()).all()


def test_factorize_edge_counts_fig1():
    """G: 20 triples. G': 4 instanceOf + 1 sg-type + 3 sg-props + 4 p4 = 12
    (type edges of c1..c4 are replaced by instanceOf per Alg. 3 line 12)."""
    store, C, p = _fig1()
    res = factorize(store, C, [p["p1"], p["p2"], p["p3"]])
    assert res.n_triples_before == 20
    assert res.n_triples_after == 12
    assert res.pct_savings_triples > 0


def test_factorize_savings_fig7a():
    store = figure7a_graph()
    C = store.dict.lookup("C")
    props = [store.dict.lookup(k) for k in ["p1", "p2", "p3"]]
    res = factorize(store, C, props)
    assert res.pct_savings_nle > 0        # paper: worthy case


def test_factorize_overhead_fig7b():
    store = figure7b_graph()
    C = store.dict.lookup("C")
    props = [store.dict.lookup(k) for k in ["p1", "p2"]]
    res = factorize(store, C, props)
    assert res.pct_savings_nle < 0        # paper: overhead case (-22% flavor)


def test_factorize_sensor_graph_savings():
    """Measurement over A8 gives the paper's largest savings (>= 50% here;
    paper reports 66.56% at their scale/distribution)."""
    store = generate(SensorGraphSpec(n_observations=2000, seed=5))
    C, a8 = property_set_ids(store, "A8")
    res = factorize(store, C, a8)
    assert res.pct_savings_nle > 50.0
    # Observation over A5 also saves
    C_obs, a5 = property_set_ids(store, "A5")
    res2 = factorize(store, C_obs, a5)
    assert res2.pct_savings_nle > 25.0


def test_factorize_classes_sequential():
    store = generate(SensorGraphSpec(n_observations=500, seed=9))
    C_obs, a5 = property_set_ids(store, "A5")
    C_meas, a8 = property_set_ids(store, "A8")
    g, results = factorize_classes(store, [(C_obs, a5), (C_meas, a8)])
    assert g.n_triples < store.n_triples
    assert len(results) == 2
    assert all(r.pct_savings_nle > 0 for r in results)


def test_fsp_to_factorization_pipeline():
    """End-to-end: detect with G.FSP, factorize with its SP, sizes shrink."""
    store = generate(SensorGraphSpec(n_observations=800, seed=13))
    for cname in ["ssn:Observation", "ssn:Measurement"]:
        C = store.dict.lookup(cname)
        res = gfsp(store, C)
        f = factorize(store, C, res.props)
        assert f.pct_savings_nle > 0
        # number of surrogates equals the number of frequent star patterns
        assert len(f.surrogates) == res.ami

"""Test bootstrap: src/ on sys.path, markers, hypothesis fallback.

Putting ``src`` on ``sys.path`` here means plain ``python -m pytest``
works without the ``PYTHONPATH=src`` incantation (conftest loads before
any test module imports ``repro``).  When the real ``hypothesis``
package is missing (air-gapped runners), the vendored shim in
``tests/_vendor`` is appended instead -- the ``test`` extra in
pyproject.toml installs the real thing where the network allows.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    _VENDOR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "_vendor")
    if _VENDOR not in sys.path:
        sys.path.insert(0, _VENDOR)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: takes >90s; deselect with -m 'not slow'")

"""Serving: prefix factorization plan (the paper's #Edges objective in
bytes), engine shared-vs-flat equality (losslessness), KV pool."""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.configs import get_arch, reduced
from repro.models.lm import LM
from repro.serving import Engine, Request, plan_prefix_sharing
from repro.serving.kv_cache import KVPool
from repro.serving.prefix_factorization import expand, prefix_edges_cost


def test_plan_shares_common_prefix():
    rng = np.random.default_rng(0)
    shared = rng.integers(1, 100, (64,), dtype=np.int32)
    toks = np.stack([np.concatenate([shared,
                                     rng.integers(1, 100, (32,),
                                                  dtype=np.int32)])
                     for _ in range(16)])
    plan = plan_prefix_sharing(toks, chunk=16, kv_bytes_per_token=1024)
    assert plan.shares
    assert plan.depth_chunks == 4            # exactly the 64 shared tokens
    assert plan.molecule_tokens.shape[0] == 1
    assert plan.savings_pct > 50
    # losslessness: instanceOf expansion rebuilds the originals
    np.testing.assert_array_equal(
        expand(plan, toks[:, plan.suffix_start:]), toks)


def test_plan_declines_unique_prompts():
    """Fig. 7 overhead case: all-distinct prompts -> no sharing."""
    rng = np.random.default_rng(1)
    toks = rng.integers(1, 1000, (8, 64), dtype=np.int32)
    plan = plan_prefix_sharing(toks, chunk=16, kv_bytes_per_token=1024)
    assert not plan.shares
    assert plan.cost_shared == plan.cost_unshared


def test_plan_partial_groups():
    """Two distinct system prompts -> two molecules."""
    rng = np.random.default_rng(2)
    heads = [rng.integers(1, 100, (32,), dtype=np.int32) for _ in range(2)]
    toks = np.stack([np.concatenate([heads[i % 2],
                                     rng.integers(1, 100, (16,),
                                                  dtype=np.int32)])
                     for i in range(10)])
    plan = plan_prefix_sharing(toks, chunk=16, kv_bytes_per_token=4096)
    assert plan.shares and plan.molecule_tokens.shape[0] == 2
    assert set(plan.instance_of.tolist()) == {0, 1}


@settings(max_examples=15, deadline=None)
@given(r=st.integers(2, 10), dup=st.integers(1, 5),
       chunk=st.sampled_from([4, 8]))
def test_plan_cost_is_true_minimum(r, dup, chunk):
    """Greedy depth == exhaustive argmin over depths (Theorem 4.1 analog)."""
    rng = np.random.default_rng(r * 10 + dup)
    base = rng.integers(1, 50, (dup, 16), dtype=np.int32)
    toks = base[rng.integers(0, dup, (r,))].copy()
    toks[:, 8:] = rng.integers(1, 50, (r, 8))      # distinct tails
    plan = plan_prefix_sharing(toks, chunk=chunk, kv_bytes_per_token=512)
    costs = [prefix_edges_cost(toks, d, chunk, 512)
             for d in range(0, 16 // chunk + 1)]
    assert plan.cost_shared == pytest.approx(min(costs))


def test_engine_shared_equals_flat():
    cfg = reduced(get_arch("llama3.2-1b"), n_layers=2)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, (48,), dtype=np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, cfg.vocab_size, (16,),
                                            dtype=np.int32)])
               for _ in range(4)]
    outs = {}
    for share in (True, False):
        eng = Engine(model, params, cache_len=96, chunk=16,
                     share_prefixes=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=6))
        outs[share] = eng.run()
    assert outs[True] == outs[False]
    assert all(len(v) == 6 for v in outs[True].values())


def test_kv_pool():
    pool = KVPool(3)
    a = pool.alloc(10)
    b = pool.alloc(11)
    assert pool.occupancy() == pytest.approx(2 / 3)
    pool.free(a)
    c = pool.alloc(12)
    assert c == a                      # slot reuse (continuous batching)
    pool.alloc(13)
    with pytest.raises(RuntimeError):
        pool.alloc(14)
    assert sorted(pool.active()) == [0, 1, 2]

"""Candidate-batched sweep engine: property-based parity of
``sweep_candidates`` against per-candidate single sweeps on all three
workspaces (ragged rows/cols straddling bucket boundaries), chunking,
lowering accounting, and the level-batched E.FSP rewire."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Compactor, get_backend
from repro.core import sweep as core_sweep
from repro.core.star import ami, num_edges
from repro.core.triples import TripleStore

jax = pytest.importorskip("jax")


def _store_from_matrix(mat: np.ndarray) -> TripleStore:
    """A complete-molecule class whose object matrix is ``mat``."""
    t = []
    for i in range(mat.shape[0]):
        e = f"e{i:04d}"
        t.append((e, "rdf:type", "C"))
        for j in range(mat.shape[1]):
            t.append((e, f"p{j:02d}", f"o{int(mat[i, j])}"))
    return TripleStore.from_triples(t)


def _workspaces(store, cid):
    stats = store.class_stats(cid)
    props = tuple(int(p) for p in stats.properties)
    n_s, am = len(props), stats.n_instances
    return {name: get_backend(name).workspace(store, cid, props, n_s, am)
            for name in ("host", "device", "sharded")}, n_s, am


def _reference(matrix: np.ndarray, masks: np.ndarray, am: int, n_s: int):
    """Ground truth, one candidate at a time, straight from the parent
    matrix (column SELECTION, not column masking)."""
    edges, amis = [], []
    for mask in masks:
        cols = np.flatnonzero(mask)
        a = ami(matrix[:, cols]) if cols.size \
            else (1 if matrix.shape[0] else 0)
        amis.append(a)
        edges.append(num_edges(a, am, int(cols.size), n_s))
    return edges, amis


# rows straddle the 64/128 bucket boundary, cols the 4/8 boundary, and
# the candidate count the 2/4/8/16 ladder rungs
@settings(max_examples=8, deadline=None)
@given(n=st.integers(60, 70), k=st.integers(2, 9), c=st.integers(1, 18),
       card=st.integers(1, 5), seed=st.integers(0, 999))
def test_sweep_candidates_matches_single_sweeps(n, k, c, card, seed):
    rng = np.random.default_rng(seed)
    mat = rng.integers(0, card, (n, k)).astype(np.int32)
    store = _store_from_matrix(mat)
    cid = int(store.dict.lookup("C"))
    workspaces, n_s, am = _workspaces(store, cid)
    masks = rng.integers(0, 2, (c, k)).astype(np.int32)
    ref_edges, ref_amis = _reference(
        workspaces["host"].matrix, masks, am, n_s)
    for name, ws in workspaces.items():
        edges, amis = ws.sweep_candidates(masks)
        assert amis.tolist() == ref_amis, (name, masks)
        assert edges.tolist() == ref_edges, (name, masks)
        # batched call == per-candidate singleton calls
        for i in range(c):
            e1, a1 = ws.sweep_candidates(masks[i:i + 1])
            assert int(a1[0]) == ref_amis[i], (name, i)
            assert int(e1[0]) == ref_edges[i], (name, i)


def test_sweep_candidates_chunks_large_stacks(monkeypatch):
    """Stacks above MAX_SWEEP_CANDIDATES split into multiple lowerings of
    one descent, with results stitched back in order."""
    rng = np.random.default_rng(5)
    mat = rng.integers(0, 3, (40, 4)).astype(np.int32)
    store = _store_from_matrix(mat)
    cid = int(store.dict.lookup("C"))
    workspaces, n_s, am = _workspaces(store, cid)
    masks = rng.integers(0, 2, (10, 4)).astype(np.int32)
    ref_edges, ref_amis = _reference(
        workspaces["host"].matrix, masks, am, n_s)
    monkeypatch.setattr(core_sweep, "MAX_SWEEP_CANDIDATES", 4)
    for name in ("device", "sharded"):
        core_sweep.reset_trace_stats()
        edges, amis = workspaces[name].sweep_candidates(masks)
        assert amis.tolist() == ref_amis
        assert edges.tolist() == ref_edges
        assert core_sweep.EXEC_STATS["descents"] == 1
        assert core_sweep.EXEC_STATS["lowerings"] == 3     # ceil(10 / 4)
    core_sweep.reset_trace_stats()


def test_one_lowering_per_descent_gfsp_device():
    """The greedy descent dispatches exactly one compiled sweep per
    logical descent step on the batched backends."""
    rng = np.random.default_rng(11)
    base = rng.integers(0, 2, (6, 5)).astype(np.int32)
    mat = base[rng.integers(0, 6, (100,))]
    store = _store_from_matrix(mat)
    for backend in ("device", "sharded"):
        core_sweep.reset_trace_stats()
        Compactor(detector="gfsp", backend=backend).run(store)
        assert core_sweep.EXEC_STATS["descents"] > 0
        assert core_sweep.EXEC_STATS["lowerings"] == \
            core_sweep.EXEC_STATS["descents"]
        assert core_sweep.lowerings_per_descent() == 1.0
    core_sweep.reset_trace_stats()


@pytest.mark.parametrize("backend", ["host", "device", "sharded"])
def test_efsp_level_batched_matches_gfsp(backend):
    """The rewired E.FSP agrees with G.FSP on every backend (sensor
    graph: Theorem 4.1 holds, detectors must coincide)."""
    from repro.data.synthetic import SensorGraphSpec, generate
    store = generate(SensorGraphSpec(n_observations=200, seed=13))
    for cname in ("ssn:Observation", "ssn:Measurement"):
        cid = int(store.dict.lookup(cname))
        e = Compactor(detector="efsp", backend=backend).detect(store, cid)
        g = Compactor(detector="gfsp", backend=backend).detect(store, cid)
        assert set(e.props) == set(g.props)
        assert e.edges == g.edges
        assert e.ami == g.ami
        assert g.evaluations <= e.evaluations


def test_efsp_default_path_never_mines_gspan(monkeypatch):
    """The rewired default E.FSP must not materialize the gSpan pattern
    space; the legacy path (explicit subgraphs_dict) still works."""
    from repro.api import detectors as det_mod
    from repro.core.efsp import build_subgraphs_dict
    store = _store_from_matrix(
        np.array([[0, 1, 2], [0, 1, 2], [1, 1, 2], [1, 0, 0]], np.int32))
    cid = int(store.dict.lookup("C"))
    legacy_dict, _, _ = build_subgraphs_dict(store, cid)

    def boom(*a, **kw):
        raise AssertionError("default efsp path called gSpan")

    monkeypatch.setattr(det_mod, "build_subgraphs_dict", boom)
    d = det_mod.ExhaustiveDetector()
    res = d.detect(store, cid)                       # must not raise
    legacy = d.detect(store, cid, subgraphs_dict=legacy_dict)
    assert res.edges == legacy.edges
    assert set(res.props) == set(legacy.props)
    assert res.evaluations == legacy.evaluations


def test_efsp_min_support_keeps_legacy_threshold_semantics():
    """min_support > 1 is a gSpan mining threshold: the detector must
    route through the pattern space, not silently evaluate exactly."""
    from repro.api.detectors import ExhaustiveDetector
    from repro.core.efsp import build_subgraphs_dict
    # one tuple appears once (support 1), another three times
    mat = np.array([[0, 0], [1, 1], [1, 1], [1, 1]], np.int32)
    store = _store_from_matrix(mat)
    cid = int(store.dict.lookup("C"))
    thresholded, _, _ = build_subgraphs_dict(store, cid, min_support=2)
    want = ExhaustiveDetector().detect(
        store, cid, subgraphs_dict=thresholded)
    got = ExhaustiveDetector(min_support=2).detect(store, cid)
    assert got.edges == want.edges
    assert got.ami == want.ami == 1          # support-1 tuple not counted
    exact = ExhaustiveDetector().detect(store, cid)
    assert exact.ami == 2                    # exact scan sees both tuples


def test_efsp_streams_large_levels_in_chunks(monkeypatch):
    """Lattice levels wider than the engine chunk are sliced at the
    detector (bounded host memory), with identical results and still
    one lowering per engine call."""
    from repro.api import detectors as det_mod
    rng = np.random.default_rng(2)
    base = rng.integers(0, 2, (4, 6)).astype(np.int32)
    mat = base[rng.integers(0, 4, (80,))]
    store = _store_from_matrix(mat)
    cid = int(store.dict.lookup("C"))
    want = Compactor(detector="efsp", backend="device").detect(store, cid)
    monkeypatch.setattr(det_mod, "MAX_SWEEP_CANDIDATES", 4)
    core_sweep.reset_trace_stats()
    got = Compactor(detector="efsp", backend="device").detect(store, cid)
    assert (got.edges, got.ami, set(got.props), got.evaluations) == \
        (want.edges, want.ami, set(want.props), want.evaluations)
    # C(6,3) = 20 wide level split into ceil(20/4) slabs, 1 lowering each
    assert core_sweep.EXEC_STATS["descents"] > 5
    assert core_sweep.lowerings_per_descent() == 1.0
    core_sweep.reset_trace_stats()


def test_efsp_iterations_and_evaluations_accounting():
    """Level count and subset count match the paper's Algorithm 1 scan
    (cardinalities |S| .. 2, every combination evaluated once)."""
    from repro.data.synthetic import figure1_graph
    store = figure1_graph()
    cid = int(store.dict.lookup("C"))
    res = Compactor(detector="efsp").detect(store, cid)
    assert res.iterations == 3                       # cards 4, 3, 2
    assert res.evaluations == 1 + 4 + 6              # C(4,4)+C(4,3)+C(4,2)


def test_batched_kernel_ops_match_per_candidate():
    """(C, N, K) signature/segment ops == the 2-D ops per candidate, for
    both the Pallas kernels and the jnp references."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    rng = np.random.default_rng(3)
    stack = rng.integers(0, 4, (5, 70, 3)).astype(np.int32)
    valid = np.arange(70) < 61
    for uk in (False, True):
        sig3 = np.asarray(kops.row_signature(
            jnp.asarray(stack), valid=jnp.asarray(valid), use_kernel=uk))
        for ci in range(stack.shape[0]):
            sig2 = np.asarray(kops.row_signature(
                jnp.asarray(stack[ci]), valid=jnp.asarray(valid),
                use_kernel=uk))
            np.testing.assert_array_equal(sig3[ci], sig2)
        sorted3, _ = kops.sort_signatures(jnp.asarray(sig3))
        bounds3, counts3 = kops.seg_boundaries(sorted3, use_kernel=uk)
        assert counts3.shape == (5,)
        for ci in range(stack.shape[0]):
            sorted2, _ = kops.sort_signatures(jnp.asarray(sig3[ci]))
            bounds2, count2 = kops.seg_boundaries(sorted2, use_kernel=uk)
            np.testing.assert_array_equal(np.asarray(sorted3)[ci],
                                          np.asarray(sorted2))
            np.testing.assert_array_equal(np.asarray(bounds3)[ci],
                                          np.asarray(bounds2))
            assert int(np.asarray(counts3)[ci]) == int(count2)

"""Defs 4.5-4.8 validated against the paper's Figure-1 worked example."""
import numpy as np
import pytest

from repro.core import (TripleStore, ami, evaluate_subset, multiplicities,
                        num_edges, row_groups, star_groups)
from repro.data.synthetic import figure1_graph


@pytest.fixture()
def fig1():
    store = figure1_graph()
    d = store.dict
    C = d.lookup("C")
    p = {k: d.lookup(k) for k in ["p1", "p2", "p3", "p4"]}
    return store, C, p


def test_store_shape(fig1):
    store, C, p = fig1
    assert store.n_triples == 20          # paper: "nineteen more RDF triples"
    ents = store.entities_of_class(C)
    assert ents.shape[0] == 4
    props = store.class_properties(C)
    assert sorted(props.tolist()) == sorted(p.values())


def test_multiplicity_def45(fig1):
    """M(e1,e2,e3 | {p1,p2,p3}) = 4; M over {p4} in {2,1,1} pattern."""
    store, C, p = fig1
    _, objmat = store.object_matrix(C, [p["p1"], p["p2"], p["p3"]])
    assert (multiplicities(objmat) == 4).all()
    _, objmat4 = store.object_matrix(C, [p["p4"]])
    m = sorted(multiplicities(objmat4).tolist())
    assert m == [1, 1, 2, 2]              # e4 shared by two, e5/e6 unique


def test_ami_def47(fig1):
    """AMI({p1,p2,p3}) = 1; AMI({p4}) = 1/2+1/2+1+1 = 3."""
    store, C, p = fig1
    _, m123 = store.object_matrix(C, [p["p1"], p["p2"], p["p3"]])
    assert ami(m123) == 1
    _, m4 = store.object_matrix(C, [p["p4"]])
    assert ami(m4) == 3


def test_edges_formula_def48(fig1):
    """Figure 3: #Edges(SS={p1..p4}) = 15, #Edges(SS'={p1,p2,p3}) = 8."""
    store, C, p = fig1
    all4 = [p["p1"], p["p2"], p["p3"], p["p4"]]
    r = evaluate_subset(store, C, all4, n_total_props=4)
    assert (r.ami, r.edges) == (3, 15)
    r = evaluate_subset(store, C, [p["p1"], p["p2"], p["p3"]], n_total_props=4)
    assert (r.ami, r.edges) == (1, 8)
    # the formula directly
    assert num_edges(3, 4, 4, 4) == 15
    assert num_edges(1, 4, 3, 4) == 8


def test_star_groups(fig1):
    store, C, p = fig1
    groups = star_groups(store, C, [p["p1"], p["p2"], p["p3"]])
    assert len(groups) == 1
    members, objs = groups[0]
    assert members.shape[0] == 4
    assert objs.shape[0] == 3


def test_row_groups_basic():
    mat = np.array([[1, 2], [1, 2], [3, 4], [1, 2], [3, 5]], np.int32)
    inv, counts, rep = row_groups(mat)
    assert counts.sum() == 5
    assert sorted(counts.tolist()) == [1, 1, 3]
    # inverse maps rows to their group
    for i in range(5):
        assert (mat[rep[inv[i]]] == mat[i]).all()


def test_incomplete_molecules_excluded():
    """Assumption (a) of §4.3: entities missing a property value are
    excluded from the candidate set (validated, not assumed)."""
    t = [("c1", "rdf:type", "C"), ("c1", "p1", "e1"), ("c1", "p2", "e2"),
         ("c2", "rdf:type", "C"), ("c2", "p1", "e1")]  # c2 misses p2
    store = TripleStore.from_triples(t)
    C = store.dict.lookup("C")
    p1, p2 = store.dict.lookup("p1"), store.dict.lookup("p2")
    ents, objmat = store.object_matrix(C, [p1, p2])
    assert ents.shape[0] == 1
    with pytest.raises(ValueError):
        store.object_matrix(C, [p1, p2], strict=True)


def test_nonfunctional_property_excluded():
    """Assumption (b): multi-valued properties disqualify the entity."""
    t = [("c1", "rdf:type", "C"), ("c1", "p1", "e1"), ("c1", "p1", "e9"),
         ("c2", "rdf:type", "C"), ("c2", "p1", "e1")]
    store = TripleStore.from_triples(t)
    C = store.dict.lookup("C")
    p1 = store.dict.lookup("p1")
    ents, _ = store.object_matrix(C, [p1])
    assert ents.shape[0] == 1

"""Unified ``repro.api`` pipeline: backend parity, auto-planning,
transactional execution, overlapping classes, incremental updates, and
the deprecated free-function shims."""
import numpy as np
import pytest

from repro.api import (CompactionPlan, Compactor, get_backend, get_detector,
                       register_detector)
from repro.core import semantic_triples
from repro.core.factorize import factorize_classes
from repro.core.triples import TermDict, TripleStore
from repro.data.synthetic import (SensorGraphSpec, figure1_graph,
                                  figure7b_graph, generate,
                                  property_set_ids)


def _sensor(n=400, seed=3, **kw):
    return generate(SensorGraphSpec(n_observations=n, seed=seed, **kw))


# ---------------------------------------------------------------------------
# backend parity (acceptance criterion)
# ---------------------------------------------------------------------------

def test_backend_parity_on_sensor_graph():
    """host / device / sharded produce identical props, edges, savings AND
    evaluation counts through the same Compactor pipeline."""
    pytest.importorskip("jax")
    store = _sensor(500, seed=21)
    reports = {be: Compactor(detector="gfsp", backend=be).run(store)
               for be in ("host", "device", "sharded")}
    ref = reports["host"]
    assert len(ref.plan) == 2            # Observation + Measurement
    for be, rep in reports.items():
        assert rep.n_triples_after == ref.n_triples_after, be
        assert rep.pct_savings_triples == ref.pct_savings_triples, be
        for cid, det in ref.detections.items():
            other = rep.detections[cid]
            assert set(other.props) == set(det.props), be
            assert other.edges == det.edges, be
            assert other.evaluations == det.evaluations, be


_MESH_PARITY = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import numpy as np, jax
sys.path.insert(0, "src")
from repro.api import Compactor, ShardedBackend
from repro.data.synthetic import SensorGraphSpec, generate
from repro.launch.mesh import make_mesh_compat

store = generate(SensorGraphSpec(n_observations=403, seed=2))
cid = store.dict.lookup("ssn:Observation")
host = Compactor(detector="gfsp", backend="host").detect(store, cid)
mesh = make_mesh_compat((4, 2), ("data", "model"))
be = ShardedBackend(mesh=mesh)
assert be.plan.dp_axes == ("data",), be.plan.dp_axes   # tp axis excluded
sh = Compactor(detector="gfsp", backend=be).detect(store, cid)
print(json.dumps([sorted(host.props), host.edges, host.evaluations,
                  sorted(sh.props), sh.edges, sh.evaluations]))
'''


def test_sharded_backend_real_mesh_parity():
    """Detection on a real 4x2 (data, model) mesh == host result.

    Regression: the implicit GSPMD lowering of the sort-based sweep
    miscounts distinct rows on multi-axis meshes (latent in the seed's
    gfsp_distributed, which only ever ran with mesh=None); the sharded
    backend must use the explicit ami_bucketed collective schedule."""
    import json
    import subprocess
    import sys
    r = subprocess.run([sys.executable, "-c", _MESH_PARITY],
                       capture_output=True, text=True, timeout=600,
                       cwd=str(__import__("pathlib").Path(__file__).parent.parent))
    assert r.returncode == 0, r.stderr[-1500:]
    hp, he, hev, sp, se, sev = json.loads(r.stdout.strip().splitlines()[-1])
    assert hp == sp and he == se and hev == sev


def test_evaluation_count_parity_early_single_pattern():
    """Seed bug: the host loop broke early on an AMI == 1 child (charging
    fewer evaluations than the device sweep's len(SP)).  Counts now agree
    even when the single-pattern child is the FIRST candidate."""
    pytest.importorskip("jax")
    # dropping property a (lowest id -> first candidate) leaves {b, c}
    # shared by all entities: AMI == 1 on the first child of sweep 1
    t = []
    for i in range(4):
        e = f"e{i}"
        t += [(e, "a", f"u{i}"), (e, "b", "y"), (e, "c", "z"),
              (e, "rdf:type", "C")]
    store = TripleStore.from_triples(t)
    C = store.dict.lookup("C")
    host = Compactor(detector="gfsp", backend="host").detect(store, C)
    dev = Compactor(detector="gfsp", backend="device").detect(store, C)
    assert host.ami == 1 and set(host.props) == set(dev.props)
    # 1 (initial S) + 3 (full first sweep, no early break) = 4
    assert host.evaluations == dev.evaluations == 4


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_resolution_and_errors():
    assert get_backend("host").name == "host"
    b = get_backend("device", use_kernel=False)
    assert b.use_kernel is False
    assert get_backend(b) is b           # instances pass through
    with pytest.raises(KeyError, match="unknown execution backend"):
        get_backend("tpu-v9")
    with pytest.raises(KeyError, match="unknown detector"):
        get_detector("magic")
    with pytest.raises(TypeError):
        get_backend(42)


def test_register_custom_detector():
    class Fixed:
        name = "fixed"

        def __init__(self, props=()):
            self.props = props

        def detect(self, store, class_id, *, backend=None, props=None):
            from repro.api.backends import HostBackend
            from repro.api.detectors import _result
            import time
            t0 = time.perf_counter()
            stats = store.class_stats(class_id)
            best = HostBackend().evaluate(
                store, class_id, tuple(self.props),
                int(stats.properties.shape[0]), stats.n_instances)
            return _result(store, class_id, best, stats.n_instances, 1, 1, t0)

    register_detector("fixed", Fixed)
    store = figure1_graph()
    C = store.dict.lookup("C")
    p1, p2 = store.dict.lookup("p1"), store.dict.lookup("p2")
    res = Compactor(detector="fixed",
                    detector_opts={"props": (p1, p2)}).detect(store, C)
    assert set(res.props) == {p1, p2}


def test_gspan_baseline_agrees_with_efsp():
    store = figure1_graph()
    C = store.dict.lookup("C")
    e = Compactor(detector="efsp").detect(store, C)
    g = Compactor(detector="gspan").detect(store, C)
    assert set(g.props) == set(e.props)
    assert g.edges == e.edges
    # gspan scores only mined subsets; efsp scans every combination
    assert g.evaluations <= e.evaluations


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def test_planner_ranks_classes_by_predicted_savings():
    store = _sensor(600, seed=8, n_sensors=10)
    plan = Compactor().plan(store)
    assert len(plan) == 2
    savings = [e.predicted_savings for e in plan]
    assert savings == sorted(savings, reverse=True)
    assert all(s > 0 for s in savings)
    by_class = {store.dict.term(e.class_id): e for e in plan}
    _, a5 = property_set_ids(store, "A5")
    _, a8 = property_set_ids(store, "A8")
    assert set(by_class["ssn:Observation"].props) == set(a5)
    assert set(by_class["ssn:Measurement"].props) == set(a8)


def test_planner_skips_overhead_class():
    """Fig. 7b: every entity its own pattern -> factorization only adds
    edges; the planner must refuse to execute it."""
    store = figure7b_graph()
    comp = Compactor()
    plan = comp.plan(store)
    assert len(plan) == 0
    report = comp.run(store)
    assert report.graph.n_triples == store.n_triples
    assert report.pct_savings_triples == 0.0


def test_explicit_plan_keeps_order_and_matches_core():
    store = _sensor(300, seed=4)
    cid, a8 = property_set_ids(store, "A8")
    rep = Compactor().execute(store,
                              CompactionPlan.explicit([(cid, a8)]))
    assert len(rep.factorizations) == 1
    res = rep.factorizations[0]
    from repro.core.factorize import _factorize
    ref = _factorize(store, cid, a8)
    assert res.nle_before == ref.nle_before
    assert res.nle_after == ref.nle_after
    assert res.pct_savings_nle == ref.pct_savings_nle


def test_execute_is_transactional_input_untouched():
    store = _sensor(200, seed=6)
    before = store.spo.copy()
    rep = Compactor().run(store)
    assert rep.n_triples_after < rep.n_triples_before
    np.testing.assert_array_equal(store.spo, before)


# ---------------------------------------------------------------------------
# overlapping classes (satellite: factorize_classes coverage)
# ---------------------------------------------------------------------------

def _overlap_graph():
    """e0..e2 are BOTH Observation-like (A) and Measurement-like (B);
    e3, e4 are B only.  A-props p1/p2 shared, B-props q1/q2 shared."""
    t = []
    for i in range(3):
        e = f"e{i}"
        t += [(e, "rdf:type", "A"), (e, "rdf:type", "B"),
              (e, "p1", "x"), (e, "p2", "y"),
              (e, "q1", "v"), (e, "q2", "w")]
    for i in range(3, 5):
        e = f"e{i}"
        t += [(e, "rdf:type", "B"), (e, "q1", "v"), (e, "q2", "w")]
    return TripleStore.from_triples(t)


def test_factorize_classes_overlapping_entities_lossless():
    store = _overlap_graph()
    A, B = store.dict.lookup("A"), store.dict.lookup("B")
    pa = [store.dict.lookup(k) for k in ("p1", "p2")]
    pb = [store.dict.lookup(k) for k in ("q1", "q2")]
    g, results = factorize_classes(store, [(A, pa), (B, pb)])
    assert len(results) == 2
    # class A factorization absorbed e0..e2 (one shared star pattern);
    # their type-B edges stay raw (only the class under factorization
    # moves to the surrogate), so B then factorizes ALL five entities
    assert len(results[0].surrogates) == 1
    assert len(results[1].surrogates) == 1
    # overlapping entities carry one instanceOf pointer per class
    e0 = store.dict.lookup("e0")
    inst = g.spo[(g.spo[:, 0] == e0) & (g.spo[:, 1] == g.INSTANCE_OF)]
    assert inst.shape[0] == 2
    a = semantic_triples(store)
    b = semantic_triples(g)
    assert a.shape == b.shape and (a == b).all()


def test_compactor_run_overlapping_classes_lossless():
    store = _overlap_graph()
    rep = Compactor(min_predicted_savings=-10_000).run(store)
    a = semantic_triples(store)
    b = semantic_triples(rep.graph)
    assert a.shape == b.shape and (a == b).all()


# ---------------------------------------------------------------------------
# incremental updates
# ---------------------------------------------------------------------------

def _obs_triples(name, phenom="Temperature", sensor="sensor/1", t="time/9"):
    return [(name, "rdf:type", "ssn:Observation"),
            (name, "ssn:observedProperty", f"phenom/{phenom}"),
            (name, "ssn:procedure", sensor),
            (name, "ssn:generatedBy", sensor)]


def test_update_requires_prior_run():
    with pytest.raises(RuntimeError):
        Compactor().update([])


def test_update_reuses_existing_surrogate():
    store = _sensor(400, seed=9, include_result_links=False, n_sensors=10)
    comp = Compactor()
    rep = comp.run(store)
    n_before = comp.graph.n_triples
    # clone an existing observation's detected-SP tuple -> link, not mint
    obs_cid = store.dict.lookup("ssn:Observation")
    sp = sorted(rep.detections[obs_cid].props)
    ents, objmat = store.object_matrix(obs_cid, sp)
    row = {p: int(o) for p, o in zip(sp, objmat[0])}
    term = store.dict.term
    up = comp.update(
        [("obs/clone", "rdf:type", "ssn:Observation")] +
        [("obs/clone", term(p), term(o)) for p, o in row.items()])
    assert up.n_entities_absorbed == 1
    assert up.n_new_surrogates == 0
    assert up.n_surrogates_reused == 1
    # absorbed entity carries ONE instanceOf edge and no direct SP edges
    g = comp.graph
    e = g.dict.lookup("obs/clone")
    mine = g.spo[g.spo[:, 0] == e]
    assert mine.shape[0] == 1 and mine[0, 1] == g.INSTANCE_OF
    # the only new triple in G' is that pointer edge
    assert g.n_triples == n_before + 1


def test_update_novel_pattern_mints_then_reuses():
    store = _sensor(300, seed=12, include_result_links=False)
    comp = Compactor()
    comp.run(store)
    novel = _obs_triples("obs/n0", sensor="sensor/brand-new") + \
        [("obs/n0", "ssn:samplingTime", "time/0")]
    up1 = comp.update(novel)
    assert up1.n_new_surrogates == 1 and up1.n_surrogates_reused == 0
    # a second entity with the same novel tuple reuses the fresh surrogate
    up2 = comp.update(_obs_triples("obs/n1", sensor="sensor/brand-new") +
                      [("obs/n1", "ssn:samplingTime", "time/1")])
    assert up2.n_new_surrogates == 0 and up2.n_surrogates_reused == 1


def test_update_incomplete_molecule_stays_raw_until_completed():
    store = _sensor(300, seed=14, include_result_links=False)
    comp = Compactor()
    comp.run(store)
    # batch 1: type + one A5 property only -> molecule incomplete
    up1 = comp.update([("obs/p", "rdf:type", "ssn:Observation"),
                       ("obs/p", "ssn:observedProperty",
                        "phenom/Temperature")])
    assert up1.n_entities_absorbed == 0
    e = comp.graph.dict.lookup("obs/p")
    assert (comp.graph.spo[:, 0] == e).sum() == 2     # still raw
    # batch 2 completes the molecule -> absorbed now
    up2 = comp.update([("obs/p", "ssn:procedure", "sensor/2"),
                       ("obs/p", "ssn:generatedBy", "sensor/2")])
    assert up2.n_entities_absorbed == 1


def test_update_closure_equals_full_recompute():
    """Incrementally updated G' and a from-scratch factorization of
    G + inserts have the same semantic closure (Def. 4.10/4.11)."""
    store = _sensor(350, seed=17, include_result_links=False)
    comp = Compactor()
    comp.run(store)
    batch = (_obs_triples("obs/u0", sensor="sensor/0") +
             [("obs/u0", "ssn:samplingTime", "time/2")] +
             _obs_triples("obs/u1", sensor="sensor/xx") +
             [("obs/u1", "ssn:samplingTime", "time/3"),
              ("meas/u0", "rdf:type", "ssn:Measurement"),
              ("meas/u0", "ssn:value", "val/0"),
              ("meas/u0", "ssn:unit", "unit/Temperature")])
    comp.update(batch)
    # reference: the full graph with the same inserts applied raw
    full = store.copy()
    d = full.dict
    full.add_ids(np.asarray([[d.id(s), d.id(p), d.id(o)]
                             for s, p, o in batch], np.int32))
    a = semantic_triples(full)
    b = semantic_triples(comp.graph)
    assert a.shape == b.shape and (a == b).all()
    # and a fresh Compactor over the full graph compacts at least as well,
    # but the incremental graph must stay strictly smaller than raw
    assert comp.graph.n_triples < full.n_triples


# ---------------------------------------------------------------------------
# deprecated shims + bulk minting
# ---------------------------------------------------------------------------

def test_deprecated_wrappers_warn_and_agree():
    from repro.core import efsp, factorize, gfsp
    store = figure1_graph()
    C = store.dict.lookup("C")
    with pytest.warns(DeprecationWarning):
        g = gfsp(store, C)
    with pytest.warns(DeprecationWarning):
        e = efsp(store, C)
    assert set(g.props) == set(e.props) and g.edges == e.edges
    with pytest.warns(DeprecationWarning):
        f = factorize(store, C, g.props)
    assert f.n_triples_after < f.n_triples_before


def test_deprecated_shims_identical_to_compactor_path():
    """The core.gfsp/efsp/factorize free functions must warn AND return
    results identical to the Compactor pipeline they shim over."""
    from repro.core import efsp as efsp_fn, factorize as fact_fn, \
        gfsp as gfsp_fn
    store = _sensor(250, seed=19)
    cid = store.dict.lookup("ssn:Observation")

    ref = Compactor(detector="gfsp", backend="host").detect(store, cid)
    with pytest.warns(DeprecationWarning):
        old = gfsp_fn(store, cid)
    assert (old.props, old.edges, old.ami, old.am, old.iterations,
            old.evaluations) == (ref.props, ref.edges, ref.ami, ref.am,
                                 ref.iterations, ref.evaluations)

    pytest.importorskip("jax")
    dev_ref = Compactor(detector="gfsp", backend="device").detect(store, cid)
    with pytest.warns(DeprecationWarning):
        dev_old = gfsp_fn(store, cid, device_sweep=True)
    assert (dev_old.props, dev_old.edges, dev_old.evaluations) == \
        (dev_ref.props, dev_ref.edges, dev_ref.evaluations)

    e_ref = Compactor(detector="efsp").detect(store, cid)
    with pytest.warns(DeprecationWarning):
        e_old = efsp_fn(store, cid)
    assert (e_old.props, e_old.edges, e_old.ami) == \
        (e_ref.props, e_ref.edges, e_ref.ami)

    f_ref = Compactor().execute(
        store, CompactionPlan.explicit([(cid, ref.props)]))
    with pytest.warns(DeprecationWarning):
        f_old = fact_fn(store, cid, ref.props)
    assert f_old.n_triples_after == f_ref.n_triples_after
    np.testing.assert_array_equal(f_old.graph.spo, f_ref.graph.spo)


def test_termdict_ids_bulk_matches_sequential():
    seq, bulk = TermDict(), TermDict()
    terms = [f"t/{i}" for i in range(50)]
    seq_ids = [seq.id(t) for t in terms]
    np.testing.assert_array_equal(bulk.ids(terms), seq_ids)
    # mixed seen/unseen + duplicates inside one batch
    mixed = ["t/3", "new/a", "t/7", "new/a", "new/b"]
    got = bulk.ids(mixed)
    assert got[0] == 3 and got[2] == 7
    assert got[1] == got[3] == 50        # duplicate minted once
    assert got[4] == 51
    assert [seq.id(t) for t in mixed] == got.tolist()

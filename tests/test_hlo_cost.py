"""Loop-aware HLO cost model: exact flop counts through scans (fwd+bwd),
trip-count extraction, collective ring models."""
from __future__ import annotations

import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import hlo_cost
from repro.launch.roofline import collective_stats


def _scan_net(nonlinear: bool):
    def f(x, ws):
        def body(c, w):
            h = c @ w
            return (jnp.tanh(h) if nonlinear else h), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    return f


def test_forward_scan_flops_exact():
    f = _scan_net(nonlinear=False)
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    c = hlo_cost(jax.jit(f).lower(xs, ws).compile().as_text())
    expect = 7 * 2 * 128**3
    assert c.flops == pytest.approx(expect, rel=0.02)
    assert any(t == 7 for _, t in c.loops)


def test_grad_scan_flops_exact():
    f = _scan_net(nonlinear=True)
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    txt = jax.jit(jax.grad(f, argnums=(0, 1))).lower(xs, ws) \
        .compile().as_text()
    c = hlo_cost(txt)
    expect = 3 * 5 * 2 * 128**3        # fwd + dx + dw
    assert c.flops == pytest.approx(expect, rel=0.02)


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    c = hlo_cost(jax.jit(f).lower(xs, ws).compile().as_text())
    assert c.flops == pytest.approx(4 * 3 * 2 * 64**3, rel=0.02)


def test_collective_ring_models():
    hlo = """
HloModule m
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups=[2,8]<=[16]
  %ag = f32[64]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[64]{0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    cs = collective_stats(hlo)
    b = 64 * 4
    assert cs.by_op["all-reduce"] == pytest.approx(2 * b * 7 / 8)
    assert cs.by_op["all-gather"] == pytest.approx(b * 3 / 4)
    assert cs.by_op["collective-permute"] == pytest.approx(b)


def test_bytes_nonzero_and_loop_scaled():
    f = _scan_net(nonlinear=False)
    xs = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w5 = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    w10 = jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)
    c5 = hlo_cost(jax.jit(f).lower(xs, w5).compile().as_text())
    c10 = hlo_cost(jax.jit(f).lower(xs, w10).compile().as_text())
    assert c10.bytes > c5.bytes > 0
    assert c10.flops == pytest.approx(2 * c5.flops, rel=0.02)

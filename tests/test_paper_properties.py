"""Hypothesis property tests for the paper's formal claims.

* Theorem 4.1 (pruning soundness): on random graphs, once a direct
  subset SP' ⊂ SP worsens #Edges, every deeper subset SP'' ⊂ SP' is at
  least as bad as SP -- the greedy stop rule never skips the optimum.
* G.FSP == E.FSP on random complete-molecule graphs (the paper's
  identical-output claim, beyond the worked examples).
* AMI bounds: 1 <= AMI <= AM; monotone under adding properties.
* Factorization is lossless and idempotent on already-factorized graphs.
"""
from __future__ import annotations

import itertools

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import efsp, factorize, gfsp, semantic_triples
from repro.core.star import ami, evaluate_subset, num_edges
from repro.core.triples import TripleStore


def _random_store(n_ents, n_props, card, seed):
    """Complete-molecule functional random graph of one class."""
    rng = np.random.default_rng(seed)
    triples = []
    obj = rng.integers(0, card, (n_ents, n_props))
    for i in range(n_ents):
        triples.append((f"c{i}", "rdf:type", "C"))
        for j in range(n_props):
            triples.append((f"c{i}", f"p{j}", f"o{j}_{obj[i, j]}"))
    return TripleStore.from_triples(triples)


def test_theorem_4_1_counterexample():
    """REPRODUCTION FINDING: Theorem 4.1 is FALSE as stated.

    The theorem claims: if #Edges(SP') > #Edges(SP) for SP' ⊂ SP, then
    every SP'' ⊂ SP' has #Edges(SP'') >= #Edges(SP) -- the justification
    for G.FSP's early stop.  Hypothesis-discovered counterexample (4
    entities, 4 properties): #Edges(S)=15, every 3-subset >= 16, yet
    {p0, p3} scores 14.  Consequently G.FSP (15) misses the optimum that
    E.FSP finds (14).  On the paper's benchmark data (and our matched
    synthetic graphs) the two DO agree -- the monotone structure holds
    for complete sensor-style molecules -- so the paper's empirical
    identical-output claim stands, but the theorem's unconditional claim
    does not.  See DESIGN.md §Fidelity-notes."""
    obj = np.array([[1, 0, 1, 1],
                    [0, 0, 0, 1],
                    [0, 1, 1, 1],
                    [0, 0, 0, 1]])
    t = []
    for i in range(4):
        t.append((f"c{i}", "rdf:type", "C"))
        for j in range(4):
            t.append((f"c{i}", f"p{j}", f"o{j}_{obj[i, j]}"))
    store = TripleStore.from_triples(t)
    cid = store.dict.lookup("C")
    props = [store.dict.lookup(f"p{j}") for j in range(4)]
    full = evaluate_subset(store, cid, props, 4)
    assert full.edges == 15
    # every direct 3-subset is strictly worse than S ...
    for sp in itertools.combinations(props, 3):
        assert evaluate_subset(store, cid, sp, 4).edges > full.edges
    # ... yet a 2-subset beats S: the theorem's conclusion fails
    best2 = min(evaluate_subset(store, cid, sp, 4).edges
                for sp in itertools.combinations(props, 2))
    assert best2 == 14 < full.edges
    # and the algorithms diverge exactly as implied
    assert gfsp(store, cid).edges == 15
    assert efsp(store, cid).edges == 14


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 25), k=st.integers(2, 4), card=st.integers(1, 3),
       seed=st.integers(0, 999))
def test_gfsp_equals_efsp_random(n, k, card, seed):
    store = _random_store(n, k, card, seed)
    cid = store.dict.lookup("C")
    r_g = gfsp(store, cid)
    r_e = efsp(store, cid)
    # E.FSP is exhaustive: it can never be worse; the paper claims (and
    # Theorem 4.1 implies, under its assumptions) greedy equality
    assert r_e.edges <= r_g.edges
    if r_e.edges == r_g.edges:
        assert r_e.ami == r_g.ami


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 60), k=st.integers(1, 4), card=st.integers(1, 5),
       seed=st.integers(0, 999))
def test_ami_bounds_and_monotonicity(n, k, card, seed):
    rng = np.random.default_rng(seed)
    mat = rng.integers(0, card, (n, k)).astype(np.int32)
    a_full = ami(mat)
    assert 1 <= a_full <= n
    for j in range(1, k):
        # AMI over a prefix of properties never exceeds AMI over more
        assert ami(mat[:, :j]) <= a_full


@settings(max_examples=8, deadline=None)
@given(n=st.integers(4, 30), card=st.integers(1, 3), seed=st.integers(0, 99))
def test_factorization_lossless_random(n, card, seed):
    store = _random_store(n, 3, card, seed)
    cid = store.dict.lookup("C")
    res = gfsp(store, cid)
    if len(res.props) < 2:
        return
    fact = factorize(store, cid, res.props)
    a, b = semantic_triples(store), semantic_triples(fact.graph)
    assert a.shape == b.shape and (a == b).all()


def test_num_edges_formula_worked_example():
    """Def. 4.8 against the paper's Figure 3 numbers (15 and 8)."""
    assert num_edges(3, 4, 4, 4) == 15     # SS = {p1..p4}: 3*(4+1) + 0
    assert num_edges(1, 4, 3, 4) == 8      # SS' = {p1,p2,p3}: 1*4 + 4*1

"""The compressed substrate: encoding round-trips, tier equivalence,
streamed detection, the workload-generator family, and the snapshot
digest memo.

The load-bearing claim of the compressed tier is *transparency*: every
accessor of ``GraphIndex``/``TripleStore`` answers byte-identically
from the bit-packed form, so the sweep and query engines run unchanged
on either tier.  The property tests here pin the encodings themselves
(pack/slice/take, delta blocks, front-coded terms); the parity tests
pin the accessor surface and the end-to-end detect/query digests.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Compactor
from repro.core.compress import (DECODE_STATS, CompactTermDict,
                                 DeltaPacked, FrontCodedTerms,
                                 PackedInts, bit_width, compress_store)
from repro.core.triples import TermDict, TripleStore
from repro.data.synthetic import (WORKLOAD_SHAPES, WorkloadSpec,
                                  generate_workload)
from repro.query import QueryEngine, StarQuery


# -- bit-packed columns -------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999), bits=st.integers(1, 40),
       n=st.integers(0, 600))
def test_packed_ints_roundtrip(seed, bits, n):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 2 ** bits, size=n, dtype=np.int64)
    packed = PackedInts.pack(vals)
    assert len(packed) == n
    np.testing.assert_array_equal(packed.slice_(), vals)
    if n:
        lo = int(rng.integers(0, n))
        hi = int(rng.integers(lo, n)) + 1
        np.testing.assert_array_equal(packed.slice_(lo, hi), vals[lo:hi])
        idx = rng.integers(0, n, size=min(n, 64))
        np.testing.assert_array_equal(packed.take(idx), vals[idx])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(0, 900),
       block=st.sampled_from((8, 64, 1024)))
def test_delta_packed_roundtrip(seed, n, block):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << 33, size=n, dtype=np.int64)
    vals.sort()                       # the CSR subject columns are sorted
    packed = DeltaPacked.pack(vals, block=block)
    assert len(packed) == n
    np.testing.assert_array_equal(packed.slice_(), vals)
    if n:
        lo = int(rng.integers(0, n))
        hi = int(rng.integers(lo, n)) + 1
        np.testing.assert_array_equal(packed.slice_(lo, hi), vals[lo:hi])


def test_delta_packed_rejects_unsorted():
    with pytest.raises(ValueError):
        DeltaPacked.pack(np.array([3, 1, 2], dtype=np.int64))


def test_bit_width_boundaries():
    assert bit_width(0) == 1
    assert bit_width(1) == 1
    assert bit_width(2) == 2
    assert bit_width(255) == 8
    assert bit_width(256) == 9


# -- front-coded dictionary ---------------------------------------------------

def _random_terms(rng, n):
    """ASCII-heavy with multi-byte tails: the find() path compares raw
    UTF-8 bytes, and 'é'/CJK sort differently as str vs bytes, which
    is exactly the bug class this guards."""
    pools = ("obs/", "sensor/", "val:", "", "é/", "時/")
    return sorted({pools[rng.integers(0, len(pools))]
                   + format(int(rng.integers(0, 10 ** 6)), "x")
                   for _ in range(n)})


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(1, 300),
       bucket=st.sampled_from((1, 4, 16)))
def test_front_coded_terms_roundtrip(seed, n, bucket):
    rng = np.random.default_rng(seed)
    terms = sorted(_random_terms(rng, n), key=lambda t: t.encode("utf-8"))
    fc = FrontCodedTerms.encode(terms, bucket=bucket)
    assert len(fc) == len(terms)
    for i, t in enumerate(terms):
        assert fc.get(i) == t
        assert fc.find(t) == i
    assert fc.find("zzz/definitely-not-present") is None


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(1, 200))
def test_compact_term_dict_parity_and_growth(seed, n):
    rng = np.random.default_rng(seed)
    d = TermDict()
    terms = list(_random_terms(rng, n))
    rng.shuffle(terms)                # insertion order != sorted order
    for t in terms:
        d.id(t)
    cd = CompactTermDict.from_dict(d)
    assert len(cd) == len(d)
    for t in terms:
        assert cd.lookup(t) == d.lookup(t)
        assert cd.term(d.lookup(t)) == t
        assert t in cd
    assert cd.lookup("zzz/not-here") is None
    # growth past the compacted base stays mutable
    new_id = cd.id("grown/after-compaction")
    assert new_id == len(d)
    assert cd.term(new_id) == "grown/after-compaction"
    assert cd.nbytes() < d.nbytes()


# -- tier equivalence on the accessor surface ---------------------------------

@pytest.fixture(scope="module", params=["sensor", "skewed", "reified"])
def tier_pair(request):
    store = generate_workload(WorkloadSpec(
        shape=request.param, n_triples=2_500, seed=11))
    return request.param, store, compress_store(store, max_resident=2)


def test_index_accessor_parity(tier_pair):
    _, plain, comp = tier_pair
    pi, ci = plain.index, comp.index
    np.testing.assert_array_equal(pi.preds, ci.preds)
    np.testing.assert_array_equal(pi.classes(), ci.classes())
    for p in pi.preds.tolist():
        np.testing.assert_array_equal(pi.pred_slice(p), ci.pred_slice(p))
        np.testing.assert_array_equal(pi.pred_subjects(p),
                                      ci.pred_subjects(p))
        np.testing.assert_array_equal(pi.pred_objects_sorted(p),
                                      ci.pred_objects_sorted(p))
        assert pi.pred_count(p) == ci.pred_count(p)
    for cid in pi.classes().tolist():
        np.testing.assert_array_equal(pi.entities_of_class(cid),
                                      ci.entities_of_class(cid))
        np.testing.assert_array_equal(pi.class_properties(cid),
                                      ci.class_properties(cid))
        props = pi.class_properties(cid)[:3]
        if props.shape[0]:
            pm = pi.object_matrix(cid, props)
            cm = ci.object_matrix(cid, props)
            for a, b in zip(pm, cm):
                np.testing.assert_array_equal(a, b)
            assert pi.labeled_edge_count(cid) == ci.labeled_edge_count(cid)
    np.testing.assert_array_equal(pi.rows, ci.rows)


def test_compressed_rows_and_accounting(tier_pair):
    _, plain, comp = tier_pair
    np.testing.assert_array_equal(plain.spo, comp.spo)
    assert comp.n_triples == plain.n_triples
    assert comp.substrate_nbytes() < 0.5 * plain.substrate_nbytes()


def test_mutation_returns_plain_tier(tier_pair):
    """filtered/merged leave the read-optimized tier: mutating a
    compressed index re-materializes a plain GraphIndex (recompression
    is the caller's explicit, paid-for step)."""
    from repro.core.index import GraphIndex
    _, plain, comp = tier_pair
    keep = np.ones(plain.n_triples, dtype=bool)
    keep[:: 7] = False
    fi = comp.index.filtered(keep)
    assert type(fi) is GraphIndex
    np.testing.assert_array_equal(fi.rows, plain.index.filtered(keep).rows)


def test_detect_and_query_digest_parity(tier_pair):
    shape, plain, comp = tier_pair
    cp, cc = Compactor(detector="gfsp"), Compactor(detector="gfsp")
    cp.run(plain)
    cc.run(comp, stream=True)
    assert cp.snapshot.digest() == cc.snapshot.digest()

    queries = []
    for cid, t in sorted(cp.fgraph.tables.items()):
        for row in t.objects[:4]:
            queries.append(StarQuery(
                arms=tuple((int(p), int(o))
                           for p, o in zip(t.props, row)),
                class_id=cid))
            queries.append(StarQuery(
                arms=((int(t.props[0]), int(row[0])),
                      (int(t.props[-1]), None)), class_id=cid))
    if not queries:
        pytest.skip(f"{shape} produced no factorized tables at this size")
    rp = QueryEngine(cp.snapshot.fgraph).query_batch(queries)
    rc = QueryEngine(cc.snapshot.fgraph).query_batch(queries)
    for a, b in zip(rp, rc):
        assert a.same_as(b)


def test_streamed_detection_bounds_resident_decodes(tier_pair):
    """stream=True must release per-class decodes between classes:
    peak resident bytes stay a fraction of the plain substrate."""
    _, plain, comp = tier_pair
    from repro.core import sweep as core_sweep
    core_sweep.reset_trace_stats()
    Compactor(detector="gfsp").run(comp, stream=True)
    peak = DECODE_STATS["peak_resident_bytes"]
    assert 0 < peak < 0.5 * plain.substrate_nbytes()


# -- workload-generator family ------------------------------------------------

@pytest.mark.parametrize("shape", WORKLOAD_SHAPES)
def test_workload_shapes_generate_and_detect(shape):
    store = generate_workload(WorkloadSpec(
        shape=shape, n_triples=3_000, seed=5))
    assert isinstance(store, TripleStore)
    # budget adherence: close to (never wildly past) the request
    assert 0.5 * 3_000 <= store.n_triples <= 1.3 * 3_000
    assert store.index.classes().shape[0] > 0
    # determinism: same spec, same bytes; different seed, different graph
    again = generate_workload(WorkloadSpec(
        shape=shape, n_triples=3_000, seed=5))
    np.testing.assert_array_equal(store.spo, again.spo)
    other = generate_workload(WorkloadSpec(
        shape=shape, n_triples=3_000, seed=6))
    assert (store.n_triples != other.n_triples
            or not np.array_equal(store.spo, other.spo))


def test_adversarial_shape_resists_compaction():
    store = generate_workload(WorkloadSpec(
        shape="adversarial", n_triples=3_000, seed=5))
    comp = Compactor(detector="gfsp")
    comp.run(store)
    # unique objects per entity leave nothing frequent to factorize:
    # compaction must not pay here (no or near-no savings)
    assert comp.snapshot.n_triples >= 0.95 * store.n_triples


# -- snapshot digest memo -----------------------------------------------------

def test_snapshot_digest_is_memoized_per_epoch():
    store = generate_workload(WorkloadSpec(
        shape="sensor", n_triples=2_000, seed=3))
    comp = Compactor(detector="gfsp")
    comp.run(store)
    snap = comp.snapshot
    assert not snap._digest_cache
    d1 = snap.digest()
    assert snap._digest_cache == [d1]
    assert snap.digest() is d1          # memo hit, not a recompute
    # a new epoch is a NEW snapshot object -> fresh (empty) memo
    comp2 = Compactor(detector="gfsp")
    comp2.run(store)
    assert comp2.snapshot is not snap
    assert comp2.snapshot.digest() == d1

"""Sharding rules: divisibility fallbacks, batch ladder, optimizer-state
spec trees mirror optimizer.init structure, hlo_cost parser."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.dist import sharding as sh
from repro.launch.mesh import make_test_mesh
from repro.models.common import TSpec
from repro.models.lm import LM
from repro.train import make_optimizer


class FakeMesh:
    """axis_sizes without real devices (rule logic is device-free)."""
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


def _plan(cfg, multi=False):
    mesh = FakeMesh((2, 16, 16) if multi else (16, 16),
                    ("pod", "data", "model") if multi
                    else ("data", "model"))
    return sh.make_plan(cfg, mesh)


def test_divisibility_fallback():
    cfg = get_arch("mamba2-780m")            # vocab 50280 !% 16
    plan = _plan(cfg)
    spec = sh.spec_for(plan, TSpec((50_280, 1536), "bfloat16",
                                   ("vocab", "embed")))
    assert spec == P(None, None)
    assert any("vocab" in f for f in plan.fallbacks)


def test_one_axis_per_tensor():
    cfg = get_arch("dbrx-132b")
    plan = _plan(cfg)
    spec = sh.spec_for(plan, TSpec((16, 6144, 10_752), "bfloat16",
                                   ("experts", "embed", "ff")))
    # experts claims model; ff must not reuse it; embed -> data (fsdp)
    assert spec == P("model", "data", None)


def test_batch_ladder():
    cfg = get_arch("qwen2-0.5b")             # tp=False
    plan = _plan(cfg, multi=True)            # dp axes (pod, data, model)
    assert sh.batch_axes_for(plan, 512) == ("pod", "data", "model")
    assert sh.batch_axes_for(plan, 256) == ("pod", "data")
    assert sh.batch_axes_for(plan, 128) == ("pod", "data")
    assert sh.batch_axes_for(plan, 16) == ("data",)
    assert sh.batch_axes_for(plan, 7) == ()


def test_kv_cache_seq_sharding():
    cfg = get_arch("qwen3-32b")              # kv=8 !% 16 -> seq takes model
    plan = _plan(cfg)
    from repro.models.blocks import attn_cache_specs
    spec = sh.spec_for(plan, attn_cache_specs(cfg, 128, 32_768,
                                              "bfloat16")["k"])
    assert spec == P("data", None, "model", None)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_opt_state_specs_match_init_structure(name):
    """The sharding tree for optimizer state must be structurally
    identical to optimizer.init's output -- otherwise the dry-run's
    in_shardings silently misalign."""
    cfg = get_arch(name)
    model = LM(cfg)
    opt = make_optimizer(cfg)
    param_shapes = jax.eval_shape(
        lambda: jax.tree.map(lambda s: jnp.zeros(s.shape, s.jdtype),
                             model.param_specs(),
                             is_leaf=lambda x: isinstance(x, TSpec)))
    opt_shapes = jax.eval_shape(opt.init, param_shapes)
    spec_tree = sh.opt_state_specs(cfg, model.param_specs())
    s1 = jax.tree.structure(opt_shapes)
    s2 = jax.tree.structure(jax.tree.map(
        lambda s: 0, spec_tree, is_leaf=lambda x: isinstance(x, TSpec)))
    assert s1 == s2, f"{name}: {s1} != {s2}"
    # shapes match leaf-for-leaf too
    for a, b in zip(jax.tree.leaves(opt_shapes),
                    jax.tree.leaves(spec_tree,
                                    is_leaf=lambda x: isinstance(x, TSpec))):
        assert a.shape == b.shape


def test_qkv_ladder():
    plan = _plan(get_arch("llama3-405b"))
    q, kv, grp = sh.qkv_specs(plan, get_arch("llama3-405b"), 32, seq=4096)
    # kv=8 !% 16: the grouped pin owns the layout; pinning q Hq-major as
    # well would fight it (per-chunk all-to-alls -- §Perf iteration 9)
    assert q == P("data", None, None, None)
    assert kv == P("data", None, None, None)
    assert grp == P("data", None, "model", None, None)  # group=16 % 16
    plan2 = _plan(get_arch("qwen3-32b"))
    _, _, grp2 = sh.qkv_specs(plan2, get_arch("qwen3-32b"), 32, seq=4096)
    assert grp2 == P("data", None, None, "model", None)  # q-seq fallback
    # kv-divisible arch: plain and grouped pins agree, both head-major
    plan3 = _plan(get_arch("moonshot-v1-16b-a3b"))
    q3, kv3, grp3 = sh.qkv_specs(plan3, get_arch("moonshot-v1-16b-a3b"),
                                 32, seq=4096)
    assert q3 == P("data", "model", None, None)
    assert grp3 == P("data", "model", None, None, None)


def test_act_spec_seq_sharding():
    cfg = get_arch("llama3-405b")
    plan = _plan(cfg)
    assert sh.act_spec(plan, 32, seq=4096) == P("data", "model", None)
    assert sh.act_spec(plan, 32, decode=True) == P("data", None, None)
    # uneven seq falls back
    assert sh.act_spec(plan, 32, seq=1500) == P("data", None, None)


def test_shard_hint_binds_under_mesh():
    from repro.models.common import shard_hint
    mesh = make_test_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    with mesh:
        y = jax.jit(lambda v: shard_hint(v, P("data", None)))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

"""Deterministic sharded LM data pipeline (synthetic tokens).

Production properties needed at 1000+ nodes, all present here:

* **Determinism + skip-ahead**: batch ``k`` is a pure function of
  (seed, k) -- restart at step k after a failure without replaying k
  batches (``batch_at``);
* **Host sharding**: each data-parallel host materializes only its slice
  (``host_slice``), so the global batch never exists on one host;
* **Straggler rebalance hook**: ``reassign`` re-partitions the host->slice
  map when the fault monitor (dist/fault.py) marks a host slow, keeping
  the global batch content IDENTICAL (same seed/step) while shrinking the
  slow host's share;
* **Factorized storage**: repeated documents live in a FactorizedStore
  (the paper's technique on the data plane).

Token stream: Zipf-ish synthetic ids with repeated "template" documents,
so compression/factorization behave like real corpora.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .factorized_store import FactorizedStore


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_templates: int = 64            # distinct repeated documents
    template_frac: float = 0.5       # fraction of rows drawn from templates


class LMPipeline:
    def __init__(self, spec: PipelineSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        self.templates = rng.integers(
            1, spec.vocab_size, (spec.n_templates, spec.seq_len),
            dtype=np.int32)

    # -- global batch ----------------------------------------------------------
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The full global batch for ``step`` (pure function of step)."""
        sp = self.spec
        rng = np.random.default_rng((sp.seed, step))
        n_templ = int(sp.global_batch * sp.template_frac)
        t_idx = rng.integers(0, sp.n_templates, (n_templ,))
        fresh = rng.integers(1, sp.vocab_size,
                             (sp.global_batch - n_templ, sp.seq_len),
                             dtype=np.int32)
        tokens = np.concatenate([self.templates[t_idx], fresh], axis=0)
        perm = rng.permutation(sp.global_batch)
        tokens = tokens[perm]
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        mask = np.ones_like(tokens, np.float32)
        mask[:, -1] = 0.0
        return {"tokens": tokens, "labels": labels, "mask": mask}

    # -- host sharding -----------------------------------------------------------
    def host_slice(self, step: int, host: int, n_hosts: int,
                   shares: np.ndarray | None = None) -> dict[str, np.ndarray]:
        """This host's rows of batch ``step``.

        ``shares``: optional per-host row counts (sum == global_batch) from
        the straggler rebalancer; default: equal split."""
        sp = self.spec
        if shares is None:
            assert sp.global_batch % n_hosts == 0
            shares = np.full((n_hosts,), sp.global_batch // n_hosts)
        bounds = np.concatenate([[0], np.cumsum(shares)])
        full = self.batch_at(step)
        lo, hi = int(bounds[host]), int(bounds[host + 1])
        return {k: v[lo:hi] for k, v in full.items()}

    @staticmethod
    def reassign(n_hosts: int, global_batch: int,
                 slow: set[int], slow_share: float = 0.5) -> np.ndarray:
        """Shrink slow hosts' shares; redistribute to healthy hosts."""
        shares = np.full((n_hosts,), global_batch // n_hosts, np.int64)
        for h in sorted(slow):
            cut = int(shares[h] * slow_share)
            shares[h] -= cut
            healthy = [i for i in range(n_hosts) if i not in slow]
            for i, extra in zip(healthy, _split(cut, len(healthy))):
                shares[i] += extra
        assert shares.sum() == global_batch
        return shares

    # -- factorized corpus ---------------------------------------------------------
    def factorized_corpus(self, n_rows: int) -> FactorizedStore:
        sp = self.spec
        rng = np.random.default_rng(sp.seed + 1)
        n_templ = int(n_rows * sp.template_frac)
        rows = np.concatenate([
            self.templates[rng.integers(0, sp.n_templates, (n_templ,))],
            rng.integers(1, sp.vocab_size, (n_rows - n_templ, sp.seq_len),
                         dtype=np.int32)])
        return FactorizedStore.build(rows)


def _split(total: int, parts: int) -> list[int]:
    base, rem = divmod(total, parts)
    return [base + (1 if i < rem else 0) for i in range(parts)]

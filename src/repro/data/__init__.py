"""Data plane: synthetic RDF benchmarks, factorized storage, LM pipeline."""

"""Factorized corpus store: the paper's compaction on the data plane.

Training corpora (and LM-serving prompt logs) contain many EXACTLY
repeated rows -- boilerplate documents, templated prompts, duplicated
web pages.  A row-store of such a corpus is an RDF-graph-shaped object:

  entity   = row index            property = column (token position)
  object   = token id             star pattern = a distinct row

``FactorizedStore`` applies Algorithm 3 at the row granularity: distinct
rows become compact molecules (stored once), each original row keeps an
``instanceOf`` pointer (int32).  ``#Edges`` (Def. 4.8) in bytes decides
whether factorization pays (Fig. 7 overhead case: near-unique corpora are
stored flat).

Reads are a single gather -- no decompression pass (the paper's key
property vs [16]); the gather composes with the host->device transfer so
repeated rows cross PCIe once per unique row per batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.star import row_groups


@dataclasses.dataclass
class FactorizedStore:
    molecules: np.ndarray | None      # (M, L) unique rows (None: flat)
    instance_of: np.ndarray | None    # (N,) row -> molecule
    flat: np.ndarray | None           # unfactorized fallback
    bytes_original: int
    bytes_stored: int

    @classmethod
    def build(cls, rows: np.ndarray, ptr_bytes: int = 4) -> "FactorizedStore":
        rows = np.asarray(rows)
        n, length = rows.shape
        item = rows.dtype.itemsize
        original = n * length * item
        inv, counts, rep = row_groups(rows)
        m = counts.shape[0]
        factorized = m * length * item + n * ptr_bytes
        if factorized >= original:                  # overhead case (Fig. 7)
            return cls(None, None, rows, original, original)
        return cls(rows[rep], inv.astype(np.int32), None, original,
                   factorized)

    @property
    def savings_pct(self) -> float:
        return 100.0 * (1 - self.bytes_stored / max(self.bytes_original, 1))

    @property
    def n_rows(self) -> int:
        if self.flat is not None:
            return self.flat.shape[0]
        return self.instance_of.shape[0]

    def __getitem__(self, idx) -> np.ndarray:
        if self.flat is not None:
            return self.flat[idx]
        return self.molecules[self.instance_of[idx]]

    def batch_parts(self, idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Decompose a batch gather into ``(unique_molecules, inverse)``.

        ``unique_molecules[inverse]`` reconstructs the batch; the first
        part is the whole device-transfer payload -- each distinct
        molecule referenced by the batch crosses the link exactly once,
        the ``inverse`` pointer array (4 bytes/row) does the instanceOf
        expansion on the far side.  Flat stores degrade to the identity
        decomposition (every row is its own molecule).
        """
        idx = np.asarray(idx)
        if self.flat is not None:
            rows = self.flat[idx].reshape(-1, self.flat.shape[1])
            return rows, np.arange(rows.shape[0]).reshape(idx.shape)
        mol = self.instance_of[idx]
        uniq, inv = np.unique(mol, return_inverse=True)
        return self.molecules[uniq], inv.reshape(mol.shape)

    def batch(self, idx: np.ndarray, device: bool = False) -> np.ndarray:
        """Gather a batch; the device path sends unique molecules once.

        ``device=True`` ships only the unique-molecule payload of
        :meth:`batch_parts` across the host->device link and expands the
        ``instanceOf`` pointers on device (returns a ``jax.Array``); the
        default host path performs the same two-step gather in numpy.
        """
        mols, inv = self.batch_parts(idx)
        if device:
            import jax.numpy as jnp
            return jnp.take(jnp.asarray(mols), jnp.asarray(inv), axis=0)
        return mols[inv]

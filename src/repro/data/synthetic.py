"""Synthetic LinkedSensorData-style RDF graphs (paper §5 datasets).

The paper evaluates on LinkedSensorData (SSN ontology): weather observations
with ``property / procedure / generatedBy / time`` edges and linked
measurements with ``value / unit`` edges.  The original dumps are not
redistributable offline, so this module regenerates graphs with the same
schema, the same A1-A10 property sets, and matched repetition statistics:

  * ``procedure``/``generatedBy`` are symmetric (same sensor object);
  * measurement values follow a Zipf law, so a few values are highly
    repeated (paper Fig. 8);
  * ``unit`` is functionally determined by the phenomenon (9 phenomena).

Scale is controlled by ``n_observations``; per-class property sets mirror
Table 2 (A1..A7 for Observation, A8..A10 for Measurement).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.triples import TripleStore

PHENOMENA = ["Temperature", "WindSpeed", "WindDirection", "RelativeHumidity",
             "Visibility", "Precipitation", "Pressure", "Rainfall", "Snowfall"]

OBSERVATION = "ssn:Observation"
MEASUREMENT = "ssn:Measurement"
SENSOR = "ssn:Sensor"
P_PROPERTY = "ssn:observedProperty"
P_PROCEDURE = "ssn:procedure"
P_GENERATED_BY = "ssn:generatedBy"
P_TIME = "ssn:samplingTime"
P_RESULT = "ssn:observationResult"
P_VALUE = "ssn:value"
P_UNIT = "ssn:unit"
P_MODEL = "ssn:model"
P_LOCATION = "ssn:location"

# Table 2 property sets
PROPERTY_SETS = {
    "A1": (OBSERVATION, [P_PROPERTY]),
    "A2": (OBSERVATION, [P_TIME]),
    "A3": (OBSERVATION, [P_PROCEDURE, P_GENERATED_BY]),
    "A4": (OBSERVATION, [P_PROPERTY, P_PROCEDURE, P_GENERATED_BY, P_TIME]),
    "A5": (OBSERVATION, [P_PROPERTY, P_PROCEDURE, P_GENERATED_BY]),
    "A6": (OBSERVATION, [P_PROPERTY, P_TIME]),
    "A7": (OBSERVATION, [P_PROCEDURE, P_TIME, P_GENERATED_BY]),
    "A8": (MEASUREMENT, [P_VALUE, P_UNIT]),
    "A9": (MEASUREMENT, [P_VALUE]),
    "A10": (MEASUREMENT, [P_UNIT]),
}


@dataclasses.dataclass
class SensorGraphSpec:
    n_observations: int = 2000
    n_sensors: int = 20
    n_timestamps: int = 50
    n_values: int = 40            # distinct measurement values
    zipf_a: float = 1.8           # value repetition skew (Fig. 8 shape)
    seed: int = 0
    include_result_links: bool = True
    # ssn:Sensor metadata stars (model/location over a few shared
    # tuples): gives cross-star BGPs a second *factorizable* class on
    # the far side of ``procedure``, so Observation-Sensor joins are
    # molecule-to-molecule (AMI x AMI).  Off by default -- the
    # single-star BENCH/test numbers predate it.
    include_sensor_metadata: bool = False


def generate(spec: SensorGraphSpec) -> TripleStore:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_observations
    phen = rng.integers(0, len(PHENOMENA), n)
    sensor = rng.integers(0, spec.n_sensors, n)
    tstamp = rng.integers(0, spec.n_timestamps, n)
    # Zipf-distributed value ids, clipped to the distinct-value budget
    vals = np.minimum(rng.zipf(spec.zipf_a, n) - 1, spec.n_values - 1)

    triples: list[tuple[str, str, str]] = []
    for i in range(n):
        obs = f"obs/{i}"
        meas = f"meas/{i}"
        sens = f"sensor/{sensor[i]}"
        triples.append((obs, "rdf:type", OBSERVATION))
        triples.append((obs, P_PROPERTY, f"phenom/{PHENOMENA[phen[i]]}"))
        triples.append((obs, P_PROCEDURE, sens))
        triples.append((obs, P_GENERATED_BY, sens))
        triples.append((obs, P_TIME, f"time/{tstamp[i]}"))
        if spec.include_result_links:
            triples.append((obs, P_RESULT, meas))
        triples.append((meas, "rdf:type", MEASUREMENT))
        triples.append((meas, P_VALUE, f"val/{vals[i]}"))
        triples.append((meas, P_UNIT, f"unit/{PHENOMENA[phen[i]]}"))
    if spec.include_sensor_metadata:
        # few distinct (model, location) tuples over many sensors ->
        # high-multiplicity Sensor molecules
        for s in range(spec.n_sensors):
            sens = f"sensor/{s}"
            triples.append((sens, "rdf:type", SENSOR))
            triples.append((sens, P_MODEL, f"model/{s % 3}"))
            triples.append((sens, P_LOCATION, f"site/{s % 4}"))
    return TripleStore.from_triples(triples)


# ---------------------------------------------------------------------------
# scenario-diverse workload generators (ROADMAP item 3(b))
# ---------------------------------------------------------------------------
#
# ``generate()`` above builds one shape (SSN sensor stars) with a python
# loop -- fine at paper scale, minutes at 1M triples.  The workload
# family below targets the (scale x shape) bench grid: every shape is
# generated *vectorized* (term vocabularies are minted once as
# contiguous id blocks via ``TermDict.ids``; triple rows are assembled
# from integer arrays), so a 1M-triple graph builds in seconds.  Shapes
# stress different parts of the pipeline:
#
#   sensor      -- the paper's SSN schema (high-multiplicity stars;
#                  everything factorizes)
#   skewed      -- Zipf class sizes, per-class multiplicity spread over
#                  two orders of magnitude: the bucket ladder sees one
#                  dominant class + a long tail
#   hierarchy   -- deep linked levels, one predicate family per level:
#                  many small CSR partitions, cross-class chains
#   reified     -- RDF-star-style statement metadata (Abuoda et al.):
#                  per-statement subject/object arms block the full
#                  star, the (predicate, source, confidence) core
#                  survives -- partial-payoff factorization
#   adversarial -- multiplicity-1 molecules everywhere (Fig. 7b at
#                  scale): nothing pays off, the planner must skip
#                  every class and compression is the only win

WORKLOAD_SHAPES = ("sensor", "skewed", "hierarchy", "reified", "adversarial")


@dataclasses.dataclass
class WorkloadSpec:
    """One cell of the (scale x shape) grid: ``n_triples`` is a target
    the generators hit within a few percent (exact counts depend on
    dedup of coincident rows)."""

    shape: str = "sensor"
    n_triples: int = 10_000
    seed: int = 0
    n_classes: int = 12        # skewed: class count (Zipf sizes)
    zipf_a: float = 1.3        # skewed: class-size skew exponent
    depth: int = 6             # hierarchy: number of linked levels
    reify_fraction: float = 0.6  # reified: fraction of statements reified


def _vocab(d, prefix: str, n: int) -> np.ndarray:
    """Mint ``n`` terms ``{prefix}{i}`` as one contiguous id block."""
    return d.ids([f"{prefix}{i}" for i in range(n)])


def generate_workload(spec: WorkloadSpec) -> TripleStore:
    if spec.shape not in WORKLOAD_SHAPES:
        raise ValueError(f"unknown workload shape {spec.shape!r}; "
                         f"choose from {WORKLOAD_SHAPES}")
    rng = np.random.default_rng(spec.seed)
    store = TripleStore()
    rows = _SHAPE_BUILDERS[spec.shape](store, spec, rng)
    store.spo = np.concatenate(rows, axis=0)
    return store


def _stack(s: np.ndarray, p: int | np.ndarray, o: np.ndarray) -> np.ndarray:
    out = np.empty((len(s), 3), np.int32)
    out[:, 0] = s
    out[:, 1] = p
    out[:, 2] = o
    return out


def _sensor_rows(store, spec, rng):
    """Vectorized SSN sensor shape: 9 triples per observation, vocab
    scaled with n so the dictionary grows with the graph."""
    d = store.dict
    n = max(spec.n_triples // 9, 1)
    n_sensors = max(20, n // 200)
    n_times = max(50, n // 100)
    n_vals = max(40, n // 250)
    obs = _vocab(d, "obs/", n)
    meas = _vocab(d, "meas/", n)
    sens = _vocab(d, "sensor/", n_sensors)
    times = _vocab(d, "time/", n_times)
    vals = _vocab(d, "val/", n_vals)
    phen = d.ids([f"phenom/{p}" for p in PHENOMENA])
    units = d.ids([f"unit/{p}" for p in PHENOMENA])
    cls_o, cls_m = d.id(OBSERVATION), d.id(MEASUREMENT)
    pi = rng.integers(0, len(PHENOMENA), n)
    si = sens[rng.integers(0, n_sensors, n)]
    vi = vals[np.minimum(rng.zipf(1.8, n) - 1, n_vals - 1)]
    return [
        _stack(obs, store.TYPE, np.full(n, cls_o, np.int32)),
        _stack(obs, d.id(P_PROPERTY), phen[pi]),
        _stack(obs, d.id(P_PROCEDURE), si),
        _stack(obs, d.id(P_GENERATED_BY), si),
        _stack(obs, d.id(P_TIME), times[rng.integers(0, n_times, n)]),
        _stack(obs, d.id(P_RESULT), meas),
        _stack(meas, store.TYPE, np.full(n, cls_m, np.int32)),
        _stack(meas, d.id(P_VALUE), vi),
        _stack(meas, d.id(P_UNIT), units[pi]),
    ]


def _skewed_rows(store, spec, rng):
    """Zipf class sizes x spread multiplicities: class c gets
    ``~ n / (c+1)^a`` entities, k_c in [3, 8] properties, and its
    molecules repeat over ``2^u`` distinct star tuples."""
    d = store.dict
    weights = 1.0 / np.arange(1, spec.n_classes + 1) ** spec.zipf_a
    weights /= weights.sum()
    rows = []
    for c, w in enumerate(weights):
        k = int(rng.integers(3, 9))
        n_ents = max(int(spec.n_triples * w / (k + 1)), 2)
        ents = _vocab(d, f"c{c}/e", n_ents)
        cls = d.id(f"class/{c}")
        rows.append(_stack(ents, store.TYPE, np.full(n_ents, cls, np.int32)))
        # distinct star tuples: multiplicity ~ 2^u, u uniform in [0, 7]
        n_tuples = max(n_ents >> int(rng.integers(0, 8)), 1)
        tup = rng.integers(0, n_tuples, n_ents)
        for j in range(k):
            objs = _vocab(d, f"c{c}/p{j}/o", n_tuples)
            rows.append(_stack(ents, d.id(f"c{c}/p{j}"), objs[tup]))
    return rows


def _hierarchy_rows(store, spec, rng):
    """``depth`` linked levels; level L entities carry a ``next`` link
    into level L+1 plus two data arms over shared objects -- every
    level is its own class with its own predicate family."""
    d = store.dict
    per_level = max(spec.n_triples // (spec.depth * 4), 2)
    level_ents = [_vocab(d, f"lvl{li}/e", per_level)
                  for li in range(spec.depth)]
    rows = []
    for li in range(spec.depth):
        ents = level_ents[li]
        n = len(ents)
        cls = d.id(f"level/{li}")
        rows.append(_stack(ents, store.TYPE, np.full(n, cls, np.int32)))
        # data arms: object pools shrink with depth (deeper = more shared)
        pool = max(n // (2 ** min(li + 1, 6)), 1)
        for j in range(2):
            objs = _vocab(d, f"lvl{li}/p{j}/o", pool)
            rows.append(_stack(ents, d.id(f"lvl{li}/p{j}"),
                               objs[rng.integers(0, pool, n)]))
        if li + 1 < spec.depth:
            nxt = level_ents[li + 1]
            rows.append(_stack(ents, d.id(f"lvl{li}/next"),
                               nxt[np.arange(n) % len(nxt)]))
    return rows


def _reified_rows(store, spec, rng):
    """RDF-star-style reification: base edges plus statement nodes
    whose ``rdf:subject``/``rdf:object`` arms are statement-unique
    (blocking the full star) while (predicate, source, confidence)
    repeat heavily (the factorizable core)."""
    d = store.dict
    per_stmt = 1 + spec.reify_fraction * 6
    n = max(int(spec.n_triples / per_stmt), 2)
    n_subj = max(n // 8, 1)
    n_obj = max(n // 8, 1)
    n_preds = 7
    subs = _vocab(d, "node/s", n_subj)
    objs = _vocab(d, "node/o", n_obj)
    preds = _vocab(d, "edge/p", n_preds)
    sources = _vocab(d, "source/", 5)
    confs = _vocab(d, "conf/", 10)
    si = subs[rng.integers(0, n_subj, n)]
    oi = objs[rng.integers(0, n_obj, n)]
    pi = preds[rng.integers(0, n_preds, n)]
    rows = [_stack(si, pi[0], oi)] if n_preds == 1 else \
        [np.column_stack([si, pi, oi]).astype(np.int32)]
    m = rng.random(n) < spec.reify_fraction
    nm = int(m.sum())
    if nm:
        stmts = _vocab(d, "stmt/", nm)
        cls = d.id("rdf:Statement")
        rows += [
            _stack(stmts, store.TYPE, np.full(nm, cls, np.int32)),
            _stack(stmts, d.id("rdf:subject"), si[m]),
            _stack(stmts, d.id("rdf:predicate"), pi[m]),
            _stack(stmts, d.id("rdf:object"), oi[m]),
            _stack(stmts, d.id("prov:source"),
                   sources[rng.integers(0, 5, nm)]),
            _stack(stmts, d.id("prov:confidence"),
                   confs[rng.integers(0, 10, nm)]),
        ]
    return rows


def _adversarial_rows(store, spec, rng):
    """Fig. 7b at scale: every molecule's object tuple is unique, so
    AMI == AM for every candidate and predicted Def. 4.8 savings are
    negative everywhere -- the planner must skip every class."""
    d = store.dict
    k = 4
    n = max(spec.n_triples // (k + 1), 2)
    ents = _vocab(d, "adv/e", n)
    rows = []
    for c in range(3):
        sel = ents[c::3]
        cls = d.id(f"advclass/{c}")
        rows.append(_stack(sel, store.TYPE,
                           np.full(len(sel), cls, np.int32)))
    for j in range(k):
        objs = _vocab(d, f"adv/p{j}/u", n)   # one object per entity
        rows.append(_stack(ents, d.id(f"adv/p{j}"),
                           objs[rng.permutation(n)]))
    return rows


_SHAPE_BUILDERS = {
    "sensor": _sensor_rows,
    "skewed": _skewed_rows,
    "hierarchy": _hierarchy_rows,
    "reified": _reified_rows,
    "adversarial": _adversarial_rows,
}


def property_set_ids(store: TripleStore, sid: str) -> tuple[int, list[int]]:
    """Resolve a Table-2 SID to (class_id, property_ids) in a store."""
    cname, props = PROPERTY_SETS[sid]
    cid = store.dict.lookup(cname)
    if cid is None:
        raise KeyError(f"class {cname} not in store")
    pids = []
    for p in props:
        pid = store.dict.lookup(p)
        if pid is None:
            raise KeyError(f"property {p} not in store")
        pids.append(pid)
    return cid, pids


def figure1_graph() -> TripleStore:
    """The paper's motivating example (Figure 1a), exactly.

    c1..c4 of class C share (p1 e1), (p2 e2), (p3 e3); p4 objects: c1->e4,
    c2->e4, c3->e5, c4->e6 (multiplicities 2, 1, 1 -> AMI({p4}) = 3,
    matching §4.2's walkthrough).  20 triples total (16 property edges +
    4 type edges).
    """
    t = []
    for c in ["c1", "c2", "c3", "c4"]:
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", "e1"))
        t.append((c, "p2", "e2"))
        t.append((c, "p3", "e3"))
    t.append(("c1", "p4", "e4"))
    t.append(("c2", "p4", "e4"))
    t.append(("c3", "p4", "e5"))
    t.append(("c4", "p4", "e6"))
    return TripleStore.from_triples(t)


def figure7a_graph() -> TripleStore:
    """Paper Figure 7a: factorization pays off (savings > 0).

    5 entities of C each carrying the same objects over p1, p2, p3 and a
    distinct object over p4: 20 property edges; factorizing {p1,p2,p3}
    replaces 15 edges by 4 (star) + 5 (instanceOf) = 9 -> saves 6 edges.
    """
    t = []
    for i in range(5):
        c = f"c{i}"
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", "e1"))
        t.append((c, "p2", "e2"))
        t.append((c, "p3", "e3"))
        t.append((c, "p4", f"u{i}"))
    return TripleStore.from_triples(t)


def figure7b_graph() -> TripleStore:
    """Paper Figure 7b flavor: factorization overhead (savings < 0).

    9 entities in 9 distinct (p1, p2) object pairs -- every star pattern has
    multiplicity 1, so factorization only adds surrogates/instanceOf edges.
    """
    t = []
    for i in range(9):
        c = f"c{i}"
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", f"a{i}"))
        t.append((c, "p2", f"b{i}"))
    return TripleStore.from_triples(t)

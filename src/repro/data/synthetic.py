"""Synthetic LinkedSensorData-style RDF graphs (paper §5 datasets).

The paper evaluates on LinkedSensorData (SSN ontology): weather observations
with ``property / procedure / generatedBy / time`` edges and linked
measurements with ``value / unit`` edges.  The original dumps are not
redistributable offline, so this module regenerates graphs with the same
schema, the same A1-A10 property sets, and matched repetition statistics:

  * ``procedure``/``generatedBy`` are symmetric (same sensor object);
  * measurement values follow a Zipf law, so a few values are highly
    repeated (paper Fig. 8);
  * ``unit`` is functionally determined by the phenomenon (9 phenomena).

Scale is controlled by ``n_observations``; per-class property sets mirror
Table 2 (A1..A7 for Observation, A8..A10 for Measurement).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.triples import TripleStore

PHENOMENA = ["Temperature", "WindSpeed", "WindDirection", "RelativeHumidity",
             "Visibility", "Precipitation", "Pressure", "Rainfall", "Snowfall"]

OBSERVATION = "ssn:Observation"
MEASUREMENT = "ssn:Measurement"
SENSOR = "ssn:Sensor"
P_PROPERTY = "ssn:observedProperty"
P_PROCEDURE = "ssn:procedure"
P_GENERATED_BY = "ssn:generatedBy"
P_TIME = "ssn:samplingTime"
P_RESULT = "ssn:observationResult"
P_VALUE = "ssn:value"
P_UNIT = "ssn:unit"
P_MODEL = "ssn:model"
P_LOCATION = "ssn:location"

# Table 2 property sets
PROPERTY_SETS = {
    "A1": (OBSERVATION, [P_PROPERTY]),
    "A2": (OBSERVATION, [P_TIME]),
    "A3": (OBSERVATION, [P_PROCEDURE, P_GENERATED_BY]),
    "A4": (OBSERVATION, [P_PROPERTY, P_PROCEDURE, P_GENERATED_BY, P_TIME]),
    "A5": (OBSERVATION, [P_PROPERTY, P_PROCEDURE, P_GENERATED_BY]),
    "A6": (OBSERVATION, [P_PROPERTY, P_TIME]),
    "A7": (OBSERVATION, [P_PROCEDURE, P_TIME, P_GENERATED_BY]),
    "A8": (MEASUREMENT, [P_VALUE, P_UNIT]),
    "A9": (MEASUREMENT, [P_VALUE]),
    "A10": (MEASUREMENT, [P_UNIT]),
}


@dataclasses.dataclass
class SensorGraphSpec:
    n_observations: int = 2000
    n_sensors: int = 20
    n_timestamps: int = 50
    n_values: int = 40            # distinct measurement values
    zipf_a: float = 1.8           # value repetition skew (Fig. 8 shape)
    seed: int = 0
    include_result_links: bool = True
    # ssn:Sensor metadata stars (model/location over a few shared
    # tuples): gives cross-star BGPs a second *factorizable* class on
    # the far side of ``procedure``, so Observation-Sensor joins are
    # molecule-to-molecule (AMI x AMI).  Off by default -- the
    # single-star BENCH/test numbers predate it.
    include_sensor_metadata: bool = False


def generate(spec: SensorGraphSpec) -> TripleStore:
    rng = np.random.default_rng(spec.seed)
    n = spec.n_observations
    phen = rng.integers(0, len(PHENOMENA), n)
    sensor = rng.integers(0, spec.n_sensors, n)
    tstamp = rng.integers(0, spec.n_timestamps, n)
    # Zipf-distributed value ids, clipped to the distinct-value budget
    vals = np.minimum(rng.zipf(spec.zipf_a, n) - 1, spec.n_values - 1)

    triples: list[tuple[str, str, str]] = []
    for i in range(n):
        obs = f"obs/{i}"
        meas = f"meas/{i}"
        sens = f"sensor/{sensor[i]}"
        triples.append((obs, "rdf:type", OBSERVATION))
        triples.append((obs, P_PROPERTY, f"phenom/{PHENOMENA[phen[i]]}"))
        triples.append((obs, P_PROCEDURE, sens))
        triples.append((obs, P_GENERATED_BY, sens))
        triples.append((obs, P_TIME, f"time/{tstamp[i]}"))
        if spec.include_result_links:
            triples.append((obs, P_RESULT, meas))
        triples.append((meas, "rdf:type", MEASUREMENT))
        triples.append((meas, P_VALUE, f"val/{vals[i]}"))
        triples.append((meas, P_UNIT, f"unit/{PHENOMENA[phen[i]]}"))
    if spec.include_sensor_metadata:
        # few distinct (model, location) tuples over many sensors ->
        # high-multiplicity Sensor molecules
        for s in range(spec.n_sensors):
            sens = f"sensor/{s}"
            triples.append((sens, "rdf:type", SENSOR))
            triples.append((sens, P_MODEL, f"model/{s % 3}"))
            triples.append((sens, P_LOCATION, f"site/{s % 4}"))
    return TripleStore.from_triples(triples)


def property_set_ids(store: TripleStore, sid: str) -> tuple[int, list[int]]:
    """Resolve a Table-2 SID to (class_id, property_ids) in a store."""
    cname, props = PROPERTY_SETS[sid]
    cid = store.dict.lookup(cname)
    if cid is None:
        raise KeyError(f"class {cname} not in store")
    pids = []
    for p in props:
        pid = store.dict.lookup(p)
        if pid is None:
            raise KeyError(f"property {p} not in store")
        pids.append(pid)
    return cid, pids


def figure1_graph() -> TripleStore:
    """The paper's motivating example (Figure 1a), exactly.

    c1..c4 of class C share (p1 e1), (p2 e2), (p3 e3); p4 objects: c1->e4,
    c2->e4, c3->e5, c4->e6 (multiplicities 2, 1, 1 -> AMI({p4}) = 3,
    matching §4.2's walkthrough).  20 triples total (16 property edges +
    4 type edges).
    """
    t = []
    for c in ["c1", "c2", "c3", "c4"]:
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", "e1"))
        t.append((c, "p2", "e2"))
        t.append((c, "p3", "e3"))
    t.append(("c1", "p4", "e4"))
    t.append(("c2", "p4", "e4"))
    t.append(("c3", "p4", "e5"))
    t.append(("c4", "p4", "e6"))
    return TripleStore.from_triples(t)


def figure7a_graph() -> TripleStore:
    """Paper Figure 7a: factorization pays off (savings > 0).

    5 entities of C each carrying the same objects over p1, p2, p3 and a
    distinct object over p4: 20 property edges; factorizing {p1,p2,p3}
    replaces 15 edges by 4 (star) + 5 (instanceOf) = 9 -> saves 6 edges.
    """
    t = []
    for i in range(5):
        c = f"c{i}"
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", "e1"))
        t.append((c, "p2", "e2"))
        t.append((c, "p3", "e3"))
        t.append((c, "p4", f"u{i}"))
    return TripleStore.from_triples(t)


def figure7b_graph() -> TripleStore:
    """Paper Figure 7b flavor: factorization overhead (savings < 0).

    9 entities in 9 distinct (p1, p2) object pairs -- every star pattern has
    multiplicity 1, so factorization only adds surrogates/instanceOf edges.
    """
    t = []
    for i in range(9):
        c = f"c{i}"
        t.append((c, "rdf:type", "C"))
        t.append((c, "p1", f"a{i}"))
        t.append((c, "p2", f"b{i}"))
    return TripleStore.from_triples(t)

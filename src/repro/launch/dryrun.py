import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count at first init.
# This gives the dry-run 512 placeholder host devices for the production
# meshes; smoke tests / benches import other modules and see 1 device.
#
# Multi-pod dry-run (deliverable e): for every (architecture x input shape)
# cell, build the jit'd train/prefill/decode step with explicit in/out
# shardings on the production mesh, ``.lower().compile()`` it, and record
# memory_analysis / cost_analysis / collective stats for EXPERIMENTS.md.
#
#   python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all --mesh both      # full 40-cell sweep
#   python -m repro.launch.dryrun --list
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, applicable, get_arch
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def input_specs(arch: str, shape: str = "train_4k") -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell
    (weak-type-correct, shardable, no device allocation)."""
    import jax.numpy as jnp
    from repro.models.lm import LM
    from repro.train.serve_step import decode_input_specs
    from repro.train.train_step import batch_specs

    cfg = get_arch(arch)
    sp = SHAPES[shape]
    model = LM(cfg)
    if sp.kind == "train":
        return batch_specs(cfg, sp.global_batch, sp.seq_len)
    if sp.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct(
            (sp.global_batch, sp.seq_len), jnp.int32)}
        if cfg.frontend != "none":
            fd = cfg.frontend_dim or cfg.d_model
            out["frontend"] = jax.ShapeDtypeStruct(
                (sp.global_batch, cfg.frontend_tokens, fd),
                jnp.dtype(cfg.dtype))
        return out
    return decode_input_specs(model, sp.global_batch, sp.seq_len)


def build_cell(arch: str, shape: str, *, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (jitted_fn, example_args) ready to .lower(*args)."""
    import dataclasses as dc

    from repro.dist import sharding as sh
    from repro.models.blocks import Ctx
    from repro.models.common import specs_to_shapes
    from repro.models.lm import LM
    from repro.train import (make_decode_step, make_optimizer,
                             make_prefill_step, make_train_step)
    from repro.train.train_step import batch_specs

    cfg = get_arch(arch)
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    sp = SHAPES[shape]
    ok, reason = applicable(cfg, sp)
    if not ok:
        raise SystemExit(reason)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = sh.make_plan(cfg, mesh)
    model = LM(cfg)
    micro = max(sp.global_batch // (cfg.grad_accum if sp.kind == "train"
                                    else 1), 1)
    aspec = sh.act_spec(plan, micro, decode=(sp.kind == "decode"),
                        seq=sp.seq_len)
    # SP boundary: tp archs gather seq to feed TP sublayers; no-TP archs
    # keep seq sharded end to end (weights are replicated -- gathering
    # would just replicate compute over the model axis)
    gspec = (sh.act_spec(plan, micro, decode=True) if cfg.tp
             else aspec)                             # seq gathered (SP edge)
    q_spec, kv_spec, grp_spec = sh.qkv_specs(plan, cfg, micro,
                                             seq=sp.seq_len)
    ctx = Ctx(cfg=cfg, attn_impl="xla", scan_impl="xla", act_spec=aspec,
              gather_spec=gspec, q_spec=q_spec, kv_spec=kv_spec,
              group_spec=grp_spec,
              moe_impl="shard_map" if cfg.n_experts else "ragged",
              mesh=mesh)
    param_specs = model.param_specs()
    p_sh = sh.tree_shardings(plan, param_specs)
    lspec = sh.layer_compute_specs(plan, param_specs["layers"])
    espec = (sh.layer_compute_specs(plan, param_specs["encoder"]["layers"])
             if cfg.encoder_layers else None)
    ctx = dc.replace(ctx, layer_param_specs=lspec, enc_param_specs=espec)

    if sp.kind == "train":
        opt = make_optimizer(cfg)
        step_fn = make_train_step(model, opt, ctx=ctx,
                                  grad_accum=cfg.grad_accum,
                                  grad_shardings=p_sh)
        state_shapes = sh.train_state_shapes(cfg, model)
        state_sh = sh.train_state_shardings(plan, cfg, param_specs)
        batch = batch_specs(cfg, sp.global_batch, sp.seq_len)
        batch_sh = sh.batch_tree_shardings(plan, batch)
        fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=0)
        return fn, (state_shapes, batch), mesh, plan

    params = specs_to_shapes(param_specs)
    if sp.kind == "prefill":
        pf = make_prefill_step(model, ctx=ctx, cache_len=sp.seq_len)
        cache_sh = sh.tree_shardings(
            plan, model.cache_specs(sp.global_batch, sp.seq_len))
        ins = input_specs(arch, shape)
        tok_sh = sh.batch_sharding(plan, sp.global_batch)
        in_sh = [p_sh, tok_sh] + ([tok_sh] if "frontend" in ins else [])
        args = [params, ins["tokens"]] + (
            [ins["frontend"]] if "frontend" in ins else [])
        fn = jax.jit(pf, in_shardings=tuple(in_sh),
                     out_shardings=(tok_sh, cache_sh))
        return fn, tuple(args), mesh, plan

    # decode
    from jax.sharding import PartitionSpec as P

    from repro.models import blocks as blk
    if cfg.n_kv_heads:
        # sequence-sharded KV cache -> shard_map flash-decode (chunking a
        # sharded S inside jit makes GSPMD reshard the cache per chunk)
        window = cfg.window if "local" in cfg.pattern else None
        kts = blk.attn_cache_specs(cfg, sp.global_batch, sp.seq_len,
                                   cfg.dtype, window=window)
        kspec = sh.spec_for(plan, kts["k"])
        if len(kspec) > 2 and kspec[2] == "model":
            ctx = dc.replace(ctx, decode_kv_specs=(
                P(kspec[0], None, None, None), kspec,
                P(kspec[0], "model")))
    dec = make_decode_step(model, ctx=ctx)
    ins = input_specs(arch, shape)
    cache_sh = sh.tree_shardings(
        plan, model.cache_specs(sp.global_batch, sp.seq_len))
    tok_sh = sh.batch_sharding(plan, sp.global_batch)
    fn = jax.jit(dec,
                 in_shardings=(p_sh, tok_sh, cache_sh, tok_sh),
                 out_shardings=(tok_sh, cache_sh), donate_argnums=2)
    args = (params, ins["tokens"], ins["cache"], ins["positions"])
    return fn, args, mesh, plan


def run_cell(arch: str, shape: str, mesh_kind: str,
             overrides: dict | None = None, tag: str = "",
             hlo_dir: str | None = None) -> dict:
    cfg = get_arch(arch)
    sp = SHAPES[shape]
    ok, reason = applicable(cfg, sp)
    cell = {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag}
    if not ok:
        return {**cell, "status": "skip", "reason": reason}
    multi = mesh_kind == "multi"
    n_chips = 512 if multi else 256
    t0 = time.time()
    try:
        fn, args, mesh, plan = build_cell(arch, shape, multi_pod=multi,
                                          overrides=overrides)
        with mesh:   # ambient mesh: with_sharding_constraint hints bind here
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        if hlo_dir:   # persist the artifact: analysis is replayable
            import gzip
            suffix = f"__{tag}" if tag else ""
            name = f"{arch}__{shape}__{mesh_kind}{suffix}.hlo.gz"
            with gzip.open(os.path.join(hlo_dir, name), "wt") as f:
                f.write(compiled.as_text())
        mf = rl.model_flops_estimate(cfg, sp.global_batch, sp.seq_len,
                                     sp.kind)
        roof = rl.analyze(compiled, n_chips=n_chips, model_flops=mf)
        return {**cell, "status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "fallbacks": sorted(set(plan.fallbacks)),
                "roofline": roof.to_json()}
    except Exception as e:  # noqa: BLE001 -- sweep must survive bad cells
        return {**cell, "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def all_cells(mesh_kinds) -> list[tuple[str, str, str]]:
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mk in mesh_kinds:
                cells.append((arch, shape, mk))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--override", default="",
                    help="JSON dict of ArchConfig overrides (perf sweeps)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args()
    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for arch, shape, mk in all_cells(kinds):
            ok, reason = applicable(get_arch(arch), SHAPES[shape])
            print(f"{arch:24s} {shape:12s} {mk:6s} "
                  f"{'ok' if ok else reason}")
        return 0

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        # subprocess per cell: isolates compile OOM/crash, bounds RAM
        import subprocess
        failures = 0
        for arch, shape, mk in all_cells(kinds):
            name = f"{arch}__{shape}__{mk}"
            path = os.path.join(args.out, name + ".json")
            if os.path.exists(path):
                st = json.load(open(path)).get("status")
                if st in ("ok", "skip"):
                    print(f"cached  {name}: {st}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk,
                   "--out", args.out]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
                if r.returncode != 0 and not os.path.exists(path):
                    json.dump({"arch": arch, "shape": shape, "mesh": mk,
                               "status": "error",
                               "error": (r.stderr or "")[-2000:]},
                              open(path, "w"), indent=1)
            except subprocess.TimeoutExpired:
                json.dump({"arch": arch, "shape": shape, "mesh": mk,
                           "status": "timeout"}, open(path, "w"), indent=1)
            res = json.load(open(path))
            status = res.get("status")
            failures += status not in ("ok", "skip")
            print(f"{time.time() - t0:7.1f}s {name}: {status}")
        return 1 if failures else 0

    overrides = json.loads(args.override) if args.override else None
    res = run_cell(args.arch, args.shape, args.mesh, overrides, args.tag,
                   hlo_dir=args.out)
    suffix = f"__{args.tag}" if args.tag else ""
    name = f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    with open(os.path.join(args.out, name), "w") as f:
        json.dump(res, f, indent=1)
    r = res.get("roofline", {})
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("roofline", "traceback")}, indent=1))
    if r:
        print(f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s "
              f"bottleneck={r['bottleneck']} "
              f"roofline_fraction={r['roofline_fraction']:.3f}")
        print("mem/device GB:",
              round(r["memory_analysis"]["peak_bytes"] / 2**30, 2))
    return 0 if res.get("status") in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())

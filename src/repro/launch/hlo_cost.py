"""Loop-aware cost model over compiled (post-SPMD) HLO text.

WHY.  ``compiled.cost_analysis()`` counts a ``while`` body ONCE, but our
stacks scan over layers (and attention scans over key chunks, grad-accum
over microbatches) -- so flops/bytes/collective traffic inside loops are
undercounted by the trip count (24-126x).  This module parses the HLO
text, rebuilds the computation call graph, extracts loop trip counts from
the ``while`` condition (compare-against-constant pattern emitted for
``lax.scan``/``fori_loop``), and accumulates:

  flops       -- 2 * prod(result_dims) * prod(contracting_dims) per dot,
                 multiplied through enclosing loops;
  bytes       -- operand + result bytes per materializing op (fusions count
                 their boundary only: internals are register/VMEM traffic);
  link_bytes  -- ring-model collective traffic (same models as roofline.py).

The result is the input to the roofline terms.  Validated against
hand-computed matmul counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
                    r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
                    r"([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+"
                       r"\[[0-9,]*\](?:\{[^}]*\})?))")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|calls|"
                        r"branch_computations)=\{?%?([\w\.\-,% ]+)\}?")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "conditional", "call", "iota",
               "after-all", "partition-id", "replica-id"}


def shape_elems(type_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    var: str
    type_str: str
    kind: str
    rest: str                    # operand list + attributes (raw tail)


@dataclasses.dataclass
class Comp:
    name: str
    ops: list[Op] = dataclasses.field(default_factory=list)
    shapes: dict[str, str] = dataclasses.field(default_factory=dict)
    param_order: list[str] = dataclasses.field(default_factory=list)


def parse(text: str) -> tuple[dict[str, Comp], str]:
    comps: dict[str, Comp] = {}
    entry = ""
    cur: Comp | None = None
    for line in text.splitlines():
        if cur is None:
            if "->" in line and "{" in line and "=" not in line.split("(")[0]:
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = Comp(m.group(1))
                    if line.strip().startswith("ENTRY"):
                        entry = cur.name
                    # bind parameter shapes from the signature (in order)
                    sig = line[line.index("("):]
                    for pname, ptype in _PARAM_RE.findall(sig):
                        cur.shapes[pname] = ptype
                        cur.param_order.append(pname)
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            var, type_str, kind, rest = m.groups()
            cur.shapes[var] = type_str
            cur.ops.append(Op(var, type_str, kind, rest))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _operands(rest: str) -> list[str]:
    depth = 0
    out = []
    for tok in re.finditer(r"[(),]|%[\w\.\-]+", rest):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            depth -= 1
            if depth < 0:
                break
        elif t.startswith("%") and depth >= 0:
            out.append(t[1:])
    return out


def trip_count(cond: Comp, comps: dict[str, "Comp"] | None = None) -> int:
    """Extract N from the compare-to-constant loop condition.

    The compare may live inside a fusion called from the condition; loop
    conditions are tiny, so "max integer constant reachable from the
    condition" is a safe and robust trip-count proxy (counted-down loops
    still carry the bound constant for the induction init)."""
    consts: list[int] = []
    comp_stack = [cond]
    seen = {cond.name}
    while comp_stack:
        c = comp_stack.pop()
        for op in c.ops:
            if op.kind == "constant":
                m = re.search(r"^\((-?\d+)\)", "(" + op.rest)
                if m:
                    consts.append(int(m.group(1)))
            elif op.kind == "fusion" and comps is not None:
                m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if m and m.group(1) in comps and m.group(1) not in seen:
                    seen.add(m.group(1))
                    comp_stack.append(comps[m.group(1)])
    return max(consts + [1])


def _dot_flops(op: Op, shapes: dict[str, str]) -> float:
    res = shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _operands(op.rest)
    if not m or not operands:
        return 2.0 * res            # unknown: treat as elementwise-ish
    lhs_shape = shape_dims(shapes.get(operands[0], ""))
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_shape):
            k *= lhs_shape[int(idx)]
    return 2.0 * res * k


def _coll_link_bytes(op: Op) -> float:
    nbytes = shape_bytes(op.type_str)
    mg = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
    if mg:
        g = int(mg.group(2))
    else:
        mg = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
        g = len(mg.group(1).split(",")) if mg else 2
    kind = op.kind.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(nbytes) * (g - 1)
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes) * (g - 1) / g      # all-gather / all-to-all


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    loops: list = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.link_bytes += other.link_bytes * mult
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v * mult


_SLICE_KINDS = {"dynamic-slice", "gather"}
_UPDATE_KINDS = {"dynamic-update-slice", "scatter"}


def _sliced_bytes(comp: Comp, pname: str, depth: int = 0) -> float | None:
    """If fusion parameter ``pname`` is consumed ONLY by slice/gather (or
    is the pass-through buffer of a dynamic-update-slice), return the
    bytes actually touched; None -> consumed elementwise (charge full).

    ``convert``/``bitcast`` consumers are transparent: XLA:CPU promotes
    bf16 in-place updates to f32 (convert -> DUS -> convert), which on TPU
    is a native bf16 DUS -- charging the promotion converts would bill the
    whole stacked KV cache once per decode step (qwen3: 0.65 TB/token)."""
    touched = 0.0
    consumed = False
    for op in comp.ops:
        ops_ = None
        if ("%" + pname) in op.rest:
            ops_ = _operands(op.rest)
        if not ops_ or pname not in ops_:
            continue
        consumed = True
        if op.kind in _SLICE_KINDS:
            touched += 2.0 * shape_bytes(op.type_str)
        elif op.kind in _UPDATE_KINDS and ops_ and ops_[0] == pname:
            upd = (shape_bytes(comp.shapes[ops_[1]])
                   if len(ops_) > 1 and ops_[1] in comp.shapes else 0)
            touched += 3.0 * (upd or shape_bytes(op.type_str))
        elif op.kind in ("convert", "bitcast", "copy") and depth < 3:
            sub = _sliced_bytes(comp, op.var, depth + 1)
            if sub is None:
                return None
            touched += sub
        else:
            return None
    return touched if consumed else 0.0


def _fusion_result_bytes(op: Op, called: Comp | None) -> float:
    """Fusion result charge; a dynamic-update-slice ROOT writes its update
    region in place (the full stacked-KV-cache 'result' is an alias, not
    traffic).  Handles tuple roots of several updates."""
    if called is None or not called.ops:
        return float(shape_bytes(op.type_str))
    by_var = {o.var: o for o in called.ops}

    def through_converts(r: Op) -> Op:
        seen = 0
        while r.kind in ("convert", "bitcast", "copy") and seen < 3:
            ops_ = _operands(r.rest)
            if not ops_ or ops_[0] not in by_var:
                break
            r = by_var[ops_[0]]
            seen += 1
        return r

    root = through_converts(called.ops[-1])
    roots = [root]
    if root.kind == "tuple":
        roots = [through_converts(by_var[v])
                 for v in _operands(root.rest) if v in by_var]
    total = 0.0
    for r in roots:
        if r.kind in _UPDATE_KINDS:
            ops_ = _operands(r.rest)
            upd = (shape_bytes(called.shapes[ops_[1]])
                   if len(ops_) > 1 and ops_[1] in called.shapes else 0)
            total += 3.0 * (upd or shape_bytes(r.type_str))
        else:
            total += shape_bytes(r.type_str)
    return total


def _comp_cost(name: str, comps: dict[str, Comp], memo: dict,
               flops_only: bool = False) -> Cost:
    key = (name, flops_only)
    if key in memo:
        return memo[key]
    c = Cost()
    memo[key] = c                     # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return c
    for op in comp.ops:
        kind = op.kind.replace("-start", "")
        if kind == "while":
            callees = dict(re.findall(r"(body|condition)=%?([\w\.\-]+)",
                                      op.rest))
            body, cond = callees.get("body"), callees.get("condition")
            trips = trip_count(comps[cond], comps) if cond in comps else 1
            if body:
                sub = _comp_cost(body, comps, memo, flops_only)
                c.add(sub, trips)
                c.loops.append((body, trips))
                c.loops.extend((b, t * trips) for b, t in sub.loops)
            continue
        if kind == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            called = comps.get(m.group(1)) if m else None
            if called is not None:
                # dots can live inside CPU loop fusions: flops recurse
                c.add(_comp_cost(called.name, comps, memo, flops_only=True))
            if flops_only:
                continue
            # fusion boundary traffic: result + operands, EXCEPT operands
            # consumed only by slices/gathers inside the fusion -- those
            # touch slice-sized bytes, not the whole (often loop-carried
            # stacked) buffer.  Charging full size there overcounts by
            # the trip count.
            b = _fusion_result_bytes(op, called)
            operands = _operands(op.rest)
            for i, oname in enumerate(operands):
                full = shape_bytes(comp.shapes.get(oname, ""))
                if called is not None and i < len(called.param_order):
                    pname = called.param_order[i]
                    touched = _sliced_bytes(called, pname)
                    if touched is not None:
                        b += min(full, touched) if full else touched
                        continue
                b += full
            c.bytes += b
            continue
        elif kind in ("call", "conditional", "async-start"):
            for grp in _CALLEE_RE.findall(op.rest):
                for callee in re.split(r"[ ,%]+", grp):
                    if callee in comps:
                        c.add(_comp_cost(callee, comps, memo, flops_only))
            continue
        elif kind == "dot":
            c.flops += _dot_flops(op, comp.shapes)
        elif kind == "convolution":
            c.flops += 2.0 * shape_elems(op.type_str) * 4  # small convs only
        elif kind in _COLLECTIVES:
            lb = _coll_link_bytes(op)
            c.link_bytes += lb
            c.coll_by_op[kind] = c.coll_by_op.get(kind, 0.0) + lb
        if flops_only or kind in _SKIP_BYTES:
            continue
        res_b = shape_bytes(op.type_str)
        if kind in ("dynamic-slice", "gather"):
            # traffic = the slice/rows actually touched, NOT the whole
            # operand -- counting the full stacked-params buffer once per
            # scan trip would overcount by the trip count (quadratic in
            # layers for the layer scan)
            c.bytes += 2.0 * res_b
            continue
        if kind in ("dynamic-update-slice", "scatter"):
            # read-modify-write of the updated region; the pass-through
            # buffer is aliased in place
            upd = 0
            ops_ = _operands(op.rest)
            if len(ops_) >= 2 and ops_[1] in comp.shapes:
                upd = shape_bytes(comp.shapes[ops_[1]])
            c.bytes += 3.0 * (upd or res_b)
            continue
        b = res_b
        for o in _operands(op.rest):
            if o in comp.shapes:
                b += shape_bytes(comp.shapes[o])
        c.bytes += b
    return c


def hlo_cost(hlo_text: str) -> Cost:
    comps, entry = parse(hlo_text)
    if not entry:
        # pick the computation that no one calls (fallback)
        called = set()
        for comp in comps.values():
            for op in comp.ops:
                called.update(x for grp in _CALLEE_RE.findall(op.rest)
                              for x in re.split(r"[ ,%]+", grp))
        roots = [n for n in comps if n not in called]
        entry = roots[0] if roots else next(iter(comps))
    return _comp_cost(entry, comps, {})


def loop_breakdown(hlo_text: str) -> tuple[Cost, list[dict]]:
    """(total cost, per-loop contributions) -- the dry-run 'profile'.

    Each row is one while body with its effective trip count (nested trips
    multiplied through); inner loops also appear inside their outer body's
    cost, so rows overlap -- read as 'total attributable to this loop'."""
    comps, entry = parse(hlo_text)
    memo: dict = {}
    total = _comp_cost(entry, comps, memo)
    rows = []
    for body, trips in total.loops:
        c = _comp_cost(body, comps, memo)
        rows.append({"body": body, "trips": trips,
                     "flops": c.flops * trips, "bytes": c.bytes * trips,
                     "link_bytes": c.link_bytes * trips})
    rows.sort(key=lambda r: -r["bytes"])
    return total, rows

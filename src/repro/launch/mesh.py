"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and
then calls it.

Target hardware (roofline constants): TPU v5e-class chip.
"""
from __future__ import annotations

from repro.compat import make_mesh as make_mesh_compat  # noqa: F401

# hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link direction


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU tests (1 real device)."""
    return make_mesh_compat(shape, axes)

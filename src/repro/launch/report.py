"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
tables.  ``python -m repro.launch.report [--dir experiments/dryrun]``."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(d: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if r.get("tag"):
            continue
        rows.append(r)
    return rows


def fmt_cell(r: dict) -> dict:
    roof = r["roofline"]
    m = roof["memory_analysis"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "peak_GB": m["peak_bytes"] / 2**30,
        "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
        "collective_s": roof["collective_s"],
        "bottleneck": roof["bottleneck"],
        "model_flops": roof["model_flops"],
        "useful": roof["useful_flops_ratio"],
        "frac": roof["roofline_fraction"],
    }


def markdown(rows: list[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | peak GB/dev | compute s | memory s | "
           "collective s | bottleneck | MODEL_FLOPS | useful ratio | "
           "roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"SKIP (sub-quadratic gate) | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['status'].upper()} | — | — | — |")
            continue
        c = fmt_cell(r)
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['peak_GB']:.2f} | "
            f"{c['compute_s']:.4g} | {c['memory_s']:.4g} | "
            f"{c['collective_s']:.4g} | {c['bottleneck']} | "
            f"{c['model_flops']:.3g} | {c['useful']:.3f} | "
            f"{c['frac']:.4f} |")
    return "\n".join(out)


def summary(rows: list[dict]) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    bad = [r for r in rows if r["status"] not in ("ok", "skip")]
    lines = [f"cells: {len(ok)} ok, {len(skip)} skip (long_500k x "
             f"full-attention archs), {len(bad)} failed"]
    for r in bad:
        lines.append(f"  FAILED {r['arch']} {r['shape']} {r['mesh']}: "
                     f"{str(r.get('error'))[:120]}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments",
        "dryrun"))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--reanalyze", action="store_true")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.dir)
    rows = load(args.dir)
    print(summary(rows))
    print()
    print(markdown(rows, args.mesh))




def reanalyze(d: str) -> int:
    """Re-run the roofline analysis over saved .hlo.gz artifacts (no
    recompilation) -- used after cost-model changes."""
    import gzip

    from repro.configs import SHAPES, get_arch
    from repro.launch import roofline as rl
    from repro.launch.hlo_cost import hlo_cost

    n = 0
    for f in sorted(glob.glob(os.path.join(d, "*.hlo.gz"))):
        base = os.path.basename(f)[:-7]
        parts = base.split("__")
        if len(parts) < 3:
            continue
        arch, shape, mesh_kind = parts[0], parts[1], parts[2]
        jf = os.path.join(d, base + ".json")
        if not os.path.exists(jf):
            continue
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        txt = gzip.open(f, "rt").read()
        cfg = get_arch(arch)
        sp = SHAPES[shape]
        cost = hlo_cost(txt)
        n_chips = 512 if mesh_kind == "multi" else 256
        mf = rl.model_flops_estimate(cfg, sp.global_batch, sp.seq_len,
                                     sp.kind)
        roof = rec["roofline"]
        roof["flops_per_device"] = cost.flops
        roof["bytes_per_device"] = cost.bytes
        roof["link_bytes_per_device"] = cost.link_bytes
        from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16)
        roof["compute_s"] = cost.flops / PEAK_FLOPS_BF16
        roof["memory_s"] = cost.bytes / HBM_BW
        roof["collective_s"] = cost.link_bytes / ICI_BW
        terms = {"compute": roof["compute_s"], "memory": roof["memory_s"],
                 "collective": roof["collective_s"]}
        roof["bottleneck"] = max(terms, key=terms.get)
        worst = max(terms.values())
        ideal = (mf / n_chips) / PEAK_FLOPS_BF16
        roof["roofline_fraction"] = ideal / worst if worst else 0.0
        roof["useful_flops_ratio"] = ((mf / n_chips) / cost.flops
                                      if cost.flops else 0.0)
        roof["collectives"]["by_op"] = cost.coll_by_op
        roof["collectives"]["loops"] = [list(x) for x in cost.loops]
        json.dump(rec, open(jf, "w"), indent=1)
        n += 1
    print(f"re-analyzed {n} cells")
    return n

if __name__ == "__main__":
    main()

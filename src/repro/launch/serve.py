"""End-to-end serving driver: batched requests through the factorized
engine.

Demonstrates the paper's technique live: a workload where many requests
share a system prompt gets its shared prefix prefilled ONCE per distinct
prefix (compact RDF molecule), then per-request suffixes attach via the
instanceOf pointer; the planner's #Edges-in-bytes objective declines to
share for all-distinct workloads (Fig. 7 overhead case).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced

``--graph-queries N`` serves the OTHER side of the paper instead: star
BGP queries answered directly on the compacted RDF graph through the
``serving.GraphQueryService`` endpoint -- N requests (molecule lookups,
variable-object arms, misses) run under both the ``factorized`` and
``raw`` strategies, binding sets are asserted identical, and the
latency of each strategy is reported.

    PYTHONPATH=src python -m repro.launch.serve --graph-queries 64

``--bgp N`` exercises the full BGP engine: N multi-star queries (cross-
star joins over ``procedure``/``observationResult``, pushed-down value
filters) served under the cost-based planner and both fixed strategies,
with binding sets asserted identical across all three.

    PYTHONPATH=src python -m repro.launch.serve --bgp 24
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax

from repro.configs import get_arch, reduced
from repro.models.blocks import Ctx
from repro.models.lm import LM
from repro.serving import (GraphQueryRequest, GraphQueryService,
                           PREFIX_POLICIES, Engine, Request)


def serve_graph_queries(n_requests: int, *, n_observations: int = 600,
                        seed: int = 0, backend: str = "host") -> dict:
    """Compact a sensor graph and serve star queries over G'."""
    from repro.api import Compactor
    from repro.data.synthetic import SensorGraphSpec, generate

    store = generate(SensorGraphSpec(n_observations=n_observations,
                                     seed=seed))
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    term = store.dict.term
    rng = np.random.default_rng(seed)

    reqs = []
    classes = list(fg.tables.items())
    for i in range(n_requests):
        cid, t = classes[i % len(classes)]
        row = t.objects[int(rng.integers(0, t.n_molecules))]
        kind = i % 4
        if kind == 0:       # full molecule lookup (all arms ground)
            arms = tuple((term(p), term(int(o)))
                         for p, o in zip(t.props, row))
        elif kind == 1:     # partial arms + one variable object
            arms = ((term(t.props[0]), term(int(row[0]))),
                    (term(t.props[-1]), None))
        elif kind == 2:     # miss: an object term from another column
            arms = ((term(t.props[0]), term(int(row[-1]))),)
        else:               # unconstrained variable scan over one arm
            arms = ((term(t.props[0]), None),)
        reqs.append((arms, term(cid)))

    results = {}
    timings = {}
    for strategy in ("raw", "factorized"):
        svc = GraphQueryService(fg, backend=backend)
        # the raw baseline queries the expanded graph: build it outside
        # the timer so the printed latency is query time, not expansion
        svc.engine.raw_store
        for rid, (arms, cterm) in enumerate(reqs):
            svc.submit(GraphQueryRequest(rid=rid, arms=arms,
                                         class_term=cterm,
                                         strategy=strategy))
        t0 = time.perf_counter()
        results[strategy] = svc.run()
        timings[strategy] = (time.perf_counter() - t0) * 1e3
    for rid in range(len(reqs)):
        a = results["raw"][rid]
        b = results["factorized"][rid]
        assert sorted(a.subjects) == sorted(b.subjects), rid
        assert a.n_rows == b.n_rows, rid
    n_rows = sum(r.n_rows for r in results["raw"].values())
    print(f"graph-query endpoint: {len(reqs)} star queries, "
          f"{n_rows} bindings -- raw {timings['raw']:.1f} ms, "
          f"factorized {timings['factorized']:.1f} ms "
          f"(identical binding sets)")
    return {"n_requests": len(reqs), "n_rows": n_rows,
            "raw_ms": timings["raw"],
            "factorized_ms": timings["factorized"]}


def serve_sharded_queries(n_requests: int, *, n_shards: int = 4,
                          n_observations: int = 600, seed: int = 0,
                          backend: str = "host") -> dict:
    """Serve star queries through the sharded fan-out path and assert
    binding-set parity with the replicated endpoint.

    Partitions the sensor graph across ``n_shards`` shards, runs
    shard-local detection, and drains the same request wave through a
    :class:`~repro.serving.ShardedQueryService` (per-shard wave queues,
    parallel drain, concat merge) and a replicated
    :class:`~repro.serving.GraphQueryService` over the unsharded
    compaction -- Def. 4.10 says the answers cannot differ, and the
    printed cross-shard traffic shows what the fan-out actually moved
    (binding sets only; molecule tables never leave their shard).
    """
    from repro.api import CompactionPlanner
    from repro.data.synthetic import SensorGraphSpec, generate
    from repro.dist.graph import ShardedFactorizedGraph
    from repro.serving import ShardedQueryService

    store = generate(SensorGraphSpec(n_observations=n_observations,
                                     seed=seed))
    snap, _ = CompactionPlanner("gfsp", "host").run(store.copy())
    sharded = ShardedFactorizedGraph.partition(store.copy(), n_shards)
    sharded.detect_all(backend="host")
    assert sharded.digest() == snap.digest(), \
        "sharded detection broke digest parity"

    fg = snap.fgraph
    term = store.dict.term
    rng = np.random.default_rng(seed)
    reqs = []
    classes = list(fg.tables.items())
    for i in range(n_requests):
        cid, t = classes[i % len(classes)]
        row = t.objects[int(rng.integers(0, t.n_molecules))]
        if i % 3 == 0:      # full molecule lookup
            arms = tuple((term(p), term(int(o)))
                         for p, o in zip(t.props, row))
        elif i % 3 == 1:    # partial ground + variable object
            arms = ((term(t.props[0]), term(int(row[0]))),
                    (term(t.props[-1]), None))
        else:               # classless variable scan (coordinator path)
            reqs.append((((term(t.props[0]), None),), None))
            continue
        reqs.append((arms, term(cid)))

    results, timings = {}, {}
    for name, svc in (("replicated", GraphQueryService(fg, backend=backend)),
                      ("sharded", ShardedQueryService(sharded,
                                                      backend=backend))):
        for rid, (arms, cterm) in enumerate(reqs):
            svc.submit(GraphQueryRequest(rid=rid, arms=arms,
                                         class_term=cterm))
        t0 = time.perf_counter()
        results[name] = svc.run()
        timings[name] = (time.perf_counter() - t0) * 1e3
    for rid in range(len(reqs)):
        a, b = results["replicated"][rid], results["sharded"][rid]
        assert sorted(zip(a.subjects, a.var_objects)) \
            == sorted(zip(b.subjects, b.var_objects)), rid
    n_rows = sum(r.n_rows for r in results["sharded"].values())
    print(f"sharded endpoint: {len(reqs)} star queries over "
          f"{n_shards} shards, {n_rows} bindings -- replicated "
          f"{timings['replicated']:.1f} ms, sharded "
          f"{timings['sharded']:.1f} ms, cross-shard "
          f"{sharded.traffic['query_bytes']} B (identical binding sets)")
    return {"n_requests": len(reqs), "n_rows": n_rows,
            "n_shards": n_shards,
            "replicated_ms": timings["replicated"],
            "sharded_ms": timings["sharded"],
            "query_bytes": sharded.traffic["query_bytes"]}


def serve_bgp_queries(n_requests: int, *, n_observations: int = 600,
                      seed: int = 0, backend: str = "host") -> dict:
    """Serve multi-star BGP queries through the cost-based BGP engine.

    Each request is a join-bearing BGP (observation-sensor over
    ``procedure``, observation-measurement over ``observationResult``,
    or a filtered single star); the wave runs once per strategy
    (``auto`` / ``raw`` / ``factorized``) and the binding sets are
    asserted identical -- the planner may pick a different per-star mix
    per query, but the answers cannot differ (Def. 4.10).
    """
    from repro.api import Compactor
    from repro.data.synthetic import (MEASUREMENT, OBSERVATION,
                                      P_MODEL, P_PROCEDURE, P_RESULT,
                                      P_TIME, P_VALUE, SENSOR,
                                      SensorGraphSpec, generate)
    from repro.serving import BGPQueryRequest

    store = generate(SensorGraphSpec(n_observations=n_observations,
                                     seed=seed,
                                     include_sensor_metadata=True))
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    rng = np.random.default_rng(seed)

    def make(rid: int) -> BGPQueryRequest:
        kind = rid % 3
        if kind == 0:       # obs-sensor molecule-to-molecule join
            stars = (("?o", ((P_PROCEDURE, "?s"),
                             (P_TIME, f"time/{rng.integers(0, 50)}")),
                      OBSERVATION),
                     ("?s", ((P_MODEL, f"model/{rng.integers(0, 3)}"),),
                      SENSOR))
            return BGPQueryRequest(rid=rid, stars=stars)
        if kind == 1:       # 3-star chain with a pushed-down filter
            stars = (("?o", ((P_PROCEDURE, "?s"), (P_RESULT, "?m")),
                      OBSERVATION),
                     ("?s", ((P_MODEL, f"model/{rng.integers(0, 3)}"),),
                      SENSOR),
                     ("?m", ((P_VALUE, "?v"),), MEASUREMENT))
            return BGPQueryRequest(
                rid=rid, stars=stars,
                filters=(("?v", "<", f"val/{rng.integers(2, 9)}"),))
        stars = (("?m", ((P_VALUE, "?v"),), MEASUREMENT),)
        return BGPQueryRequest(
            rid=rid, stars=stars,
            filters=(("?v", "==", f"val/{rng.integers(0, 6)}"),))

    reqs = [make(rid) for rid in range(n_requests)]
    results, timings = {}, {}
    for strategy in ("raw", "factorized", "auto"):
        svc = GraphQueryService(fg, backend=backend)
        svc.engine.raw_store    # build the baseline outside the timer
        for r in reqs:
            svc.submit(dataclasses.replace(r, strategy=strategy))
        t0 = time.perf_counter()
        results[strategy] = svc.run()
        timings[strategy] = (time.perf_counter() - t0) * 1e3
    planner_mix = {"raw": 0, "factorized": 0}
    for rid in range(n_requests):
        a, b, c = (results[s][rid] for s in ("raw", "factorized", "auto"))
        assert sorted(a.rows) == sorted(b.rows) == sorted(c.rows), rid
        for s in c.strategies:
            planner_mix[s] += 1
    n_rows = sum(r.n_rows for r in results["auto"].values())
    print(f"bgp endpoint: {n_requests} multi-star queries, "
          f"{n_rows} bindings -- raw {timings['raw']:.1f} ms, "
          f"factorized {timings['factorized']:.1f} ms, "
          f"planner {timings['auto']:.1f} ms "
          f"(identical binding sets; planner mix {planner_mix})")
    return {"n_requests": n_requests, "n_rows": n_rows,
            "raw_ms": timings["raw"],
            "factorized_ms": timings["factorized"],
            "auto_ms": timings["auto"], "planner_mix": planner_mix}


def serve_online(n_batches: int = 20, *, n_observations: int = 80,
                 seed: int = 0, backend: str = "device",
                 assert_gates: bool = True, durable_root: str | None = None,
                 chaos_seed: int | None = None) -> dict:
    """Soak the online compaction service with mixed ingest batches.

    Drives ``n_batches`` mixed insert/delete batches through an
    :class:`~repro.online.OnlineCompactionService` alongside a
    no-recompaction twin (same planner, ``auto_redetect=False``) over
    the same edit stream, and checks the service-level guarantees the
    CI soak gates on:

    * the write-ahead queue fully drains on both services;
    * re-detection is warm after the soak: a forced re-detect of every
      factorized class adds ZERO new sweep traces (all bucket shapes
      were compiled during the run) and leaves the graph digest
      unchanged;
    * recompaction pays, monotonically: every re-detection pass leaves
      the realized edge count no higher than it found it (the planner's
      realized-edges guard), the service's triple count (the graph-wide
      Def. 4.8 edge total) never exceeds the no-recompaction baseline,
      and the final advantage strictly beats the initial one -- the
      drift cohort's singleton churn decays the baseline while the
      service's re-detected SP absorbs it;
    * incremental == batch: the final snapshot is digest-identical to a
      from-scratch ``Compactor`` run on the net graph.

    Returns the ``drift`` matrix recorded per batch (recompaction
    latency, queue depth, dirty-class count, edge counts) plus the
    metrics-channel summaries -- ``benchmarks/run.py`` embeds this dict
    in ``BENCH_fsp.json`` and ``check_snapshot.py`` gates it.

    **Durable mode** (``durable_root``): the service journals every
    batch to an on-disk WAL and checkpoints under ``durable_root``.  If
    the root already holds a valid checkpoint (this process is a
    RESTART after a crash) the soak does not re-run the workload:
    it recovers, drains whatever the journal preserved, and gates the
    recovered state -- queue fully drained, digest identical to a
    from-scratch ``Compactor`` over the recovered net graph, recovery
    metrics recorded.  ``chaos_seed`` arms a seeded kill-mode
    :class:`~repro.dist.fault.FaultPlan` (SIGKILL at a random injection
    site) on FRESH durable runs only; the CI soak runs once expecting
    exit 137, then reruns the same command to prove recovery.
    """
    from repro.api import Compactor
    from repro.core import sweep as core_sweep
    from repro.data.synthetic import SensorGraphSpec, generate
    from repro.dist.fault import FaultPlan
    from repro.online import OnlineCompactionService
    from repro.online.recovery import has_state

    svc_kw = dict(detector="gfsp", backend=backend,
                  raw_residue_threshold=6, support_drift_threshold=4,
                  max_backoff=1)

    if durable_root is not None and has_state(durable_root):
        # RESTART path: the journal + checkpoint are the workload now
        svc = OnlineCompactionService.durable(durable_root, **svc_kw)
        reps = svc.drain()
        rec = svc.last_recovery.as_dict() if svc.last_recovery else {}
        net = svc.snapshot.fgraph.expand()
        comp = Compactor(detector="gfsp", backend=backend)
        comp.run(net)
        result = {
            "recovered": True,
            "drained": svc.queue.depth == 0,
            "batches_drained_after_recovery": len(reps),
            "batch_parity_digest": comp.snapshot.digest()
            == svc.snapshot.digest(),
            "recovery": rec,
            "metrics": svc.metrics_summary(),
        }
        svc.close()
        if assert_gates:
            assert result["drained"], "recovered queue not drained"
            assert result["batch_parity_digest"], \
                "recovered state != from-scratch compaction of its net graph"
            assert rec.get("checkpoint_bytes", 0) > 0, rec
        print(f"online soak (recovery): checkpoint step "
              f"{rec.get('checkpoint_step')} "
              f"({rec.get('checkpoint_bytes', 0)} bytes), "
              f"{rec.get('mints_replayed', 0)} mints + "
              f"{rec.get('batches_pending', 0)} batches replayed in "
              f"{rec.get('replay_ms', 0.0):.1f} ms, "
            f"{len(reps)} drained post-recovery, gates "
            f"{'PASS' if assert_gates else 'recorded'}")
        return result

    store = generate(SensorGraphSpec(n_observations=n_observations,
                                     seed=seed))
    # max_backoff=1: the drift cohort's re-plan is rejected until enough
    # singletons accumulate, and a deep rejection backoff would push the
    # eventually-accepted pass past this soak's short horizon
    if durable_root is not None:
        plan = (None if chaos_seed is None
                else FaultPlan.seeded(chaos_seed, mode="kill"))
        svc = OnlineCompactionService.durable(
            durable_root, store, checkpoint_every=3,
            checkpoint_async=False, fault_plan=plan, **svc_kw)
    else:
        svc = OnlineCompactionService(store, **svc_kw)
    base = OnlineCompactionService(store, detector="gfsp", backend=backend,
                                   auto_redetect=False)
    rng = np.random.default_rng(seed)
    term = store.dict.term
    type_term = term(store.TYPE)
    classes = list(svc.snapshot.fgraph.tables.items())
    # complete entity templates per class (every class property, §4.3
    # assumption (a)) sampled from the ORIGINAL store, so inserted
    # entities are candidates for whatever SP a re-detection picks
    full_props = {cid: np.asarray(store.class_properties(cid))
                  for cid, _ in classes}
    full_mats = {cid: store.object_matrix(cid, full_props[cid])[1]
                 for cid, _ in classes}
    inserted: list[str] = []

    def build_batch(b: int):
        """One mixed batch: complete entities cloning existing rows
        (absorb into existing molecules), a drift cohort of SINGLETON
        tuples (shared objects on every property except one current-SP
        column, a unique object there), and -- every third batch --
        deletes of earlier inserts (support decay + payoff-sweep
        pressure).  The singletons are the decay source: without
        re-detection each one mints a sub-payoff molecule (Fig. 7
        overhead, +1 edge apiece, forever), while re-detection shifts
        the class SP off the churning column and absorbs the whole
        cohort into one high-support molecule."""
        cid, t = classes[b % len(classes)]
        cterm = term(cid)
        fprops = full_props[cid]
        mat = full_mats[cid]
        pterms = [term(int(p)) for p in fprops]
        uniq_col = int(np.searchsorted(fprops, t.props[-1]))
        ins = []
        for j in range(3):          # reuse: clone a full original row
            row = mat[int(rng.integers(0, mat.shape[0]))]
            s = f"e:online/{b}/reuse{j}"
            ins.append((s, type_term, cterm))
            ins += [(s, p, term(int(o))) for p, o in zip(pterms, row)]
            inserted.append(s)
        for j in range(4):          # drift: singleton tuples pile up
            s = f"e:online/{b}/drift{j}"
            ins.append((s, type_term, cterm))
            ins += [(s, p, f"o:uniq/{b}/{j}" if k == uniq_col
                     else f"o:drift/{cterm}/{k}")
                    for k, p in enumerate(pterms)]
            inserted.append(s)
        dels = []
        if b % 3 == 2 and len(inserted) > 6:
            dels = [inserted.pop(int(rng.integers(0, len(inserted))))
                    for _ in range(4)]
        return ins, dels

    drift_rows = []
    for b in range(n_batches):
        ins, dels = build_batch(b)
        for s in (svc, base):
            s.submit(inserts=ins)
            if dels:
                s.submit(delete_entities=dels)
        reps = svc.drain()
        base.drain()
        red = next((r.redetect for r in reps if r.redetect is not None),
                   None)
        drift_rows.append({
            "batch": b,
            "latency_ms": sum(r.latency_ms for r in reps),
            "queue_depth": svc.queue.depth,
            "n_dirty": len(red.considered) if red else 0,
            "redetect_ms": red.exec_time_ms if red else 0.0,
            "redetect_descents": red.descents if red else 0,
            "redetect_rejected": bool(red.rejected) if red else False,
            "redetect_edges_before": red.edges_before if red else 0,
            "redetect_edges_after": red.edges_after if red else 0,
            "edges": svc.snapshot.n_triples,
            "edges_baseline": base.snapshot.n_triples,
        })

    # warm-retrace gate: every sweep shape the service will ever need
    # was compiled during the soak, so a forced full re-detect must add
    # zero traces -- and must not change the graph it re-derives
    digest_before = svc.snapshot.digest()
    core_sweep.reset_trace_stats()
    svc.redetect(sorted(svc.snapshot.fgraph.tables))
    warm_retraces = core_sweep.trace_count()
    digest_after = svc.snapshot.digest()

    net = svc.snapshot.fgraph.expand()
    comp = Compactor(detector="gfsp", backend=backend)
    comp.run(net)
    gaps = [r["edges"] - r["edges_baseline"] for r in drift_rows]
    result = {
        "n_batches": n_batches,
        "drained": svc.queue.depth == 0 and base.queue.depth == 0,
        "warm_redetect_traces": int(warm_retraces),
        "redetect_digest_stable": digest_after == digest_before,
        "never_above_baseline": all(g <= 0 for g in gaps),
        "redetect_monotone": all(
            r["redetect_edges_after"] <= r["redetect_edges_before"]
            for r in drift_rows if r["n_dirty"]),
        "final_gap": gaps[-1], "first_gap": gaps[0],
        "n_redetects": sum(1 for r in drift_rows if r["n_dirty"]),
        "swap_count": svc.swap_count,
        "batch_parity_digest": comp.snapshot.digest()
        == svc.snapshot.digest(),
        "rows": drift_rows,
        "metrics": svc.metrics_summary(),
    }
    if durable_root is not None:
        result["durable"] = True
        result["wal_segments"] = svc.wal.n_segments
        svc.checkpoint(wait=True)
        svc.close()
    if assert_gates:
        assert result["drained"], "ingest queue not drained"
        assert result["warm_redetect_traces"] == 0, \
            f"re-detection retraced warm shapes: {warm_retraces}"
        assert result["redetect_digest_stable"], \
            "forced re-detect changed graph semantics"
        assert result["never_above_baseline"], \
            f"service edge count exceeded no-recompaction baseline: {gaps}"
        assert result["redetect_monotone"], \
            "a re-detection pass increased the realized edge count"
        assert result["final_gap"] < result["first_gap"], \
            f"recompaction never beat the no-recompaction twin: {gaps}"
        assert result["batch_parity_digest"], \
            "incremental != from-scratch compaction of the net graph"
    print(f"online soak: {n_batches} batches, "
          f"{result['n_redetects']} re-detections, "
          f"{result['swap_count']} swaps, "
          f"edge advantage {gaps[0]} -> {gaps[-1]} vs no-recompaction, "
          f"warm retraces {warm_retraces}, gates "
          f"{'PASS' if assert_gates else 'recorded'}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--shared-frac", type=float, default=0.75,
                    help="fraction of the prompt shared across requests")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="both",
                    choices=("both",) + PREFIX_POLICIES.names(),
                    help="prefix-compaction policy; 'both' runs every "
                         "registered policy and asserts identical tokens")
    ap.add_argument("--graph-queries", type=int, default=0,
                    help="serve N star BGP queries over a compacted RDF "
                         "graph instead of the LM path")
    ap.add_argument("--bgp", type=int, default=0,
                    help="serve N multi-star BGP queries (joins + "
                         "filters) through the cost-based planner")
    ap.add_argument("--sharded", type=int, default=0, metavar="N",
                    help="serve star queries over an N-shard partitioned "
                         "graph (fan-out path) and assert parity with "
                         "the replicated endpoint")
    ap.add_argument("--graph-backend", default="host",
                    choices=("host", "device"),
                    help="molecule-match backend for --graph-queries")
    ap.add_argument("--online", action="store_true",
                    help="soak the online compaction service (mixed "
                         "ingest batches + drift-tracked re-detection) "
                         "and gate the service-level guarantees")
    ap.add_argument("--online-batches", type=int, default=20,
                    help="ingest batches for --online")
    ap.add_argument("--durable", default=None, metavar="DIR",
                    help="durable root for --online: WAL + checkpoints "
                         "under DIR; with existing state, recover and "
                         "gate instead of re-running the workload")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="arm a seeded kill-mode fault plan (SIGKILL at "
                         "a random injection site) on a fresh --durable "
                         "run; restart the same command to recover")
    args = ap.parse_args(argv)

    if args.online:
        return serve_online(args.online_batches, seed=args.seed,
                            durable_root=args.durable,
                            chaos_seed=args.chaos)

    if args.sharded:
        return serve_sharded_queries(
            max(args.graph_queries, 24), n_shards=args.sharded,
            seed=args.seed, backend=args.graph_backend)

    if args.bgp:
        return serve_bgp_queries(args.bgp, seed=args.seed,
                                 backend=args.graph_backend)

    if args.graph_queries:
        return serve_graph_queries(args.graph_queries, seed=args.seed,
                                   backend=args.graph_backend)

    cfg = reduced(get_arch(args.arch)) if args.reduced \
        else get_arch(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    shared_len = int(args.prompt_len * args.shared_frac)
    system_prompt = rng.integers(1, cfg.vocab_size, (shared_len,),
                                 dtype=np.int32)
    prompts = [np.concatenate([
        system_prompt,
        rng.integers(1, cfg.vocab_size,
                     (args.prompt_len - shared_len,), dtype=np.int32)])
        for _ in range(args.requests)]

    policies = (PREFIX_POLICIES.names() if args.policy == "both"
                else (args.policy,))
    results = {}
    shared_plan = None
    for policy in policies:
        eng = Engine(model, params, cache_len=args.prompt_len + args.max_new,
                     chunk=32, policy=policy)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=args.max_new))
        t0 = time.time()
        outs = eng.run()
        dt = time.time() - t0
        results[policy] = outs
        plan = eng.last_plan
        extra = ""
        if eng.policy.plan and plan is not None:
            if shared_plan is None:
                shared_plan = plan
            verb = "kv_savings" if eng.policy.share else "would_save"
            extra = (f" molecules={plan.molecule_tokens.shape[0]} "
                     f"depth={plan.depth_chunks * plan.chunk} "
                     f"{verb}={plan.savings_pct:.1f}%")
        print(f"{policy:10s}: {len(outs)} requests x {args.max_new} tokens "
              f"in {dt:.2f}s{extra}")
    first = results[policies[0]]
    assert all(r == first for r in results.values()), \
        "every prefix policy must produce identical tokens"
    if len(policies) > 1:
        print("all policies produce identical outputs: information "
              "preserved (Def. 4.10)")
    return {"outputs": first,
            "plan_savings_pct": shared_plan.savings_pct
            if shared_plan else 0.0}


if __name__ == "__main__":
    main()

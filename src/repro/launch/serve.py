"""End-to-end serving driver: batched requests through the factorized
engine.

Demonstrates the paper's technique live: a workload where many requests
share a system prompt gets its shared prefix prefilled ONCE per distinct
prefix (compact RDF molecule), then per-request suffixes attach via the
instanceOf pointer; the planner's #Edges-in-bytes objective declines to
share for all-distinct workloads (Fig. 7 overhead case).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced

``--graph-queries N`` serves the OTHER side of the paper instead: star
BGP queries answered directly on the compacted RDF graph through the
``serving.GraphQueryService`` endpoint -- N requests (molecule lookups,
variable-object arms, misses) run under both the ``factorized`` and
``raw`` strategies, binding sets are asserted identical, and the
latency of each strategy is reported.

    PYTHONPATH=src python -m repro.launch.serve --graph-queries 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_arch, reduced
from repro.models.blocks import Ctx
from repro.models.lm import LM
from repro.serving import (GraphQueryRequest, GraphQueryService,
                           PREFIX_POLICIES, Engine, Request)


def serve_graph_queries(n_requests: int, *, n_observations: int = 600,
                        seed: int = 0, backend: str = "host") -> dict:
    """Compact a sensor graph and serve star queries over G'."""
    from repro.api import Compactor
    from repro.data.synthetic import SensorGraphSpec, generate

    store = generate(SensorGraphSpec(n_observations=n_observations,
                                     seed=seed))
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    term = store.dict.term
    rng = np.random.default_rng(seed)

    reqs = []
    classes = list(fg.tables.items())
    for i in range(n_requests):
        cid, t = classes[i % len(classes)]
        row = t.objects[int(rng.integers(0, t.n_molecules))]
        kind = i % 4
        if kind == 0:       # full molecule lookup (all arms ground)
            arms = tuple((term(p), term(int(o)))
                         for p, o in zip(t.props, row))
        elif kind == 1:     # partial arms + one variable object
            arms = ((term(t.props[0]), term(int(row[0]))),
                    (term(t.props[-1]), None))
        elif kind == 2:     # miss: an object term from another column
            arms = ((term(t.props[0]), term(int(row[-1]))),)
        else:               # unconstrained variable scan over one arm
            arms = ((term(t.props[0]), None),)
        reqs.append((arms, term(cid)))

    results = {}
    timings = {}
    for strategy in ("raw", "factorized"):
        svc = GraphQueryService(fg, backend=backend)
        # the raw baseline queries the expanded graph: build it outside
        # the timer so the printed latency is query time, not expansion
        svc.engine.raw_store
        for rid, (arms, cterm) in enumerate(reqs):
            svc.submit(GraphQueryRequest(rid=rid, arms=arms,
                                         class_term=cterm,
                                         strategy=strategy))
        t0 = time.perf_counter()
        results[strategy] = svc.run()
        timings[strategy] = (time.perf_counter() - t0) * 1e3
    for rid in range(len(reqs)):
        a = results["raw"][rid]
        b = results["factorized"][rid]
        assert sorted(a.subjects) == sorted(b.subjects), rid
        assert a.n_rows == b.n_rows, rid
    n_rows = sum(r.n_rows for r in results["raw"].values())
    print(f"graph-query endpoint: {len(reqs)} star queries, "
          f"{n_rows} bindings -- raw {timings['raw']:.1f} ms, "
          f"factorized {timings['factorized']:.1f} ms "
          f"(identical binding sets)")
    return {"n_requests": len(reqs), "n_rows": n_rows,
            "raw_ms": timings["raw"],
            "factorized_ms": timings["factorized"]}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--shared-frac", type=float, default=0.75,
                    help="fraction of the prompt shared across requests")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="both",
                    choices=("both",) + PREFIX_POLICIES.names(),
                    help="prefix-compaction policy; 'both' runs every "
                         "registered policy and asserts identical tokens")
    ap.add_argument("--graph-queries", type=int, default=0,
                    help="serve N star BGP queries over a compacted RDF "
                         "graph instead of the LM path")
    ap.add_argument("--graph-backend", default="host",
                    choices=("host", "device"),
                    help="molecule-match backend for --graph-queries")
    args = ap.parse_args(argv)

    if args.graph_queries:
        return serve_graph_queries(args.graph_queries, seed=args.seed,
                                   backend=args.graph_backend)

    cfg = reduced(get_arch(args.arch)) if args.reduced \
        else get_arch(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    shared_len = int(args.prompt_len * args.shared_frac)
    system_prompt = rng.integers(1, cfg.vocab_size, (shared_len,),
                                 dtype=np.int32)
    prompts = [np.concatenate([
        system_prompt,
        rng.integers(1, cfg.vocab_size,
                     (args.prompt_len - shared_len,), dtype=np.int32)])
        for _ in range(args.requests)]

    policies = (PREFIX_POLICIES.names() if args.policy == "both"
                else (args.policy,))
    results = {}
    shared_plan = None
    for policy in policies:
        eng = Engine(model, params, cache_len=args.prompt_len + args.max_new,
                     chunk=32, policy=policy)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=args.max_new))
        t0 = time.time()
        outs = eng.run()
        dt = time.time() - t0
        results[policy] = outs
        plan = eng.last_plan
        extra = ""
        if eng.policy.plan and plan is not None:
            if shared_plan is None:
                shared_plan = plan
            verb = "kv_savings" if eng.policy.share else "would_save"
            extra = (f" molecules={plan.molecule_tokens.shape[0]} "
                     f"depth={plan.depth_chunks * plan.chunk} "
                     f"{verb}={plan.savings_pct:.1f}%")
        print(f"{policy:10s}: {len(outs)} requests x {args.max_new} tokens "
              f"in {dt:.2f}s{extra}")
    first = results[policies[0]]
    assert all(r == first for r in results.values()), \
        "every prefix policy must produce identical tokens"
    if len(policies) > 1:
        print("all policies produce identical outputs: information "
              "preserved (Def. 4.10)")
    return {"outputs": first,
            "plan_savings_pct": shared_plan.savings_pct
            if shared_plan else 0.0}


if __name__ == "__main__":
    main()

"""End-to-end serving driver: batched requests through the factorized
engine.

Demonstrates the paper's technique live: a workload where many requests
share a system prompt gets its shared prefix prefilled ONCE per distinct
prefix (compact RDF molecule), then per-request suffixes attach via the
instanceOf pointer; the planner's #Edges-in-bytes objective declines to
share for all-distinct workloads (Fig. 7 overhead case).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_arch, reduced
from repro.models.blocks import Ctx
from repro.models.lm import LM
from repro.serving import PREFIX_POLICIES, Engine, Request


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--shared-frac", type=float, default=0.75,
                    help="fraction of the prompt shared across requests")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="both",
                    choices=("both",) + PREFIX_POLICIES.names(),
                    help="prefix-compaction policy; 'both' runs every "
                         "registered policy and asserts identical tokens")
    args = ap.parse_args(argv)

    cfg = reduced(get_arch(args.arch)) if args.reduced \
        else get_arch(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    shared_len = int(args.prompt_len * args.shared_frac)
    system_prompt = rng.integers(1, cfg.vocab_size, (shared_len,),
                                 dtype=np.int32)
    prompts = [np.concatenate([
        system_prompt,
        rng.integers(1, cfg.vocab_size,
                     (args.prompt_len - shared_len,), dtype=np.int32)])
        for _ in range(args.requests)]

    policies = (PREFIX_POLICIES.names() if args.policy == "both"
                else (args.policy,))
    results = {}
    shared_plan = None
    for policy in policies:
        eng = Engine(model, params, cache_len=args.prompt_len + args.max_new,
                     chunk=32, policy=policy)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=args.max_new))
        t0 = time.time()
        outs = eng.run()
        dt = time.time() - t0
        results[policy] = outs
        plan = eng.last_plan
        extra = ""
        if eng.policy.plan and plan is not None:
            if shared_plan is None:
                shared_plan = plan
            verb = "kv_savings" if eng.policy.share else "would_save"
            extra = (f" molecules={plan.molecule_tokens.shape[0]} "
                     f"depth={plan.depth_chunks * plan.chunk} "
                     f"{verb}={plan.savings_pct:.1f}%")
        print(f"{policy:10s}: {len(outs)} requests x {args.max_new} tokens "
              f"in {dt:.2f}s{extra}")
    first = results[policies[0]]
    assert all(r == first for r in results.values()), \
        "every prefix policy must produce identical tokens"
    if len(policies) > 1:
        print("all policies produce identical outputs: information "
              "preserved (Def. 4.10)")
    return {"outputs": first,
            "plan_savings_pct": shared_plan.savings_pct
            if shared_plan else 0.0}


if __name__ == "__main__":
    main()

"""End-to-end training driver.

Runs REAL steps (CPU: reduced config; TPU: full config) with the whole
production substrate engaged: deterministic sharded data pipeline,
AdamW/adafactor, async atomic checkpointing with retention, crash/resume
(--preempt-at simulates a SIGTERM mid-run; rerunning with the same
--ckpt-dir resumes from the newest checkpoint), and optional int8
gradient compression on the pod boundary.

``--compress-grads`` routes through the ``compress_fn`` hook of
``make_train_step`` and engages ONLY when the gradient reduction
actually crosses a pod (DCN) boundary (``--pods > 1``): intra-pod
gradients ride ICI and stay uncompressed -- the seed wrapped the whole
optimizer, quantizing every reduction regardless of the link it used.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer, latest_step
from repro.configs import get_arch, reduced
from repro.data.lm_pipeline import LMPipeline, PipelineSpec
from repro.dist.compression import make_pod_compress_fn
from repro.models.blocks import Ctx
from repro.models.lm import LM
from repro.train import make_optimizer, make_train_step
from repro.train.train_step import TrainState, init_train_state


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--preempt-at", type=int, default=-1,
                    help="simulate preemption after this step")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8-compress the pod-boundary gradient "
                         "reduction (no-op unless --pods > 1)")
    ap.add_argument("--pods", type=int, default=1,
                    help="pods the gradient all-reduce crosses; intra-pod "
                         "gradients are never compressed")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg, grad_accum=1)
    if args.seq % max(cfg.ssm_chunk, 1):
        cfg = dataclasses.replace(cfg, ssm_chunk=min(cfg.ssm_chunk,
                                                     args.seq))
    model = LM(cfg)
    ctx = Ctx(cfg=cfg)
    opt = make_optimizer(cfg, base_lr=args.lr, warmup=10,
                         total=max(args.steps, 100))
    compress_fn = None
    if args.compress_grads:
        compress_fn = make_pod_compress_fn(n_pods=args.pods)
        print("grad compression:",
              "pod-boundary int8" if compress_fn is not None
              else "off (no pod boundary to compress)")
    step_fn = jax.jit(make_train_step(model, opt, ctx=ctx,
                                      grad_accum=cfg.grad_accum,
                                      compress_fn=compress_fn))
    pipe = LMPipeline(PipelineSpec(cfg.vocab_size, args.seq, args.batch,
                                   seed=args.seed))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if ckpt is not None and latest_step(args.ckpt_dir) is not None:
        like = jax.eval_shape(
            lambda: init_train_state(model, opt, jax.random.PRNGKey(0)))
        state, start = ckpt.restore(like)
        print(f"resumed from step {start}")
    else:
        state = init_train_state(model, opt,
                                 jax.random.PRNGKey(args.seed))

    frontend = None
    if cfg.frontend != "none":
        fd = cfg.frontend_dim or cfg.d_model
        frontend = jnp.zeros((args.batch, cfg.frontend_tokens, fd),
                             jnp.dtype(cfg.dtype))

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 pipe.batch_at(step).items()}
        if frontend is not None:
            batch["frontend"] = frontend
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"{(time.time() - t0):6.1f}s")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, step + 1)
        if args.preempt_at >= 0 and step + 1 >= args.preempt_at:
            if ckpt is not None:
                ckpt.wait()
            print(f"PREEMPTED at step {step + 1} (simulated)")
            return {"final_loss": losses[-1], "steps_done": step + 1,
                    "losses": losses, "preempted": True}
    if ckpt is not None:
        ckpt.save(state, args.steps)
        ckpt.wait()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"final_loss": losses[-1], "first_loss": losses[0],
            "steps_done": args.steps, "losses": losses,
            "preempted": False,
            "grad_compression": ("pod-boundary"
                                 if compress_fn is not None else "off")}


if __name__ == "__main__":
    main()

"""Roofline terms from a compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, all in seconds (lower bound on
step time if that resource were the only one):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = HLO_bytes_per_device / HBM_BW
  collective = link_bytes_per_device / ICI_BW

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
flops / bytes (verified empirically: a (32,64)x(64,128) matmul over a
(2,4) mesh reports B/2 * F/4 flops).  Collective bytes are NOT in
cost_analysis; we parse the post-partitioning HLO and apply standard ring
cost models per op (bytes that cross links, per device):

  all-gather          result_bytes * (g-1)/g
  all-reduce          2 * result_bytes * (g-1)/g     (reduce-scatter + AG)
  reduce-scatter      result_bytes * (g-1)            (operand = result * g)
  all-to-all          result_bytes * (g-1)/g
  collective-permute  result_bytes

where ``g`` is the replica-group size parsed from the op.  Shapes in the
partitioned module are already per-shard, so the sums are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)          # [n_groups,group_size]<=...
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)             # {{0,1,2,...},{...}}
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    ops: dict[str, int]
    link_bytes: float                # per device, ring-model
    result_bytes: float              # raw sum of collective result sizes
    by_op: dict[str, float]

    def to_json(self):
        return dataclasses.asdict(self)


def collective_stats(hlo_text: str) -> CollectiveStats:
    ops: dict[str, int] = {}
    by_op: dict[str, float] = {}
    link = 0.0
    raw = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = _shape_bytes(type_str)
        g = _group_size(line)
        if op == "all-reduce":
            moved = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            moved = float(nbytes) * (g - 1)
        elif op == "collective-permute":
            moved = float(nbytes)
        else:                          # all-gather / all-to-all
            moved = float(nbytes) * (g - 1) / g
        ops[op] = ops.get(op, 0) + 1
        by_op[op] = by_op.get(op, 0.0) + moved
        link += moved
        raw += nbytes
    return CollectiveStats(ops=ops, link_bytes=link, result_bytes=raw,
                           by_op=by_op)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    link_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float               # 6ND (train) / 2ND (serve), global
    useful_flops_ratio: float        # model_flops/chips / hlo_flops
    roofline_fraction: float         # ideal_compute / max(all terms)
    collectives: dict[str, Any]
    memory_analysis: dict[str, float]

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_chips: int, model_flops: float) -> Roofline:
    from . import hlo_cost as hc
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    cost = hc.hlo_cost(txt)           # loop-aware (see hlo_cost.py docstring)
    flops = cost.flops
    nbytes = cost.bytes
    cs = CollectiveStats(
        ops=collective_stats(txt).ops, link_bytes=cost.link_bytes,
        result_bytes=0.0, by_op=cost.coll_by_op)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    coll_s = cs.link_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)  # type: ignore[arg-type]
    ideal = (model_flops / n_chips) / PEAK_FLOPS_BF16
    worst = max(terms.values())
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": float(mem.argument_size_in_bytes),
        "output_bytes": float(mem.output_size_in_bytes),
        "temp_bytes": float(mem.temp_size_in_bytes),
        "alias_bytes": float(mem.alias_size_in_bytes),
        "peak_bytes": float(mem.argument_size_in_bytes
                            + mem.output_size_in_bytes
                            + mem.temp_size_in_bytes
                            - mem.alias_size_in_bytes),
    }
    return Roofline(
        flops_per_device=flops, bytes_per_device=nbytes,
        link_bytes_per_device=cs.link_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=(model_flops / n_chips) / flops if flops else 0.0,
        roofline_fraction=ideal / worst if worst else 0.0,
        collectives={**cs.to_json(),
                     "loops": [list(x) for x in cost.loops],
                     "cost_analysis_flops_once": float(ca.get("flops", 0.0)),
                     "cost_analysis_bytes_once":
                         float(ca.get("bytes accessed", 0.0))},
        memory_analysis=mem_d)


def model_flops_estimate(cfg, batch: int, seq: int, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D (train) or 2*N*D (forward), N = active params.

    For decode, D = batch tokens (one step) and attention adds
    2 * layers * kv_bytes-equivalent reads -- we report the matmul-model
    number (the standard MFU convention) and let useful_flops_ratio carry
    the gap.
    """
    n = cfg.n_active_params
    tokens = batch * seq
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * batch            # decode: one token per sequence

"""Tiny name -> strategy registry shared by the strategy extension points
(``repro.api`` detectors and execution backends, ``repro.serving`` prefix
policies).

Kept OUTSIDE the ``repro.api`` package on purpose: importing any
``repro.api`` submodule executes the package ``__init__`` and with it the
full detection pipeline (gSpan miner, jax backends), which lightweight
consumers like ``repro.serving`` must not pay for.
"""
from __future__ import annotations


class Registry:
    def __init__(self, kind: str) -> None:
        self.kind = kind
        self._items: dict[str, object] = {}

    def register(self, name: str, obj) -> None:
        self._items[name] = obj

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._items))

    def get(self, name: str):
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{', '.join(self.names())}") from None

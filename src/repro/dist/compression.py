"""Int8 gradient compression with error feedback.

Same compact-representation trade as the source paper's factorized
stars: spend a cheap encode/decode to move 4x fewer bytes.  Cross-pod
gradient all-reduces ride a 16 GB/s DCN link while in-pod ICI does
50 GB/s per direction, so the pod-boundary reduction is the one worth
compressing.

Quantization is per-row (last-axis absmax -> one f32 scale per row);
round-to-nearest keeps the error within ``absmax / 254`` per element.
The part rounding throws away is NOT dropped: ``compressed`` keeps an
error-feedback residual per parameter and re-injects it the next step
(Seide et al. 2014), which is what keeps tiny-gradient directions alive
-- without it, any gradient under half a quantum is silently zero
forever and the optimizer stalls on flat loss surfaces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer

_EPS = 1e-12


def quantize_int8(g) -> tuple[jax.Array, jax.Array]:
    """``g`` (f32/bf16) -> (int8 codes, f32 per-row scale).

    Scale is ``absmax / 127`` over the last axis (keepdims), so
    ``dequantize_int8(*quantize_int8(g))`` is within half a quantum of
    ``g`` elementwise.
    """
    gf = jnp.asarray(g, jnp.float32)
    if gf.ndim == 0:
        gf = gf[None]
        absmax = jnp.abs(gf)
    else:
        absmax = jnp.max(jnp.abs(gf), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, _EPS) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    if jnp.ndim(g) == 0:
        return q[0], scale[0]
    return q, scale


def dequantize_int8(q, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual):
    """One encode/decode round over a gradient tree.

    Returns ``(decoded, new_residual)``: what the all-reduce would carry
    (already decoded, since the sum of int8 shards is itself exactly
    representable as f32) and the per-leaf rounding error to feed back.
    """
    def one(g, r):
        total = jnp.asarray(g, jnp.float32) + r
        deq = dequantize_int8(*quantize_int8(total))
        dec = deq.astype(g.dtype)
        # residual against what the caller actually receives: for bf16
        # grads the f32->bf16 cast error must also feed back, or it
        # biases every step
        return dec, total - dec.astype(jnp.float32)
    g_leaves, treedef = jax.tree.flatten(grads)
    out = [one(g, r) for g, r in zip(g_leaves, jax.tree.leaves(residual))]
    decoded = jax.tree.unflatten(treedef, [d for d, _ in out])
    new_res = jax.tree.unflatten(treedef, [r for _, r in out])
    return decoded, new_res


def make_pod_compress_fn(mesh=None, *, n_pods: int | None = None,
                         pod_axis: str = "pod"):
    """Gradient codec for the pod-boundary (DCN) reduction -- and ONLY
    that boundary.

    Returns ``None`` when no pod boundary exists (no mesh, no ``pod``
    axis, or a single pod): intra-pod gradients ride the 50 GB/s ICI
    and must stay uncompressed -- compressing them buys nothing and
    costs precision.  With a real boundary, returns a ``compress_fn``
    for the ``make_train_step`` hook: one int8 encode/decode round per
    leaf, exactly the payload the cross-pod all-reduce would carry
    (the sum of int8 shards is representable in f32, so decoding before
    the optimizer is equivalent to decoding after the DCN hop).

    The hook is stateless by design -- error feedback needs per-step
    state, which lives in the :func:`compressed` optimizer wrapper;
    compose both when EF is wanted on top of boundary-only compression.
    """
    if n_pods is None:
        if mesh is None:
            return None
        names = tuple(getattr(mesh, "axis_names", ()))
        if pod_axis not in names:
            return None
        shape = getattr(mesh, "devices", None)
        sizes = dict(zip(names, shape.shape)) if shape is not None else {}
        n_pods = int(sizes.get(pod_axis, 1))
    if n_pods <= 1:
        return None

    def compress_fn(grads):
        return jax.tree.map(
            lambda g: dequantize_int8(*quantize_int8(g)).astype(g.dtype),
            grads)

    return compress_fn


def compressed(opt: Optimizer) -> Optimizer:
    """Wrap an optimizer so its incoming gradients pass through int8
    quantization with error feedback.  State: ``{"inner": <wrapped
    state>, "ef": <residual tree, f32, param-shaped>}`` -- the residual
    shards exactly like the parameters, so plans derived for params
    apply verbatim.
    """
    def init(params):
        return {"inner": opt.init(params),
                "ef": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        decoded, new_ef = compress_tree(grads, state["ef"])
        new_params, new_inner = opt.update(decoded, state["inner"],
                                           params, step)
        return new_params, {"inner": new_inner, "ef": new_ef}

    return Optimizer(init, update)

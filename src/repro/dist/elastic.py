"""Elastic re-meshing: shrink the mesh after device loss and keep going.

Policy: after losing chips, rebuild an ``(data, model)`` mesh over the
survivors.  The model axis wants to stay a power of two (TP collectives
degrade badly on odd rings) and no wider than 16 (one ICI torus edge),
so ``choose_mesh_shape`` gives the model axis the largest power-of-two
divisor of the survivor count up to 16 and hands the rest to data.
Checkpoints are layout-free (host numpy), so restore-with-shardings onto
the new mesh is the whole recovery story -- see ``Checkpointer.restore``.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as make_mesh_compat

_MAX_MODEL = 16


def choose_mesh_shape(n_devices: int) -> tuple[int, int]:
    """``n_devices`` -> (data, model); always satisfies
    ``data * model == n_devices``."""
    if n_devices <= 0:
        raise ValueError(f"need at least one device, got {n_devices}")
    model = 1
    while model * 2 <= _MAX_MODEL and n_devices % (model * 2) == 0:
        model *= 2
    return n_devices // model, model


def remesh(n_devices: int | None = None, *, tp_pref: int | None = None,
           devices=None):
    """Build a fresh ``(data, model)`` mesh over the surviving devices.

    ``devices`` is the survivor list (e.g. ``healthy`` filtered through
    the fault monitor); without it the prefix of ``jax.devices()`` is
    used, which is only correct when the *tail* of the fleet died.
    ``tp_pref`` pins the model-axis width when it divides the survivor
    count (keep TP degree stable across a shrink when possible);
    otherwise falls back to ``choose_mesh_shape``.
    """
    if devices is None:
        devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    if n_devices > len(devices):
        raise ValueError(
            f"asked for {n_devices} devices, only {len(devices)} alive")
    if tp_pref and n_devices % tp_pref == 0:
        shape = (n_devices // tp_pref, tp_pref)
    else:
        shape = choose_mesh_shape(n_devices)
    return make_mesh_compat(shape, ("data", "model"),
                            devices=devices[:n_devices])

"""Sharding planner: logical tensor axes -> mesh axes, per config.

The model code annotates every tensor with *logical* axes
(``TSpec.axes``: "vocab", "embed", "ff", "heads", "experts", "rnn",
"batch", "seq", "hd", "layers", ...).  ``make_plan`` reads the mesh and
an ``ArchConfig`` and produces a :class:`Plan`; ``spec_for`` then maps a
``TSpec`` to a concrete ``PartitionSpec`` under three rules:

1. **TP rule** -- "vocab"/"ff"/"heads"/"experts"/"rnn" shard over the
   ``model`` axis when ``cfg.tp``; "embed" shards over ``data`` when
   ``cfg.fsdp`` (ZeRO-3 style); "seq" may take ``model`` when
   ``cfg.seq_shard`` (flash-decode style sequence sharding).
2. **Divisibility fallback** -- a dim whose size does not divide its
   mesh axis replicates instead, and the decision is recorded in
   ``plan.fallbacks`` so the dry-run can report it.  Sharding a
   non-divisible dim would force GSPMD padding + resharding on every
   touch.
3. **One-mesh-axis-per-tensor rule** -- within one tensor each mesh
   axis is claimed at most once, first (leftmost) logical dim wins.
   Double-booking an axis is a GSPMD error; the left-to-right order
   encodes the priority ladder (e.g. KV-cache "heads" > "seq" > "hd").

Batch dims use the DP ladder (``batch_axes_for``): the widest rung of
``("pod", "data", "model")`` whose total size divides the batch, giving
up "model" first (it is the TP axis when ``cfg.tp``) and "pod" second,
so plain "data" sharding survives the smallest batches.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import TSpec

# logical axes that ride the TP ("model") mesh axis, in no particular
# order -- per-tensor priority is the order of the dims in the TSpec
_TP_AXES = ("vocab", "ff", "heads", "experts", "rnn")


@dataclasses.dataclass
class Plan:
    """Resolved layout policy for one (config, mesh) pair."""
    cfg: Any
    mesh: Any
    axis_sizes: dict[str, int]
    tp: bool                      # model axis reserved for tensor parallel
    fsdp: bool                    # params/opt-state sharded over data
    seq_shard: bool               # activations may shard seq over model
    dp_axes: tuple[str, ...]      # mesh axes available for batch sharding
    ladder: tuple[tuple[str, ...], ...]   # DP rungs, widest first
    fallbacks: list[str] = dataclasses.field(default_factory=list)

    @property
    def model_axis(self) -> str | None:
        return "model" if self.tp and "model" in self.axis_sizes else None

    def size(self, axis: str) -> int:
        return self.axis_sizes.get(axis, 1)

    def note_fallback(self, msg: str) -> None:
        if msg not in self.fallbacks:
            self.fallbacks.append(msg)


def make_plan(cfg, mesh) -> Plan:
    """Build a plan from any mesh-like object exposing ``.devices``
    (ndarray) and ``.axis_names`` -- real ``jax.sharding.Mesh`` or a test
    fake; the rule logic itself is device-free."""
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    tp = bool(cfg.tp) and "model" in sizes
    dp = tuple(n for n in names if not (tp and n == "model"))

    # DP ladder: sacrifice "model" first, then "pod"; "data" dies last
    rungs = [dp]
    remaining = list(dp)
    for drop in ("model", "pod"):
        if drop in remaining:
            remaining = [a for a in remaining if a != drop]
            rungs.append(tuple(remaining))
    if rungs[-1]:
        rungs.append(())
    return Plan(cfg=cfg, mesh=mesh, axis_sizes=sizes, tp=tp,
                fsdp=bool(cfg.fsdp), seq_shard=bool(cfg.seq_shard),
                dp_axes=dp, ladder=tuple(rungs))


# ---------------------------------------------------------------------------
# batch ladder
# ---------------------------------------------------------------------------

def batch_axes_for(plan: Plan, batch: int) -> tuple[str, ...]:
    """Widest DP rung whose device count divides ``batch``."""
    for rung in plan.ladder:
        n = 1
        for a in rung:
            n *= plan.size(a)
        if n and batch % n == 0:
            return rung
    return ()


def _batch_entry(plan: Plan, batch: int):
    """PartitionSpec entry for a batch dim: str | tuple | None."""
    axes = batch_axes_for(plan, batch)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


# ---------------------------------------------------------------------------
# tensor specs
# ---------------------------------------------------------------------------

def _candidates(plan: Plan, logical: str | None) -> tuple[str, ...]:
    if logical in _TP_AXES and plan.model_axis:
        return (plan.model_axis,)
    if logical == "embed" and plan.fsdp and "data" in plan.axis_sizes:
        return ("data",)
    if logical == "seq" and plan.seq_shard and plan.model_axis:
        return (plan.model_axis,)
    if logical == "hd" and plan.model_axis:   # last resort (see TSpec doc)
        return (plan.model_axis,)
    return ()


def spec_for(plan: Plan, tspec: TSpec) -> P:
    """Map one ``TSpec`` to a ``PartitionSpec`` under the plan's rules."""
    axes = tspec.axes or (None,) * len(tspec.shape)
    used: set[str] = set()
    entries: list = []
    for dim, logical in zip(tspec.shape, axes):
        if logical == "batch":
            entry = _batch_entry(plan, dim)
            picked = entry if isinstance(entry, tuple) else (
                (entry,) if entry else ())
            if any(a in used for a in picked):
                entry = None
            else:
                used.update(picked)
            entries.append(entry)
            continue
        entry = None
        for cand in _candidates(plan, logical):
            if cand in used:
                continue               # one-mesh-axis-per-tensor rule
            if dim % plan.size(cand) == 0:
                entry = cand
                used.add(cand)
                break
            if logical != "hd":        # hd replicas are free, stay quiet
                plan.note_fallback(
                    f"{logical}: {dim} % {cand}={plan.size(cand)} != 0 "
                    f"-> replicated")
        entries.append(entry)
    return P(*entries)


def tree_shardings(plan: Plan, spec_tree):
    """TSpec tree -> NamedSharding tree (requires a real mesh)."""
    return jax.tree.map(
        lambda s: NamedSharding(plan.mesh, spec_for(plan, s)), spec_tree,
        is_leaf=lambda x: isinstance(x, TSpec))


# ---------------------------------------------------------------------------
# activations / attention
# ---------------------------------------------------------------------------

def act_spec(plan: Plan, batch: int, *, seq: int | None = None,
             decode: bool = False) -> P:
    """(B, T, D) residual-stream spec.  Sequence takes the model axis for
    seq-sharded TP archs on divisible lengths; decode (T=1) and uneven
    lengths replicate T."""
    b = _batch_entry(plan, batch)
    t = None
    if not decode and seq and plan.seq_shard and plan.model_axis:
        if seq % plan.size(plan.model_axis) == 0:
            t = plan.model_axis
        else:
            plan.note_fallback(
                f"seq: {seq} % {plan.model_axis}="
                f"{plan.size(plan.model_axis)} != 0 -> replicated")
    return P(b, t, None)


def qkv_specs(plan: Plan, cfg, batch: int, *, seq: int | None = None
              ) -> tuple[P, P, P]:
    """Specs for head-major attention tensors.

    Returns ``(q, kv, grouped)`` for layouts ``(B, H, T, hd)``,
    ``(B, Hkv, T, hd)`` and ``(B, Hkv, G, T, hd)`` (G = Hq/Hkv).

    The KV head count owns the layout decision: when it divides the
    model axis, q/kv/grouped all pin heads to ``model``.  When it does
    not (GQA kv=8 on a 16-way axis), pinning q head-major anyway would
    fight the grouped layout with per-chunk all-to-alls, so q/kv stay
    replicated over heads and the grouped tensor sheds TP onto its
    group dim, then its seq dim, then gives up.
    """
    b = _batch_entry(plan, batch)
    m = plan.model_axis
    kv_heads = cfg.n_kv_heads
    heads = cfg.n_heads
    if m and kv_heads and kv_heads % plan.size(m) == 0 \
            and heads % plan.size(m) == 0:
        return (P(b, m, None, None), P(b, m, None, None),
                P(b, m, None, None, None))
    if m and kv_heads:
        bad = (f"kv={kv_heads}" if kv_heads % plan.size(m)
               else f"q={heads}")
        plan.note_fallback(
            f"heads: {bad} % {m}={plan.size(m)} != 0 "
            f"-> q/kv heads replicated")
    q = P(b, None, None, None)
    kv = P(b, None, None, None)
    group = heads // kv_heads if kv_heads else 0
    if m and group and group % plan.size(m) == 0:
        grp = P(b, None, m, None, None)
    elif m and seq and seq % plan.size(m) == 0:
        grp = P(b, None, None, m, None)      # q-seq fallback
    else:
        grp = P(b, None, None, None, None)
    return q, kv, grp


# ---------------------------------------------------------------------------
# optimizer state
# ---------------------------------------------------------------------------

def opt_state_specs(cfg, param_specs):
    """TSpec tree for the optimizer state, structurally identical to
    ``make_optimizer(cfg).init(params)`` (same dict keys, same leaf
    order, same shapes) -- the dry-run zips the two trees, so any drift
    silently misaligns ``in_shardings``.

    Moments inherit the parameter's logical axes verbatim (ZeRO-3 by
    construction); adafactor's factored row/col statistics drop the
    reduced dim's axis.
    """
    is_ts = lambda x: isinstance(x, TSpec)  # noqa: E731
    if cfg.optimizer == "adafactor":
        def factored(p: TSpec):
            axes = p.axes or (None,) * len(p.shape)
            if len(p.shape) >= 2:
                return {"vr": TSpec(p.shape[:-1], "float32", axes[:-1],
                                    init="zeros"),
                        "vc": TSpec(p.shape[:-2] + p.shape[-1:], "float32",
                                    axes[:-2] + axes[-1:], init="zeros")}
            return {"v": TSpec(p.shape, "float32", axes, init="zeros")}
        return {"f": jax.tree.map(factored, param_specs, is_leaf=is_ts)}

    def moment(p: TSpec):
        return TSpec(p.shape, cfg.opt_state_dtype,
                     p.axes or (None,) * len(p.shape), init="zeros")
    return {"m": jax.tree.map(moment, param_specs, is_leaf=is_ts),
            "v": jax.tree.map(moment, param_specs, is_leaf=is_ts)}


# ---------------------------------------------------------------------------
# launcher helpers (dry-run wiring)
# ---------------------------------------------------------------------------

def _strip_layer_dim(s: TSpec) -> TSpec:
    if s.axes and s.axes[0] == "layers":
        return TSpec(s.shape[1:], s.dtype, s.axes[1:], s.init)
    return s


def layer_compute_specs(plan: Plan, layer_specs):
    """Per-layer ``PartitionSpec`` hint tree for the scan body (the scan
    strips the leading "layers" dim before the hints apply)."""
    if isinstance(layer_specs, (list, tuple)):
        return [layer_compute_specs(plan, l) for l in layer_specs]
    return jax.tree.map(
        lambda s: spec_for(plan, _strip_layer_dim(s)), layer_specs,
        is_leaf=lambda x: isinstance(x, TSpec))


def batch_sharding(plan: Plan, batch: int) -> NamedSharding:
    """Sharding for a batch-leading tensor.  Deliberately a rank-1
    prefix spec: the same sharding serves (B,) sampled tokens, (B, T)
    prompts and (B, 1) decode steps (trailing dims replicate)."""
    return NamedSharding(plan.mesh, P(_batch_entry(plan, batch)))


def batch_tree_shardings(plan: Plan, batch_tree):
    """Shardings for a batch dict: leading dim over the DP ladder, the
    rest replicated (tokens/labels/mask are tiny next to activations)."""
    def of(leaf):
        b = _batch_entry(plan, leaf.shape[0]) if leaf.ndim else None
        return NamedSharding(plan.mesh,
                             P(b, *(None,) * max(leaf.ndim - 1, 0)))
    return jax.tree.map(of, batch_tree)


def train_state_shapes(cfg, model):
    """ShapeDtypeStruct TrainState mirroring ``init_train_state``."""
    from repro.models.common import specs_to_shapes
    from repro.train.train_step import TrainState

    param_specs = model.param_specs()
    params = specs_to_shapes(param_specs)
    opt = specs_to_shapes(opt_state_specs(cfg, param_specs))
    return TrainState(params, opt, jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(plan: Plan, cfg, param_specs):
    """NamedSharding TrainState matching ``train_state_shapes``."""
    from repro.train.train_step import TrainState

    return TrainState(
        tree_shardings(plan, param_specs),
        tree_shardings(plan, opt_state_specs(cfg, param_specs)),
        NamedSharding(plan.mesh, P()))

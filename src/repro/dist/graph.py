"""Sharded ``FactorizedGraph``: partition the compact form across a mesh.

Every multi-device path so far shards only the sweep *math* -- triples,
molecule tables and the dictionary stay replicated on host.  This module
partitions the graph itself, exploiting exactly the structure the
factorized form already has:

* **typed entities partition by class** (molecule tables + instanceOf
  CSR become shard-local): every row whose subject carries a ``type``
  edge routes to the *owner shard* of that subject, where the owner
  class is the subject's minimum class id.  Keeping each entity's whole
  star co-located is what makes shard-local detection AND per-class
  query routing exact -- a molecule never straddles shards;
* **untyped-subject rows partition by predicate** (the substrate's
  vertical-partition CSR columns): a row with no typed subject routes to
  the owner shard of its predicate, so classless var-arm scans touch one
  shard per predicate;
* a :class:`ShardPlan` balances the shards on Def. 4.8 edge counts
  (per-entity row counts are exactly the entity's edge contribution).
  Classes bigger than the balance target are *chunk-split* at cumulative
  edge-weight boundaries and the chunks placed LPT-greedy, so a two-
  class workload still fills an 8-way mesh.

Detection then runs **shard-local** through the existing
``SweepWorkspace``/``sweep_candidates`` engine (each shard is an
ordinary ``CompactionPlanner.run`` over its sub-store, with a per-shard
surrogate prefix so mints never collide in the shared dictionary); the
``ami_bucketed_batch`` collective schedule is engaged only where a
class's entity universe crosses shards (:meth:`cross_shard_ami` -- one
hash-bucket ``all_to_all``, signatures cross shards exactly once).
Chunk-splitting a class is AMI-exact for detection because the digest /
Def. 4.11 semantics are invariant to *how* the population is cut: each
chunk detects its own frequent star over the same property universe and
the union of expansions is the original graph (asserted in
``tests/test_sharded.py``).

Queries fan out per shard and only *binding sets* cross shards: star
results concatenate (typed subjects are uniquely owned), classless arms
merge per-arm ``(s, v)`` pair sets, and BGP stars evaluate to concrete
per-shard relations that join at the coordinator.

The module imports without jax (``repro.dist`` is imported by the online
service); mesh collectives are reached lazily via
``repro.core.distributed``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Sequence

import numpy as np

from repro.core.index import SPO_PERM, csr_take, in_sorted, sort_unique
from repro.core.triples import TripleStore

# fork-shared worker context for parallel shard detection: the child
# processes read it copy-on-write, so the (possibly large) shard
# snapshots are never pickled
_FORK_CTX: dict = {}


# ---------------------------------------------------------------------------
# the shard plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static row-routing plan balanced on Def. 4.8 edge counts.

    ``owner_entities`` (sorted) / ``owner_shard`` give the typed-subject
    routing; ``pred_shard`` routes untyped-subject rows by predicate;
    ``class_shards`` is the query-routing view (every shard holding at
    least one entity of the class, multi-typed entities included);
    ``class_props`` freezes each class's property universe at build time
    so cross-shard AMI evaluates every chunk over the same columns.
    """

    n_shards: int
    owner_entities: np.ndarray          # (E,) int64, sorted
    owner_shard: np.ndarray             # (E,) int32, aligned
    pred_shard: dict[int, int]
    class_shards: dict[int, tuple[int, ...]]
    class_props: dict[int, tuple[int, ...]]
    shard_weights: tuple[int, ...]      # Def. 4.8 edge-count loads
    n_chunks: int                       # entity chunks placed (>= classes)

    @classmethod
    def build(cls, store: TripleStore, n_shards: int, *,
              oversplit: int = 2) -> "ShardPlan":
        """Balance on per-entity edge counts with class chunk-splitting.

        A class whose weight exceeds ``total / (n_shards * oversplit)``
        splits into equal-weight entity-range chunks; chunks (plus the
        untyped per-predicate column groups) are placed LPT-greedy on
        the least-loaded shard.
        """
        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        idx = store.index
        spo = store.spo
        trows = idx.pred_slice(store.TYPE)
        if trows.shape[0]:
            # the (s, o)-sorted type partition: first row per subject
            # carries its minimum class id -- the owner class
            ents, first = np.unique(trows[:, 0], return_index=True)
            ents = ents.astype(np.int64)
            owner_class = trows[first, 2].astype(np.int64)
        else:
            ents = np.empty((0,), np.int64)
            owner_class = np.empty((0,), np.int64)
        subs = spo[:, 0].astype(np.int64)
        lo = np.searchsorted(subs, ents, side="left")
        hi = np.searchsorted(subs, ents, side="right")
        w = (hi - lo).astype(np.int64)           # per-entity edge count
        typed_mask = in_sorted(subs, ents)
        upreds, ucounts = (np.unique(spo[~typed_mask, 1],
                                     return_counts=True)
                           if (~typed_mask).any()
                           else (np.empty(0, np.int64),
                                 np.empty(0, np.int64)))
        total = int(w.sum()) + int(ucounts.sum())
        target = max(1, -(-total // max(n_shards * oversplit, 1)))
        items: list[tuple[int, str, object]] = []
        n_chunks = 0
        for cid in np.unique(owner_class).tolist():
            m = owner_class == cid
            ce, cw = ents[m], w[m]
            wc = int(cw.sum())
            k = min(max(1, -(-wc // target)), n_shards * oversplit,
                    int(ce.shape[0]))
            if k <= 1:
                items.append((wc, "ents", ce))
                n_chunks += 1
                continue
            cum = np.cumsum(cw)
            cuts = np.searchsorted(
                cum, [wc * j // k for j in range(1, k)], side="left") + 1
            prev = 0
            for b in list(int(c) for c in cuts) + [int(ce.shape[0])]:
                b = min(max(b, prev), int(ce.shape[0]))
                if b > prev:
                    items.append((int(cw[prev:b].sum()), "ents",
                                  ce[prev:b]))
                    n_chunks += 1
                    prev = b
        for p, c in zip(upreds.tolist(), ucounts.tolist()):
            items.append((int(c), "pred", int(p)))
        # LPT greedy: heaviest item first onto the least-loaded shard
        items.sort(key=lambda it: -it[0])
        loads = [0] * n_shards
        owner_shard = np.zeros((ents.shape[0],), np.int32)
        pred_shard: dict[int, int] = {}
        for wt, kind, payload in items:
            sid = int(np.argmin(loads))
            loads[sid] += wt
            if kind == "ents":
                pos = np.searchsorted(ents, payload)
                owner_shard[pos] = sid
            else:
                pred_shard[int(payload)] = sid
        class_shards: dict[int, tuple[int, ...]] = {}
        class_props: dict[int, tuple[int, ...]] = {}
        for cid in (int(c) for c in store.classes()):
            ec = idx.entities_of_class(cid).astype(np.int64)
            if ec.shape[0] == 0:
                continue
            if ents.shape[0]:
                pos = np.searchsorted(ents, ec)
                pos = np.minimum(pos, ents.shape[0] - 1)
                known = ents[pos] == ec
                shards = (np.unique(owner_shard[pos[known]])
                          if known.any() else np.empty(0, np.int32))
            else:
                shards = np.empty(0, np.int32)
            class_shards[cid] = tuple(int(s) for s in shards)
            stats = store.class_stats(cid)
            class_props[cid] = tuple(
                int(p) for p in np.sort(np.asarray(stats.properties)))
        return cls(n_shards=n_shards, owner_entities=ents,
                   owner_shard=owner_shard, pred_shard=pred_shard,
                   class_shards=class_shards, class_props=class_props,
                   shard_weights=tuple(int(x) for x in loads),
                   n_chunks=n_chunks)

    @property
    def split_classes(self) -> tuple[int, ...]:
        """Classes whose entity universe crosses shards -- the ones the
        collective AMI schedule covers."""
        return tuple(c for c, s in sorted(self.class_shards.items())
                     if len(s) > 1)

    def shards_for_class(self, class_id: int) -> tuple[int, ...]:
        return self.class_shards.get(
            int(class_id), tuple(range(self.n_shards)))

    def route_rows(self, spo: np.ndarray) -> np.ndarray:
        """Shard id per row: typed subjects to their owner shard,
        untyped rows to their predicate's shard."""
        spo = np.asarray(spo).reshape(-1, 3)
        n = spo.shape[0]
        out = np.zeros((n,), np.int32)
        if n == 0:
            return out
        subs = spo[:, 0].astype(np.int64)
        if self.owner_entities.shape[0]:
            pos = np.searchsorted(self.owner_entities, subs)
            pos_c = np.minimum(pos, self.owner_entities.shape[0] - 1)
            typed = (pos < self.owner_entities.shape[0]) & \
                (self.owner_entities[pos_c] == subs)
            out[typed] = self.owner_shard[pos_c[typed]]
        else:
            typed = np.zeros((n,), bool)
        rest = ~typed
        if rest.any():
            preds = spo[rest, 1]
            ps = np.empty((int(rest.sum()),), np.int32)
            for p in np.unique(preds).tolist():
                ps[preds == p] = self.pred_shard.get(
                    int(p), int(p) % self.n_shards)
            out[rest] = ps
        return out


# ---------------------------------------------------------------------------
# parallel shard detection (fork workers, shared-dict remap)
# ---------------------------------------------------------------------------

def _remap_ids(a: np.ndarray, base: int, new_ids: np.ndarray) -> np.ndarray:
    """Rewrite worker-minted ids (>= ``base``) to their parent-dict ids."""
    out = np.asarray(a, np.int64).copy()
    m = out >= base
    if m.any():
        out[m] = new_ids[out[m] - base]
    return out


def _detect_shard_worker(sid: int):
    """Runs in a fork child: compact one shard, return its successor
    snapshot as (arrays, meta) plus the terms it minted past the fork
    point (the parent re-mints them into the shared dictionary and
    rewrites the ids)."""
    from repro.api.snapshot import CompactionPlanner, GraphSnapshot
    snap = _FORK_CTX["snaps"][sid]
    kw = _FORK_CTX["kw"]
    store = (snap.fgraph.store if not snap.fgraph.tables
             else snap.fgraph.expand())
    base = len(store.dict)
    planner = CompactionPlanner(
        kw["detector"], kw["backend"],
        min_predicted_savings=kw["min_predicted_savings"],
        surrogate_prefix=f"{kw['surrogate_prefix']}/s{sid}")
    # CPU time, not wall: concurrent workers time-slicing fewer cores
    # would otherwise bill each other's share into every shard's number
    t0 = time.process_time()
    new_snap, rep = planner.run(store)
    detect_ms = (time.process_time() - t0) * 1e3
    arrays, meta = GraphSnapshot(fgraph=new_snap.fgraph,
                                 epoch=snap.epoch + 1).to_state()
    d = store.dict
    minted = [d.term(i) for i in range(base, len(d))]
    arrays = {k: np.ascontiguousarray(v) for k, v in arrays.items()}
    report = {"n_before": int(rep.n_triples_before),
              "n_after": int(rep.n_triples_after),
              "classes": len(new_snap.fgraph.tables),
              "pct_savings": float(rep.pct_savings_triples),
              "detect_ms": round(detect_ms, 1)}
    return sid, arrays, meta, minted, base, report


# ---------------------------------------------------------------------------
# the sharded graph
# ---------------------------------------------------------------------------

class ShardedFactorizedGraph:
    """Per-shard :class:`~repro.api.snapshot.GraphSnapshot` tuple over a
    shared dictionary, swapped atomically (one attribute store) under
    the same epoch discipline as the replicated snapshot path."""

    def __init__(self, dictionary, plan: ShardPlan,
                 snapshots: Sequence) -> None:
        self.dict = dictionary
        self.plan = plan
        self._snaps = tuple(snapshots)
        if len(self._snaps) != plan.n_shards:
            raise ValueError("snapshot count does not match the plan")
        # cross-shard byte accounting (filled by collective AMI and the
        # query fan-out merge; the bench matrix records it)
        self.traffic = {"detect_bytes": 0, "query_bytes": 0,
                        "collective_calls": 0}

    # -- construction ------------------------------------------------------
    @classmethod
    def partition(cls, store: TripleStore, n_shards: int, *,
                  plan: ShardPlan | None = None,
                  oversplit: int = 2) -> "ShardedFactorizedGraph":
        """Route every row of a plain store to its shard (disjoint row
        partition; a row subset of the sorted spo stays sorted)."""
        from repro.api.snapshot import GraphSnapshot
        from repro.core.fgraph import FactorizedGraph
        if plan is None:
            plan = ShardPlan.build(store, n_shards, oversplit=oversplit)
        sids = plan.route_rows(store.spo)
        snaps = []
        for sid in range(plan.n_shards):
            sub = TripleStore.from_ids(store.dict,
                                       store.spo[sids == sid],
                                       presorted=True)
            snaps.append(GraphSnapshot(fgraph=FactorizedGraph(sub, {}),
                                       epoch=0))
        return cls(store.dict, plan, snaps)

    # -- snapshot discipline -----------------------------------------------
    @property
    def snapshots(self) -> tuple:
        return self._snaps

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def epoch(self) -> int:
        return max(s.epoch for s in self._snaps)

    def swap(self, snapshots: Sequence) -> None:
        """THE commit: one atomic attribute store of the whole tuple --
        a reader holding the old tuple keeps a consistent world view."""
        snaps = tuple(snapshots)
        if len(snaps) != self.plan.n_shards:
            raise ValueError("snapshot count does not match the plan")
        self._snaps = snaps

    def swap_shard(self, sid: int, snapshot) -> None:
        """Replace one shard's snapshot (still one atomic tuple store)."""
        snaps = list(self._snaps)
        snaps[int(sid)] = snapshot
        self._snaps = tuple(snaps)

    # -- detection ---------------------------------------------------------
    def detect_all(self, *, detector: str = "gfsp",
                   backend: str = "host",
                   min_predicted_savings: int = 1,
                   surrogate_prefix: str = "repro:sg",
                   parallel: bool = False, mesh=None,
                   use_kernel: bool = True) -> dict:
        """Shard-local detection through the existing sweep engine.

        Each shard compacts independently (per-shard surrogate prefix,
        shared dictionary).  With a ``mesh``, the classes whose entity
        universe crosses shards first run the ``ami_bucketed_batch``
        collective schedule -- the only step where signatures cross
        shards -- and the global AMI lands in the report.
        ``parallel=True`` forks one worker per shard (host detection is
        numpy-only, fork-safe); workers return snapshot state plus their
        minted terms, which the parent re-mints into the shared
        dictionary and rewrites, so the shared-dict invariant survives
        process-parallel detection.
        """
        report: dict = {"split_class_ami": {}, "shards": {}}
        for cid in self.plan.split_classes:
            report["split_class_ami"][int(cid)] = self.cross_shard_ami(
                cid, mesh=mesh, use_kernel=use_kernel)
        kw = dict(detector=detector, backend=backend,
                  min_predicted_savings=int(min_predicted_savings),
                  surrogate_prefix=surrogate_prefix)
        if parallel and self.n_shards > 1:
            report["shards"] = self._detect_parallel(kw)
        else:
            report["shards"] = self._detect_sequential(kw)
        return report

    def _detect_sequential(self, kw: dict) -> dict:
        from repro.api.snapshot import CompactionPlanner, GraphSnapshot
        snaps = list(self._snaps)
        out = {}
        for sid, snap in enumerate(snaps):
            planner = CompactionPlanner(
                kw["detector"], kw["backend"],
                min_predicted_savings=kw["min_predicted_savings"],
                surrogate_prefix=f"{kw['surrogate_prefix']}/s{sid}")
            store = (snap.fgraph.store if not snap.fgraph.tables
                     else snap.fgraph.expand())
            t0 = time.process_time()
            new_snap, rep = planner.run(store)
            detect_ms = (time.process_time() - t0) * 1e3
            snaps[sid] = GraphSnapshot(fgraph=new_snap.fgraph,
                                       epoch=snap.epoch + 1)
            out[sid] = {"n_before": int(rep.n_triples_before),
                        "n_after": int(rep.n_triples_after),
                        "classes": len(new_snap.fgraph.tables),
                        "pct_savings": float(rep.pct_savings_triples),
                        "detect_ms": round(detect_ms, 1)}
        self.swap(snaps)
        return out

    def _detect_parallel(self, kw: dict) -> dict:
        import concurrent.futures
        import multiprocessing as mp
        from repro.api.snapshot import GraphSnapshot
        ctx = mp.get_context("fork")
        _FORK_CTX["snaps"] = self._snaps
        _FORK_CTX["kw"] = kw
        try:
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=self.n_shards,
                    mp_context=ctx) as ex:
                results = list(ex.map(_detect_shard_worker,
                                      range(self.n_shards)))
        finally:
            _FORK_CTX.clear()
        snaps = list(self._snaps)
        out = {}
        for sid, arrays, meta, minted, base, rep in results:
            new_ids = (self.dict.ids(minted).astype(np.int64)
                       if minted else np.empty((0,), np.int64))
            fixed: dict[str, np.ndarray] = {}
            for k, v in arrays.items():
                if k == "spo":
                    # remapped mints can break (s, p, o) order: re-sort
                    fixed[k] = sort_unique(
                        _remap_ids(v, base, new_ids).astype(np.int32),
                        SPO_PERM)
                elif k.endswith("_surrogates"):
                    # parent re-mints in worker mint order, so the map
                    # is monotone and ascending surrogates stay sorted
                    fixed[k] = _remap_ids(v, base,
                                          new_ids).astype(np.int32)
                else:
                    fixed[k] = v        # object ids predate the fork
            snaps[sid] = GraphSnapshot.from_state(self.dict, fixed, meta)
            out[sid] = rep
        self.swap(snaps)
        return out

    # -- cross-shard collective AMI ---------------------------------------
    def cross_shard_ami(self, class_id: int, *, mesh=None,
                        use_kernel: bool = True) -> int:
        """Global AMI of a chunk-split class.

        Stacks each shard's object matrix over the class's full build-
        time property universe; with a ``mesh`` the distinct-row count
        runs through the ``ami_bucketed`` hash-bucket exchange (every
        signature crosses shards exactly once -- counted in
        ``traffic``), otherwise an exact host count.
        """
        cid = int(class_id)
        props = np.asarray(self.plan.class_props.get(cid, ()), np.int32)
        if props.shape[0] == 0:
            return 0
        mats = []
        for snap in self._snaps:
            fg = snap.fgraph
            st = fg.store if not fg.tables else fg.expand()
            ents, mat = st.object_matrix(cid, props)
            if ents.shape[0]:
                mats.append(mat)
        if not mats:
            return 0
        stack = np.ascontiguousarray(
            np.concatenate(mats, axis=0).astype(np.int32))
        if mesh is None:
            return int(np.unique(stack, axis=0).shape[0])
        from repro.core.distributed import ami_bucketed, pad_rows
        n_dev = 1
        for s in mesh.devices.shape:
            n_dev *= int(s)
        padded, n = pad_rows(stack, max(n_dev, 1))
        valid = np.arange(padded.shape[0]) < n
        dp = tuple(a for a in mesh.axis_names if a != "model")
        self.traffic["detect_bytes"] += int(stack.shape[0] * 8)
        self.traffic["collective_calls"] += 1
        return int(ami_bucketed(padded, valid, mesh, dp_axes=dp,
                                use_kernel=use_kernel))

    # -- losslessness / accounting -----------------------------------------
    def expand_union(self) -> TripleStore:
        """Semantic union of every shard's expansion -- the original
        graph, independent of the partition and of what each shard
        factorized (the digest-parity anchor)."""
        parts = [s.fgraph.expand().spo for s in self._snaps]
        return TripleStore.from_ids(self.dict,
                                    np.concatenate(parts, axis=0))

    def digest(self) -> str:
        """Same contract as ``GraphSnapshot.digest()``: sha1 of the
        canonical expanded rows, so sharded == unsharded is one string
        comparison."""
        return hashlib.sha1(np.ascontiguousarray(
            self.expand_union().spo).tobytes()).hexdigest()[:16]

    @property
    def n_triples(self) -> int:
        """Stored rows across shards (post-detection: compact form)."""
        return sum(s.fgraph.n_triples for s in self._snaps)

    def shard_nbytes(self) -> list[int]:
        """Resident substrate bytes per shard: triples + index + the
        shard-local molecule tables (the shared dictionary is excluded
        -- it is the one replicated structure)."""
        out = []
        for snap in self._snaps:
            fg = snap.fgraph
            b = int(fg.store.substrate_nbytes(include_dict=False))
            for t in fg.tables.values():
                b += int(t.surrogates.nbytes) + int(t.objects.nbytes)
            out.append(b)
        return out


# ---------------------------------------------------------------------------
# fan-out query engine
# ---------------------------------------------------------------------------

class ShardedQueryEngine:
    """Star/BGP evaluation against shard-resident molecule tables;
    only binding sets cross shards.

    Class-constrained stars route to the shards holding the class
    (typed subjects are uniquely owned, so per-shard answers
    concatenate).  Classless stars merge per-arm ``(s, v)`` pair sets
    at the coordinator.  BGP stars evaluate to concrete per-shard
    relations, concatenate, and join here -- molecule tables and member
    sets never leave their shard.
    """

    def __init__(self, sharded: ShardedFactorizedGraph, *,
                 use_kernel: bool = True) -> None:
        from repro.query.batch import QueryEngine
        self.sharded = sharded
        self.use_kernel = bool(use_kernel)
        self.engines = [QueryEngine(s.fgraph, use_kernel=use_kernel,
                                    epoch=s.epoch)
                        for s in sharded.snapshots]

    def rebind(self) -> None:
        """Follow a swap: rebind every per-shard engine to its shard's
        live snapshot (old-epoch device buffers evict per engine
        policy)."""
        for eng, snap in zip(self.engines, self.sharded.snapshots):
            eng.rebind(snap.fgraph, snap.epoch)

    # -- star queries ------------------------------------------------------
    def _route(self, q) -> tuple[int, ...]:
        if q.class_id is None:
            return tuple(range(self.sharded.n_shards))
        return self.sharded.plan.shards_for_class(int(q.class_id))

    def _merge(self, q, parts: list):
        from repro.query.star import Bindings
        vp = tuple(int(p) for p in q.var_props)
        parts = [p for p in parts if p is not None]
        if not parts:
            return Bindings(subjects=np.empty((0,), np.int64),
                            var_props=vp,
                            var_objects=np.empty((0, len(vp)), np.int64))
        subs = np.concatenate(
            [np.asarray(p.subjects, np.int64) for p in parts])
        vo = np.concatenate(
            [np.asarray(p.var_objects, np.int64).reshape(
                np.asarray(p.subjects).shape[0], len(vp))
             for p in parts])
        self.sharded.traffic["query_bytes"] += \
            int(subs.nbytes) + int(vo.nbytes)
        return Bindings(subjects=subs, var_props=vp, var_objects=vo)

    def query(self, q, strategy: str = "factorized"):
        if q.class_id is None:
            return self._query_classless(q)
        parts = [self.engines[sid].query(q, strategy)
                 for sid in self._route(q)]
        return self._merge(q, parts)

    def _query_classless(self, q):
        """Coordinator-side per-arm merge: an untyped subject's rows may
        spread over predicate shards, so ground-arm subject sets union
        per arm and var-arm pairs union per arm before the join."""
        from repro.query.star import (_arm_pairs, _arm_subject_set,
                                      _intersect, _join_vars)
        cand = None
        for p, o in q.ground_arms:
            subs = np.unique(np.concatenate(
                [_arm_subject_set(eng.fgraph, p, o)
                 for eng in self.engines]) if self.engines
                else np.empty((0,), np.int64))
            self.sharded.traffic["query_bytes"] += int(subs.nbytes)
            cand = _intersect(cand, subs)

        def pairs_of(p, c):
            ss, vv = [], []
            for eng in self.engines:
                s, v = _arm_pairs(eng.fgraph, p, c)
                ss.append(np.asarray(s, np.int64))
                vv.append(np.asarray(v, np.int64))
            s = np.concatenate(ss)
            v = np.concatenate(vv)
            self.sharded.traffic["query_bytes"] += \
                int(s.nbytes) + int(v.nbytes)
            pairs = np.unique(np.stack([s, v], axis=1), axis=0)
            return pairs[:, 0], pairs[:, 1]

        var_props = q.var_props
        if cand is None:
            if not var_props:
                raise ValueError(
                    "star query needs a class or at least one arm")
            s0, _ = pairs_of(var_props[0], None)
            cand = np.unique(s0)
        return _join_vars(cand, var_props, pairs_of)

    def query_batch(self, queries, strategy: str = "factorized",
                    backend: str = "host") -> list:
        """Per-shard grouped fan-out: each shard sees one batched call
        (device-eligible queries keep the one-lowering-per-chunk path
        of the shard's own engine)."""
        queries = list(queries)
        out: list = [None] * len(queries)
        per_shard: dict[int, list[int]] = {}
        partials: dict[int, list] = {}
        for i, q in enumerate(queries):
            if q.class_id is None:
                out[i] = self._query_classless(q)
                continue
            for sid in self._route(q):
                per_shard.setdefault(sid, []).append(i)
        for sid, idxs in per_shard.items():
            res = self.engines[sid].query_batch(
                [queries[i] for i in idxs], strategy=strategy,
                backend=backend)
            for i, b in zip(idxs, res):
                partials.setdefault(i, []).append(b)
        for i, q in enumerate(queries):
            if out[i] is None:
                out[i] = self._merge(q, partials.get(i, []))
        return out

    # -- BGP ---------------------------------------------------------------
    def query_bgp(self, q, strategy: str = "auto",
                  backend: str = "host"):
        """Evaluate each star shard-local (concrete relations), ship
        only the binding sets, and join at the coordinator."""
        from repro.query.bgp.algebra import BGPBindings, BGPQuery
        rels: list[BGPBindings] = []
        for star in q.stars:
            fs = tuple(f for f in q.filters if f.var in star.variables)
            if star.class_id is None:
                rels.append(self._classless_star_bindings(star, fs))
                continue
            sub_q = BGPQuery(stars=(star,), filters=fs)
            parts = []
            for sid in self.sharded.plan.shards_for_class(
                    int(star.class_id)):
                b = self.engines[sid].query_bgp(
                    sub_q, strategy=strategy, backend=backend)
                if b.n_rows:
                    parts.append(b)
            cols = sub_q.variables
            if parts:
                rows = np.concatenate(
                    [p.rows[:, [p.columns.index(v) for v in cols]]
                     for p in parts])
            else:
                rows = np.empty((0, len(cols)), np.int64)
            self.sharded.traffic["query_bytes"] += int(rows.nbytes)
            rels.append(BGPBindings(columns=cols, rows=rows))
        out = rels[0]
        for rel in rels[1:]:
            out = _join_bindings(out, rel)
        cols = q.variables
        rows = out.rows[:, [out.columns.index(v) for v in cols]]
        return BGPBindings(columns=cols, rows=rows)

    def _classless_star_bindings(self, star, filters):
        from repro.query.bgp.algebra import BGPBindings
        from repro.query.star import StarQuery
        sq = StarQuery(arms=tuple(
            (p, None if isinstance(o, str) else int(o))
            for p, o in star.arms), class_id=None)
        b = self._query_classless(sq)
        cols = (star.subject,) + tuple(o for _, o in star.var_arms)
        rows = b.rows()
        out = BGPBindings(columns=cols, rows=rows)
        for f in filters:
            keep = f.apply(out.column(f.var))
            out = BGPBindings(columns=out.columns, rows=out.rows[keep])
        return out


def _join_bindings(a, b):
    """Natural join of two concrete binding relations (coordinator
    side: both inputs are already materialized per-shard unions)."""
    from repro.query.bgp.algebra import BGPBindings
    shared = [v for v in a.columns if v in b.columns]
    extra = [v for v in b.columns if v not in a.columns]
    cols = tuple(a.columns) + tuple(extra)
    if not shared:
        ra = np.repeat(np.arange(a.n_rows), b.n_rows)
        rb = np.tile(np.arange(b.n_rows), a.n_rows)
    else:
        ka = a.rows[:, [a.columns.index(v) for v in shared]]
        kb = b.rows[:, [b.columns.index(v) for v in shared]]
        allk = np.concatenate([ka, kb], axis=0)
        _, inv = np.unique(allk, axis=0, return_inverse=True)
        ia, ib = inv[:ka.shape[0]], inv[ka.shape[0]:]
        order = np.argsort(ib, kind="stable")
        ib_s = ib[order]
        lo = np.searchsorted(ib_s, ia, side="left")
        hi = np.searchsorted(ib_s, ia, side="right")
        counts = hi - lo
        ra = np.repeat(np.arange(a.n_rows), counts)
        rb = order[csr_take(lo, counts)]
    if extra:
        rows = np.concatenate(
            [a.rows[ra],
             b.rows[rb][:, [b.columns.index(v) for v in extra]]],
            axis=1)
    else:
        rows = a.rows[ra]
    return BGPBindings(columns=cols, rows=rows)


__all__ = ["ShardPlan", "ShardedFactorizedGraph", "ShardedQueryEngine"]

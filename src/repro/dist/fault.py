"""Fault detection and injection: liveness, retry, seeded crash plans.

Detection: the coordinator calls ``Monitor.record(worker, step)`` on
every heartbeat and ``Monitor.check()`` on its own cadence.  A worker
whose last beat is older than ``deadline_s`` is dead (fires ``on_dead``
once, permanently excluded); a live worker ``straggler_factor`` or more
steps behind the fastest is a straggler (fires ``on_straggler`` on the
transition, re-arms when it catches back up).  Dead workers keep their
last known step out of the straggler baseline so one corpse cannot mark
the whole fleet slow.

Injection: a :class:`FaultPlan` arms ONE named site -- the durable
online service threads :data:`SITES` through its write path -- and
trips it on the n-th visit, either by raising :class:`InjectedFault`
(in-process crash-point sweeps) or by ``SIGKILL``-ing the process (the
CI kill-and-restart soak).  Plans are seeded so a failure reproduces
from its seed alone.  ``retry`` never retries an :class:`InjectedFault`
-- injection simulates process death, not a transient error.
"""
from __future__ import annotations

import dataclasses
import os
import random
import signal
import threading
import time
from typing import Callable

# injection sites threaded through OnlineCompactionService, in the
# order they occur along one submit -> apply -> checkpoint lifecycle
SITES = ("wal.append", "apply", "pre_swap", "post_swap",
         "checkpoint.write", "redetect")


class InjectedFault(RuntimeError):
    """A deliberately injected crash (see :class:`FaultPlan`)."""

    def __init__(self, site: str, occurrence: int):
        super().__init__(f"injected fault at {site!r} "
                         f"(occurrence {occurrence})")
        self.site = site
        self.occurrence = occurrence


class FaultPlan:
    """One seeded crash: trip ``site`` on its ``occurrence``-th visit.

    ``mode="raise"`` raises :class:`InjectedFault` (the sweep recovers
    in-process); ``mode="kill"`` sends the process ``SIGKILL`` (the CI
    soak restarts the command).  A plan fires at most once; ``fire``
    is a no-op for unarmed plans, so production code can call it
    unconditionally with ``plan=None`` handled by the caller.
    """

    def __init__(self, site: str | None, *, occurrence: int = 0,
                 mode: str = "raise") -> None:
        if mode not in ("raise", "kill"):
            raise ValueError(f"unknown fault mode {mode!r}")
        if site is not None and site not in SITES:
            raise ValueError(f"unknown fault site {site!r} "
                             f"(sites: {', '.join(SITES)})")
        self.site = site
        self.occurrence = int(occurrence)
        self.mode = mode
        self.fired = False
        self._counts: dict[str, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def seeded(cls, seed: int, *, sites=SITES, mode: str = "raise",
               max_occurrence: int = 2) -> "FaultPlan":
        """Deterministic plan from a seed: uniform site, occurrence in
        ``[0, max_occurrence]``."""
        rng = random.Random(int(seed))
        return cls(rng.choice(list(sites)),
                   occurrence=rng.randint(0, max_occurrence), mode=mode)

    def seen(self, site: str) -> int:
        """How many times ``site`` has been visited so far."""
        return self._counts.get(site, 0)

    def fire(self, site: str) -> None:
        """Visit ``site``; trip if this is the armed occurrence."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            trip = (not self.fired and site == self.site
                    and n == self.occurrence)
            if trip:
                self.fired = True
        if trip:
            if self.mode == "kill":     # pragma: no cover - kills pytest
                os.kill(os.getpid(), signal.SIGKILL)
            raise InjectedFault(site, n)

    def __repr__(self) -> str:
        return (f"FaultPlan(site={self.site!r}, "
                f"occurrence={self.occurrence}, mode={self.mode!r}, "
                f"fired={self.fired})")


class Monitor:
    def __init__(self, *, deadline_s: float, straggler_factor: int = 3,
                 on_dead: Callable[[str], None] | None = None,
                 on_straggler: Callable[[str], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.straggler_factor = straggler_factor
        self._on_dead = on_dead or (lambda w: None)
        self._on_straggler = on_straggler or (lambda w: None)
        self._clock = clock
        self._beats: dict[str, tuple[float, int]] = {}  # worker -> (t, step)
        self._dead: set[str] = set()
        self._flagged: set[str] = set()

    def record(self, worker: str, step: int) -> None:
        if worker in self._dead:
            return                      # no resurrection: restart re-joins
        self._beats[worker] = (self._clock(), step)

    def check(self) -> None:
        now = self._clock()
        for w, (t, _) in self._beats.items():
            if w not in self._dead and now - t > self.deadline_s:
                self._dead.add(w)
                self._flagged.discard(w)
                self._on_dead(w)
        alive = {w: s for w, (_, s) in self._beats.items()
                 if w not in self._dead}
        if not alive:
            return
        front = max(alive.values())
        for w, s in alive.items():
            if front - s >= self.straggler_factor:
                if w not in self._flagged:
                    self._flagged.add(w)
                    self._on_straggler(w)
            else:
                self._flagged.discard(w)

    def healthy_workers(self) -> list[str]:
        return sorted(w for w in self._beats if w not in self._dead)

    def stragglers(self) -> list[str]:
        return sorted(self._flagged)


def retry(fn: Callable, *, attempts: int = 3, base_s: float = 0.5,
          factor: float = 2.0, max_s: float = 30.0, jitter: bool = True,
          deadline_s: float | None = None, exceptions=(Exception,),
          sleep: Callable[[float], None] = time.sleep,
          clock: Callable[[], float] = time.monotonic,
          rng: random.Random | None = None,
          on_retry: Callable[[int, float, BaseException], None] | None
          = None) -> Callable:
    """Wrap ``fn`` with backoff retries under an overall time budget.

    Delays use decorrelated jitter (``min(max_s, uniform(base_s,
    prev * 3))`` -- independent retriers de-synchronize instead of
    thundering in lockstep); ``jitter=False`` falls back to the plain
    ``base_s * factor**k`` exponential, still capped at ``max_s``.
    ``deadline_s`` bounds the WHOLE call: once the budget is spent no
    further attempt starts (and a pending sleep is clipped to the
    remainder), so a slow callee cannot block its caller unboundedly.
    The final exception propagates with ``retry_attempts`` (attempts
    made) and ``retry_elapsed_s`` attached; ``on_retry(attempt, delay,
    exc)`` fires before each sleep.  ``sleep``/``clock``/``rng`` are
    injectable for tests.  :class:`InjectedFault` is never retried --
    it models process death.
    """
    if attempts < 1:
        raise ValueError("attempts must be >= 1")
    _rng = rng or random.Random()

    def wrapped(*args, **kwargs):
        t0 = clock()
        prev = base_s
        for attempt in range(attempts):
            try:
                return fn(*args, **kwargs)
            except InjectedFault:
                raise
            except exceptions as e:
                made = attempt + 1
                elapsed = clock() - t0
                out_of_time = (deadline_s is not None
                               and elapsed >= deadline_s)
                if made >= attempts or out_of_time:
                    e.retry_attempts = made
                    e.retry_elapsed_s = elapsed
                    raise
                if jitter:
                    delay = min(max_s, _rng.uniform(base_s, prev * 3.0))
                    prev = delay
                else:
                    delay = min(max_s, base_s * factor ** attempt)
                if deadline_s is not None:
                    delay = min(delay, max(0.0, deadline_s - elapsed))
                if on_retry is not None:
                    on_retry(made, delay, e)
                sleep(delay)
        raise AssertionError("unreachable")

    return wrapped

"""Fault detection: heartbeat liveness, straggler flagging, retry.

The coordinator calls ``Monitor.record(worker, step)`` on every
heartbeat and ``Monitor.check()`` on its own cadence.  A worker whose
last beat is older than ``deadline_s`` is dead (fires ``on_dead`` once,
permanently excluded); a live worker ``straggler_factor`` or more steps
behind the fastest is a straggler (fires ``on_straggler`` on the
transition, re-arms when it catches back up).  Dead workers keep their
last known step out of the straggler baseline so one corpse cannot mark
the whole fleet slow.
"""
from __future__ import annotations

import time
from typing import Callable


class Monitor:
    def __init__(self, *, deadline_s: float, straggler_factor: int = 3,
                 on_dead: Callable[[str], None] | None = None,
                 on_straggler: Callable[[str], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.straggler_factor = straggler_factor
        self._on_dead = on_dead or (lambda w: None)
        self._on_straggler = on_straggler or (lambda w: None)
        self._clock = clock
        self._beats: dict[str, tuple[float, int]] = {}  # worker -> (t, step)
        self._dead: set[str] = set()
        self._flagged: set[str] = set()

    def record(self, worker: str, step: int) -> None:
        if worker in self._dead:
            return                      # no resurrection: restart re-joins
        self._beats[worker] = (self._clock(), step)

    def check(self) -> None:
        now = self._clock()
        for w, (t, _) in self._beats.items():
            if w not in self._dead and now - t > self.deadline_s:
                self._dead.add(w)
                self._flagged.discard(w)
                self._on_dead(w)
        alive = {w: s for w, (_, s) in self._beats.items()
                 if w not in self._dead}
        if not alive:
            return
        front = max(alive.values())
        for w, s in alive.items():
            if front - s >= self.straggler_factor:
                if w not in self._flagged:
                    self._flagged.add(w)
                    self._on_straggler(w)
            else:
                self._flagged.discard(w)

    def healthy_workers(self) -> list[str]:
        return sorted(w for w in self._beats if w not in self._dead)

    def stragglers(self) -> list[str]:
        return sorted(self._flagged)


def retry(fn: Callable, *, attempts: int = 3, base_s: float = 0.5,
          factor: float = 2.0, exceptions=(Exception,),
          sleep: Callable[[float], None] = time.sleep) -> Callable:
    """Wrap ``fn`` with exponential-backoff retries.  The last attempt's
    exception propagates; ``sleep`` is injectable for tests."""
    if attempts < 1:
        raise ValueError("attempts must be >= 1")

    def wrapped(*args, **kwargs):
        delay = base_s
        for attempt in range(attempts):
            try:
                return fn(*args, **kwargs)
            except exceptions:
                if attempt == attempts - 1:
                    raise
                sleep(delay)
                delay *= factor
        raise AssertionError("unreachable")

    return wrapped

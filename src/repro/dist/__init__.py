"""Distribution substrate: sharding plans, gradient compression, elastic
re-meshing and fault monitoring.

Layering: this package sits between the pure model/train code (which only
carries ``PartitionSpec`` hints it is handed) and the launchers
(``repro.launch.dryrun`` / ``repro.launch.train``), which own real meshes.
All layout decisions live in :mod:`repro.dist.sharding`; everything else
consumes its ``Plan``.
"""
from . import compression, elastic, fault, graph, sharding  # noqa: F401

"""The paper's factorization, transferred to LM serving.

Mapping (DESIGN.md §2): a batch of requests sharing a prompt prefix IS a
frequent star pattern --

  entity (subject)  = request
  property p_i      = prefix chunk position i
  object o_i        = the token block at chunk i
  compact molecule  = ONE shared KV segment for the common prefix
  surrogate entity  = the shared segment's id
  instanceOf edge   = the per-request pointer to the shared segment

and the paper's #Edges objective (Def. 4.8) becomes a BYTES objective
deciding how deep to share:

  cost(d) = sum_{i<d} distinct_prefixes(i) * chunk_kv_bytes     (molecules)
          + R * (L - d*c) * token_kv_bytes                      (suffixes)
          + R * ptr_bytes * (d > 0)                             (instanceOf)

``distinct_prefixes(i)`` is exactly the paper's AMI over the first i+1
"properties" (chunk positions), computed with the same row-group
machinery (core.star.row_groups).  The paper's factorization-overhead
case (Fig. 7 -- sharing that GROWS the graph) appears verbatim: for
unique prompts or tiny chunks, cost(d) is minimized at d = 0 and the
planner declines to share.

Losslessness (Def. 4.10/4.11 analog): expanding each request's pointer
chain reproduces its full token sequence -- asserted in tests, and the
engine validates shared-vs-unshared logits agree.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.star import row_groups


@dataclasses.dataclass(frozen=True)
class PrefixPlan:
    depth_chunks: int                 # chosen sharing depth d*
    chunk: int
    molecule_tokens: np.ndarray       # (n_molecules, d*chunk) shared prefixes
    instance_of: np.ndarray           # (R,) request -> molecule id (-1: none)
    suffix_start: int                 # tokens from here on are per-request
    cost_shared: float
    cost_unshared: float

    @property
    def shares(self) -> bool:
        return self.depth_chunks > 0

    @property
    def savings_pct(self) -> float:
        """%Savings metric of the paper (Table 5), in KV bytes."""
        if self.cost_unshared == 0:
            return 0.0
        return 100.0 * (1 - self.cost_shared / self.cost_unshared)


def prefix_edges_cost(tokens: np.ndarray, d: int, chunk: int,
                      kv_bytes_per_token: float,
                      ptr_bytes: float = 8.0) -> float:
    """#Edges (Def. 4.8) in bytes for sharing depth ``d`` (chunks)."""
    r, length = tokens.shape
    cost = r * (length - d * chunk) * kv_bytes_per_token
    if d > 0:
        cost += r * ptr_bytes
        for i in range(1, d + 1):
            _, counts, _ = row_groups(tokens[:, :i * chunk])
            cost += counts.shape[0] * chunk * kv_bytes_per_token
    return float(cost)


def plan_prefix_sharing(tokens: np.ndarray, *, chunk: int = 128,
                        kv_bytes_per_token: float,
                        ptr_bytes: float = 8.0) -> PrefixPlan:
    """Greedy depth descent (G.FSP analog): start from the deepest
    shareable prefix and stop when the bytes objective stops improving
    (Theorem 4.1's monotonicity holds here too: once extending the shared
    depth is a loss, deeper extensions only add molecules)."""
    tokens = np.asarray(tokens)
    r, length = tokens.shape
    max_d = length // chunk
    base = float(r * length * kv_bytes_per_token)     # d = 0
    best_d, best_cost = 0, base
    # incremental greedy: walk depth upward while the objective improves
    cum = base
    for d in range(1, max_d + 1):
        _, counts, _ = row_groups(tokens[:, :d * chunk])
        n_mol = counts.shape[0]
        # marginal change of moving chunk d-1 from per-request to shared:
        cum = prefix_edges_cost(tokens, d, chunk, kv_bytes_per_token,
                                ptr_bytes)
        if cum < best_cost:
            best_d, best_cost = d, cum
        elif n_mol == r:
            break            # fully distinct already: deeper never helps
    if best_d == 0:
        return PrefixPlan(0, chunk, np.empty((0, 0), tokens.dtype),
                          np.full((r,), -1, np.int64), 0, base, base)
    inv, counts, rep = row_groups(tokens[:, :best_d * chunk])
    molecules = tokens[rep][:, :best_d * chunk]
    return PrefixPlan(best_d, chunk, molecules, inv,
                      best_d * chunk, best_cost, base)


def expand(plan: PrefixPlan, suffixes: np.ndarray) -> np.ndarray:
    """Inverse transformation (instanceOf axioms): rebuild full sequences."""
    if not plan.shares:
        return suffixes
    return np.concatenate(
        [plan.molecule_tokens[plan.instance_of], suffixes], axis=1)

"""KV cache pool for the serving engine.

Host-side slot manager over the device-resident cache tree built by
``model.cache_specs``.  Supports:

* slot allocation / free (continuous batching: a finished request's slot
  is immediately reusable);
* shared-prefix attach: a slot's first ``prefix_len`` positions point at a
  molecule from ``prefix_factorization`` -- physically, the molecule's KV
  is copied into the slot range once per molecule and broadcast to its
  instance slots (device-side gather, no recompute), which keeps the
  decode step's cache layout dense and static-shaped.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SlotState:
    request_id: int | None = None
    length: int = 0                  # tokens currently cached


class KVPool:
    def __init__(self, n_slots: int):
        self.slots = [SlotState() for _ in range(n_slots)]

    def alloc(self, request_id: int) -> int:
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                self.slots[i] = SlotState(request_id, 0)
                return i
        raise RuntimeError("KV pool exhausted")

    def free(self, slot: int) -> None:
        self.slots[slot] = SlotState()

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots)
                if s.request_id is not None]

    def occupancy(self) -> float:
        return len(self.active()) / max(len(self.slots), 1)


def molecule_broadcast(cache_layers, molecule_cache, instance_of: np.ndarray):
    """Copy each molecule's prefix KV into its instance slots.

    cache_layers / molecule_cache: matching pytrees whose array leaves are
    (L, B, ...) / (L, M, ...) with the batch dim second; returns the
    updated cache tree (one device-side gather -- the 'instanceOf'
    expansion made physical)."""
    import jax

    idx = np.asarray(instance_of)

    def leaf(full, mol):
        take = mol[:, idx]           # (L, B, ...) gathered per instance
        # molecule KV occupies the first prefix positions of the sequence
        # axis; layouts: (L, B, heads, S, hd) or (L, B, S)
        if full.ndim == 5:
            return full.at[:, :, :, :take.shape[3]].set(take)
        return full.at[:, :, :take.shape[2]].set(take)

    return jax.tree.map(leaf, cache_layers, molecule_cache)

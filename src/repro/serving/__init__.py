"""Serving: batched engine, KV pool, and the paper's factorization applied
to shared-prefix KV caches."""
from .prefix_factorization import (  # noqa: F401
    PrefixPlan, plan_prefix_sharing, prefix_edges_cost)
from .engine import (BGPQueryRequest, BGPQueryResponse, Engine,  # noqa: F401
                     GraphQueryRequest, GraphQueryResponse,
                     GraphQueryService, PREFIX_POLICIES, PrefixPolicy,
                     Request, ShardedQueryService)

"""Batched serving engine with factorized shared prefixes.

Flow per admission wave (continuous batching):

  1. collect queued requests into the next batch;
  2. ``plan_prefix_sharing`` (the paper's #Edges-in-bytes objective)
     decides the shared depth d*;
  3. prefill each distinct MOLECULE once (batch of n_molecules), then
     broadcast molecule KV into the per-request slots ("instanceOf"
     expansion) and prefill only the per-request suffixes;
  4. greedy decode steps over the whole batch until max_new or eos.

When the planner declines to share (paper Fig. 7 overhead case) the
engine transparently falls back to plain batched prefill.  Shared and
unshared paths produce identical tokens (asserted in tests/test_serving).

Prefix compaction is selected by *named policy* (same strategy style as
``repro.api``): ``"auto"`` runs the bytes-objective planner and honors
its decision, ``"flat"`` skips planning and serves plain batched
prefill, ``"measure"`` plans (populating ``Engine.last_plan`` with the
would-be savings) but serves flat.  The old ``share_prefixes=`` boolean
is kept as a deprecated alias.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.registry import Registry
from repro.models.blocks import Ctx
from repro.train.serve_step import make_decode_step, make_prefill_step
from .prefix_factorization import plan_prefix_sharing


@dataclasses.dataclass(frozen=True)
class PrefixPolicy:
    """Named KV-prefix compaction strategy.

    ``plan`` runs the #Edges-in-bytes planner (``Engine.last_plan`` is
    populated); ``share`` additionally honors a positive sharing
    decision.  ``measure`` plans without sharing -- flat serving plus the
    would-be savings report.
    """

    name: str
    plan: bool       # run the #Edges-in-bytes planner
    share: bool      # honor a positive sharing decision


PREFIX_POLICIES = Registry("prefix policy")
PREFIX_POLICIES.register("auto", PrefixPolicy("auto", plan=True, share=True))
PREFIX_POLICIES.register("flat", PrefixPolicy("flat", plan=False,
                                              share=False))
PREFIX_POLICIES.register("measure", PrefixPolicy("measure", plan=True,
                                                 share=False))


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray               # (L,) prompt
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


# -- graph-query endpoint -----------------------------------------------------

@dataclasses.dataclass
class GraphQueryRequest:
    """One star BGP at the term level: ``arms`` are (property term,
    object term or None-for-variable) pairs, plus an optional class."""

    rid: int
    arms: tuple[tuple[str, str | None], ...]
    class_term: str | None = None
    strategy: str = "factorized"     # "factorized" | "raw"


@dataclasses.dataclass
class GraphQueryResponse:
    rid: int
    subjects: list[str]
    var_props: tuple[str, ...]
    var_objects: list[tuple[str, ...]]   # aligned with subjects
    strategy: str
    n_rows: int
    # "ok" | "degraded" (factorized path failed; answered via raw
    # fallback) | "shed" (per-wave deadline exhausted; NOT evaluated)
    status: str = "ok"


@dataclasses.dataclass
class BGPQueryRequest:
    """A multi-star BGP at the term level.  Each star is ``(subject,
    arms, class_term)`` with arms as (property term, object term) pairs;
    any term starting with ``"?"`` is a variable (subjects must be
    variables).  ``filters`` are ``(var, op, value term)`` triples with
    ``op`` one of ``== != < <= > >=``."""

    rid: int
    stars: tuple[tuple[str, tuple[tuple[str, str], ...], str | None], ...]
    filters: tuple[tuple[str, str, str], ...] = ()
    strategy: str = "auto"           # "auto" | "raw" | "factorized"


@dataclasses.dataclass
class BGPQueryResponse:
    rid: int
    variables: tuple[str, ...]       # canonical output column order
    rows: list[tuple[str, ...]]      # decoded bindings, aligned
    strategies: tuple[str, ...]      # planner's per-star choices
    n_rows: int
    status: str = "ok"               # "ok" | "degraded" | "shed"


def _compile_star(req: GraphQueryRequest, d):
    """Term-level star request -> id-level :class:`StarQuery` (``None``
    when any term is unknown to the dictionary: nothing can match it)."""
    from repro.query import StarQuery
    cid = None
    if req.class_term is not None:
        cid = d.lookup(req.class_term)
        if cid is None:
            return None
    arms = []
    for p, o in req.arms:
        pid = d.lookup(p)
        if pid is None:
            return None
        if o is None:
            arms.append((pid, None))
        else:
            oid = d.lookup(o)
            if oid is None:
                return None
            arms.append((pid, oid))
    return StarQuery(arms=tuple(arms), class_id=cid)


def _compile_bgp_query(req: BGPQueryRequest, d):
    from repro.query import BGPQuery, Filter, StarPattern
    from repro.query.bgp import is_var

    def enc(t):
        return t if is_var(t) else d.lookup(t)

    stars = []
    for subject, arms, class_term in req.stars:
        cid = None
        if class_term is not None:
            cid = d.lookup(class_term)
            if cid is None:
                return None
        enc_arms = []
        for p, o in arms:
            pid, oid = d.lookup(p), enc(o)
            if pid is None or oid is None:
                return None
            enc_arms.append((pid, oid))
        stars.append(StarPattern(subject, tuple(enc_arms),
                                 class_id=cid))
    filters = []
    for var, op, value in req.filters:
        vid = d.lookup(value)
        if vid is None:
            return None
        filters.append(Filter(var, op, vid))
    return BGPQuery(stars=tuple(stars), filters=tuple(filters))


class GraphQueryService:
    """Star-query endpoint over a compacted graph (the paper's "queries
    get faster on G'" claim, served).

    Wraps a ``repro.query.QueryEngine`` with the same queue/run shape as
    the LM :class:`Engine`: requests accumulate via :meth:`submit`, and
    :meth:`run` drains the queue -- class-constrained in-SP queries of
    one wave ride the batched device molecule-match lowering when
    ``backend="device"``, everything else evaluates on host.  Terms
    unknown to the dictionary yield empty binding sets (nothing can
    match a term the graph has never seen).

    :class:`BGPQueryRequest` entries in the same queue route through the
    cost-based BGP engine (``repro.query.bgp``): per-star raw-vs-
    factorized planning, filter pushdown, and molecule-level joins, with
    deferred stars of a request riding the batched device path.

    ``source`` is a *snapshot handle*, any of:

    * a bare ``FactorizedGraph`` (static graph, the original surface);
    * a ``repro.api.GraphSnapshot``;
    * an object with a ``.snapshot`` property (``repro.online.
      OnlineCompactionService``, ``repro.api.Compactor``) -- the live
      handle;
    * a zero-arg callable returning any of the above.

    Each ``run`` wave resolves the handle ONCE and serves the whole
    wave from that immutable snapshot: queries issued during an
    in-flight recompaction are answered from the old epoch (consistent,
    never torn) and the next wave picks up the swap.  The engine's
    device buffers are epoch-keyed, so a swap invalidates them without
    any cross-thread coordination.

    **Graceful degradation** (all counted, never silent):

    * ``max_pending`` bounds the admission queue -- a full queue sheds
      the submit (``submit`` returns ``False``; ``admission.shed``
      channel) instead of growing unboundedly;
    * ``wave_deadline_s`` budgets one ``run`` wave -- requests the
      budget cannot reach are answered with ``status="shed"`` and empty
      bindings (``wave.deadline_shed``), never dropped on the floor;
    * a factorized-path failure mid-wave falls back to raw ``expand()``
      evaluation for the affected requests (``status="degraded"``,
      ``wave.raw_fallback``) -- answers stay correct, only slower.
    """

    def __init__(self, source, *, backend: str = "host",
                 use_kernel: bool = True,
                 max_pending: int | None = None,
                 wave_deadline_s: float | None = None,
                 metrics=None, clock=None):
        import time

        from repro.online.metrics import MetricsHub
        from repro.query import QueryEngine
        self._source = source
        self.backend = backend
        snap = self._resolve()
        self.engine = QueryEngine(snap.fgraph, use_kernel=use_kernel,
                                  epoch=snap.epoch)
        self.queue: list[GraphQueryRequest] = []
        self.max_pending = max_pending
        self.wave_deadline_s = wave_deadline_s
        self.metrics = metrics if metrics is not None else MetricsHub()
        self._clock = clock if clock is not None else time.monotonic
        for ch in ("admission.shed", "wave.deadline_shed",
                   "wave.raw_fallback"):
            self.metrics.channel(ch)

    def _resolve(self):
        """Current snapshot from the handle (one atomic read)."""
        from repro.api.snapshot import GraphSnapshot
        src = self._source
        if callable(src):
            src = src()
        if isinstance(src, GraphSnapshot):
            return src
        snap = getattr(src, "snapshot", None)
        if snap is not None:
            return snap
        return GraphSnapshot(fgraph=src, epoch=0)   # bare FactorizedGraph

    @property
    def fgraph(self):
        """The fgraph a wave starting now would serve from."""
        return self._resolve().fgraph

    @property
    def epoch(self) -> int:
        return int(self._resolve().epoch)

    def submit(self, req: GraphQueryRequest) -> bool:
        """Admit ``req`` into the next wave.  Returns ``False`` (and
        counts ``admission.shed``) when the bounded queue is full --
        the caller owns retry/backpressure, the service never grows an
        unbounded backlog."""
        if self.max_pending is not None \
                and len(self.queue) >= self.max_pending:
            self.metrics.observe("admission.shed", 1)
            return False
        self.queue.append(req)
        return True

    def _compile(self, req: GraphQueryRequest, fgraph):
        return _compile_star(req, fgraph.store.dict)

    def _compile_bgp(self, req: BGPQueryRequest, fgraph):
        return _compile_bgp_query(req, fgraph.store.dict)

    def _run_bgp(self, req: BGPQueryRequest, snap) -> BGPQueryResponse:
        q = self._compile_bgp(req, snap.fgraph)
        if q is None:        # unknown term: nothing can match it
            return BGPQueryResponse(req.rid, (), [], (), 0)
        term = snap.fgraph.store.dict.term
        try:
            b, stats = self.engine.query_bgp(
                q, strategy=req.strategy, backend=self.backend,
                return_stats=True)
            strategies = stats["plan"].strategies
            status = "ok"
        except Exception:
            if req.strategy == "raw":
                raise            # the fallback path itself failed
            # factorized/auto path failed mid-wave: answer from the
            # raw expansion instead of failing the request (counted)
            self.metrics.observe("wave.raw_fallback", 1)
            b, stats = self.engine.query_bgp(
                q, strategy="raw", backend="host", return_stats=True)
            strategies = stats["plan"].strategies
            status = "degraded"
        return BGPQueryResponse(
            rid=req.rid, variables=b.columns,
            rows=[tuple(term(int(v)) for v in row) for row in b.rows],
            strategies=strategies, n_rows=b.n_rows, status=status)

    def _shed(self, req) -> "GraphQueryResponse | BGPQueryResponse":
        self.metrics.observe("wave.deadline_shed", 1)
        if isinstance(req, BGPQueryRequest):
            return BGPQueryResponse(req.rid, (), [], (), 0,
                                    status="shed")
        return GraphQueryResponse(req.rid, [], (), [], req.strategy, 0,
                                  status="shed")

    def run(self) -> dict[int, GraphQueryResponse]:
        batch, self.queue = self.queue, []
        if not batch:
            return {}
        deadline = (None if self.wave_deadline_s is None
                    else self._clock() + self.wave_deadline_s)

        def overdue():
            return deadline is not None and self._clock() >= deadline

        # resolve the handle once: the ENTIRE wave -- compilation,
        # batched match, term decoding -- reads this one immutable
        # snapshot, so a concurrent swap cannot tear a wave
        snap = self._resolve()
        self.engine.rebind(snap.fgraph, snap.epoch)
        term = snap.fgraph.store.dict.term
        out: dict[int, GraphQueryResponse] = {}
        bgps = [r for r in batch if isinstance(r, BGPQueryRequest)]
        batch = [r for r in batch if not isinstance(r, BGPQueryRequest)]
        for req in bgps:      # multi-star: planned + joined per request
            if overdue():     # deadline spent: explicit shed, not a drop
                out[req.rid] = self._shed(req)
                continue
            out[req.rid] = self._run_bgp(req, snap)
        if overdue():
            for req in batch:
                out[req.rid] = self._shed(req)
            return out
        compiled = [(req, self._compile(req, snap.fgraph)) for req in batch]
        # factorized queries of the wave evaluate as ONE batch (device
        # backend: one molecule-match lowering per class chunk)
        fact = [(req, q) for req, q in compiled
                if q is not None and req.strategy == "factorized"]
        degraded: set[int] = set()
        try:
            results = self.engine.query_batch([q for _, q in fact],
                                              backend=self.backend)
        except Exception:
            # batched factorized path failed mid-wave: every factorized
            # request of this wave re-evaluates on the raw expansion
            self.metrics.observe("wave.raw_fallback", len(fact))
            results = [self.engine.query(q, strategy="raw")
                       for _, q in fact]
            degraded = {req.rid for req, _ in fact}
        by_rid = {req.rid: b for (req, _), b in zip(fact, results)}
        for req, q in compiled:
            if q is None:
                out[req.rid] = GraphQueryResponse(
                    req.rid, [], (), [], req.strategy, 0)
                continue
            b = by_rid.get(req.rid)
            if b is None:                       # raw strategy, host only
                if overdue():
                    out[req.rid] = self._shed(req)
                    continue
                b = self.engine.query(q, strategy=req.strategy)
            out[req.rid] = GraphQueryResponse(
                rid=req.rid,
                subjects=[term(int(s)) for s in b.subjects],
                var_props=tuple(term(int(p)) for p in b.var_props),
                var_objects=[tuple(term(int(v)) for v in row)
                             for row in b.var_objects],
                strategy="raw" if req.rid in degraded else req.strategy,
                n_rows=b.n_rows,
                status="degraded" if req.rid in degraded else "ok")
        return out


class ShardedQueryService:
    """Fan-out request path over a ``repro.dist.ShardedFactorizedGraph``.

    One bounded :class:`GraphQueryService` per shard is the async
    request surface -- each shard keeps its own wave queue, and every
    per-shard knob (``max_pending`` admission bound, ``wave_deadline_s``
    shedding, the raw-expansion degraded fallback) applies *per shard*,
    exactly as on the replicated service.  Routing at submit:

    * class-constrained star requests enqueue on every shard that owns
      a chunk of the class (``ShardPlan.shards_for_class``).  Admission
      is all-or-nothing across the owners: if ANY owner's queue is full
      the whole submit sheds (``admission.shed``), never a torn
      fan-out.
    * classless star requests and BGP requests go to a coordinator
      queue (their answers need cross-shard per-arm unions / joins, not
      concatenation) evaluated by ``repro.dist.ShardedQueryEngine`` --
      only binding sets cross shards, bounded by the service's own
      ``max_pending`` and deadline.

    :meth:`run` drains every shard queue in parallel (one thread per
    shard) and merges per-request: typed subjects are uniquely owned,
    so the per-shard binding sets concatenate duplicate-free; the
    merged status is ``"shed"`` if any owner shed, else ``"degraded"``
    if any owner degraded, else ``"ok"``.

    Restart story: a shard rebuilt through ``repro.online.recover()``
    swaps back in with ``sharded.swap_shard(sid, service.snapshot)``;
    the next wave's per-shard handle resolution picks up the new epoch
    with no coordination beyond the atomic tuple store.
    """

    def __init__(self, sharded, *, backend: str = "host",
                 use_kernel: bool = True,
                 max_pending: int | None = None,
                 wave_deadline_s: float | None = None,
                 metrics=None, clock=None):
        import time

        from repro.dist.graph import ShardedQueryEngine
        from repro.online.metrics import MetricsHub
        self.sharded = sharded
        self.backend = backend
        self.metrics = metrics if metrics is not None else MetricsHub()
        self._clock = clock if clock is not None else time.monotonic
        self.max_pending = max_pending
        self.wave_deadline_s = wave_deadline_s
        self.shards = [
            GraphQueryService(
                (lambda sid=sid: self.sharded.snapshots[sid]),
                backend=backend, use_kernel=use_kernel,
                max_pending=max_pending,
                wave_deadline_s=wave_deadline_s,
                metrics=self.metrics, clock=self._clock)
            for sid in range(sharded.n_shards)]
        self.coordinator = ShardedQueryEngine(sharded,
                                              use_kernel=use_kernel)
        self.queue: list = []            # coordinator-evaluated requests
        self._fanout: dict[int, tuple[int, ...]] = {}  # rid -> shard ids
        self._raw_engine = None          # degraded fallback, epoch-keyed

    @property
    def n_shards(self) -> int:
        return self.sharded.n_shards

    def _owners(self, req: GraphQueryRequest) -> tuple[int, ...] | None:
        """Owning shards for a class-routed star request; ``None`` when
        the request must evaluate at the coordinator instead."""
        if req.class_term is None:
            return None
        cid = self.sharded.dict.lookup(req.class_term)
        if cid is None:
            # unknown class: empty answer from any single shard
            return (0,)
        return self.sharded.plan.shards_for_class(int(cid))

    def submit(self, req) -> bool:
        """Admit ``req``; ``False`` (+ ``admission.shed``) when any
        target queue is full -- all-or-nothing across the fan-out."""
        if isinstance(req, BGPQueryRequest):
            owners = None
        else:
            owners = self._owners(req)
        if owners is None:
            if self.max_pending is not None \
                    and len(self.queue) >= self.max_pending:
                self.metrics.observe("admission.shed", 1)
                return False
            self.queue.append(req)
            return True
        # capacity pre-check across every owner BEFORE any enqueue
        if any(s.max_pending is not None
               and len(s.queue) >= s.max_pending
               for s in (self.shards[sid] for sid in owners)):
            self.metrics.observe("admission.shed", 1)
            return False
        for sid in owners:
            self.shards[sid].submit(req)
        self._fanout[req.rid] = tuple(owners)
        return True

    def _merge_star(self, req: GraphQueryRequest,
                    parts: list[GraphQueryResponse]) -> GraphQueryResponse:
        subjects: list[str] = []
        var_objects: list[tuple[str, ...]] = []
        var_props: tuple[str, ...] = ()
        for p in parts:
            subjects.extend(p.subjects)
            var_objects.extend(p.var_objects)
            if p.var_props:
                var_props = p.var_props
        status = "ok"
        if any(p.status == "degraded" for p in parts):
            status = "degraded"
        if any(p.status == "shed" for p in parts):
            status = "shed"     # partial: at least one owner unanswered
        self.sharded.traffic["query_bytes"] += sum(
            8 * (len(p.subjects) + sum(len(r) for r in p.var_objects))
            for p in parts)
        return GraphQueryResponse(
            rid=req.rid, subjects=subjects, var_props=var_props,
            var_objects=var_objects, strategy=parts[0].strategy
            if parts else req.strategy, n_rows=len(subjects),
            status=status)

    def _degraded(self):
        """Replicated raw-expansion engine (built lazily per epoch) --
        the answers-stay-correct fallback when the sharded path fails."""
        from repro.core.fgraph import FactorizedGraph
        from repro.query import QueryEngine
        epoch = self.sharded.epoch
        if self._raw_engine is None or self._raw_engine[0] != epoch:
            fg = FactorizedGraph(self.sharded.expand_union(), {})
            self._raw_engine = (epoch, QueryEngine(fg, use_kernel=False))
        return self._raw_engine[1]

    def _run_coordinator(self, out: dict) -> None:
        deadline = (None if self.wave_deadline_s is None
                    else self._clock() + self.wave_deadline_s)
        batch, self.queue = self.queue, []
        d = self.sharded.dict
        for req in batch:
            if deadline is not None and self._clock() >= deadline:
                self.metrics.observe("wave.deadline_shed", 1)
                if isinstance(req, BGPQueryRequest):
                    out[req.rid] = BGPQueryResponse(req.rid, (), [], (),
                                                    0, status="shed")
                else:
                    out[req.rid] = GraphQueryResponse(
                        req.rid, [], (), [], req.strategy, 0,
                        status="shed")
                continue
            if isinstance(req, BGPQueryRequest):
                q = _compile_bgp_query(req, d)
                if q is None:
                    out[req.rid] = BGPQueryResponse(req.rid, (), [], (), 0)
                    continue
                try:
                    b = self.coordinator.query_bgp(
                        q, strategy=req.strategy, backend=self.backend)
                    status = "ok"
                except Exception:
                    self.metrics.observe("wave.raw_fallback", 1)
                    b = self._degraded().query_bgp(q, strategy="raw")
                    status = "degraded"
                out[req.rid] = BGPQueryResponse(
                    rid=req.rid, variables=b.columns,
                    rows=[tuple(d.term(int(v)) for v in row)
                          for row in b.rows],
                    strategies=(), n_rows=b.n_rows, status=status)
            else:                        # classless star
                q = _compile_star(req, d)
                if q is None:
                    out[req.rid] = GraphQueryResponse(
                        req.rid, [], (), [], req.strategy, 0)
                    continue
                try:
                    b = self.coordinator.query(q, strategy=req.strategy)
                    status = "ok"
                except Exception:
                    self.metrics.observe("wave.raw_fallback", 1)
                    b = self._degraded().query(q, strategy="raw")
                    status = "degraded"
                out[req.rid] = GraphQueryResponse(
                    rid=req.rid,
                    subjects=[d.term(int(s)) for s in b.subjects],
                    var_props=tuple(d.term(int(p))
                                    for p in b.var_props),
                    var_objects=[tuple(d.term(int(v)) for v in row)
                                 for row in b.var_objects],
                    strategy=req.strategy, n_rows=b.n_rows,
                    status=status)

    def run(self) -> dict[int, GraphQueryResponse]:
        """Drain one wave: shard queues in parallel (one thread per
        shard -- each thread touches only its own service), coordinator
        queue on the caller's thread, then the fan-out merge."""
        from concurrent.futures import ThreadPoolExecutor
        self._fanout = {}
        out: dict[int, GraphQueryResponse] = {}
        self.coordinator.rebind()
        busy = [s for s in self.shards if s.queue]
        if busy:
            with ThreadPoolExecutor(max_workers=len(busy)) as ex:
                shard_outs = list(ex.map(lambda s: s.run(), busy))
        else:
            shard_outs = []
        self._run_coordinator(out)
        by_rid: dict[int, list] = {}
        for responses in shard_outs:
            for rid, resp in responses.items():
                by_rid.setdefault(rid, []).append(resp)
        for rid, parts in by_rid.items():
            req = GraphQueryRequest(rid=rid, arms=(), class_term=None,
                                    strategy=parts[0].strategy)
            out[rid] = self._merge_star(req, parts)
        return out


class Engine:
    def __init__(self, model, params, *, cache_len: int = 512,
                 chunk: int = 64, ctx: Ctx | None = None,
                 policy: str | PrefixPolicy = "auto",
                 share_prefixes: bool | None = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.chunk = chunk
        if share_prefixes is not None:
            warnings.warn(
                "Engine(share_prefixes=...) is deprecated; use "
                "policy='auto' or 'flat'", DeprecationWarning, stacklevel=2)
            policy = "auto" if share_prefixes else "flat"
        self.policy = (policy if isinstance(policy, PrefixPolicy)
                       else PREFIX_POLICIES.get(policy))
        self.ctx = ctx or Ctx(cfg=model.cfg)
        self._prefill = jax.jit(make_prefill_step(
            model, ctx=self.ctx, cache_len=cache_len))
        self._decode = jax.jit(make_decode_step(model, ctx=self.ctx))
        self.queue: list[Request] = []
        self.last_plan = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- internals -------------------------------------------------------------
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim \
            * jnp.dtype(cfg.dtype).itemsize
        return float(per_layer * cfg.n_layers)

    def _plan_prefixes(self, tokens: np.ndarray):
        plan = plan_prefix_sharing(
            tokens, chunk=self.chunk,
            kv_bytes_per_token=self._kv_bytes_per_token())
        self.last_plan = plan
        return plan

    def _prefill_shared(self, tokens: np.ndarray, plan):
        if not plan.shares or plan.molecule_tokens.shape[0] == len(tokens):
            _, cache = self._prefill(self.params, jnp.asarray(tokens))
            return cache, tokens.shape[1]
        # 1. prefill molecules once each
        _, mol_cache = self._prefill(self.params,
                                     jnp.asarray(plan.molecule_tokens))
        # 2. expand to instances (the physical instanceOf edge), then
        #    prefill suffixes against the expanded cache
        idx = jnp.asarray(plan.instance_of)
        cache = jax.tree.map(lambda m: jnp.take(m, idx, axis=1), mol_cache)
        suffix = tokens[:, plan.suffix_start:]
        cur = cache
        b = tokens.shape[0]
        for t in range(suffix.shape[1]):       # suffix decode-extend
            pos = jnp.full((b, 1), plan.suffix_start + t, jnp.int32)
            _, cur = self._decode(self.params,
                                  jnp.asarray(suffix[:, t:t + 1]), cur, pos)
        return cur, tokens.shape[1]

    # -- main loop ---------------------------------------------------------------
    def run(self, *, max_new: int | None = None) -> dict[int, list[int]]:
        if not self.queue:
            return {}
        batch, self.queue = self.queue, []
        lens = {r.tokens.shape[0] for r in batch}
        if len(lens) != 1:
            # left-pad to a common length (static shapes)
            m = max(lens)
            toks = np.stack([np.pad(r.tokens, (m - len(r.tokens), 0))
                             for r in batch])
        else:
            toks = np.stack([r.tokens for r in batch])
        steps = max_new if max_new is not None else max(r.max_new
                                                        for r in batch)
        plan = self._plan_prefixes(toks) if self.policy.plan else None
        if self.policy.share and plan is not None:
            cache, pos0 = self._prefill_shared(toks, plan)
            # next token from one decode of the last prompt token
            last = jnp.asarray(toks[:, -1:])
            posv = jnp.full((len(batch), 1), pos0 - 1, jnp.int32)
            nxt, cache = self._decode(self.params, last, cache, posv)
        else:
            nxt, cache = self._prefill(self.params, jnp.asarray(toks))
            pos0 = toks.shape[1]
        outs = {r.rid: [int(t)] for r, t in zip(batch, np.asarray(nxt))}
        cur = nxt[:, None]
        for t in range(1, steps):
            pos = jnp.full((len(batch), 1), pos0 + t - 1, jnp.int32)
            cur, cache = self._decode(self.params, cur, cache, pos)
            for r, tok in zip(batch, np.asarray(cur)):
                outs[r.rid].append(int(tok))
            cur = cur[:, None]
        for r in batch:
            r.out = outs[r.rid]
        return outs

"""Batched serving engine with factorized shared prefixes.

Flow per admission wave (continuous batching):

  1. collect queued requests into the next batch;
  2. ``plan_prefix_sharing`` (the paper's #Edges-in-bytes objective)
     decides the shared depth d*;
  3. prefill each distinct MOLECULE once (batch of n_molecules), then
     broadcast molecule KV into the per-request slots ("instanceOf"
     expansion) and prefill only the per-request suffixes;
  4. greedy decode steps over the whole batch until max_new or eos.

When the planner declines to share (paper Fig. 7 overhead case) the
engine transparently falls back to plain batched prefill.  Shared and
unshared paths produce identical tokens (asserted in tests/test_serving).

Prefix compaction is selected by *named policy* (same strategy style as
``repro.api``): ``"auto"`` runs the bytes-objective planner and honors
its decision, ``"flat"`` skips planning and serves plain batched
prefill, ``"measure"`` plans (populating ``Engine.last_plan`` with the
would-be savings) but serves flat.  The old ``share_prefixes=`` boolean
is kept as a deprecated alias.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.registry import Registry
from repro.models.blocks import Ctx
from repro.train.serve_step import make_decode_step, make_prefill_step
from .prefix_factorization import plan_prefix_sharing


@dataclasses.dataclass(frozen=True)
class PrefixPolicy:
    """Named KV-prefix compaction strategy.

    ``plan`` runs the #Edges-in-bytes planner (``Engine.last_plan`` is
    populated); ``share`` additionally honors a positive sharing
    decision.  ``measure`` plans without sharing -- flat serving plus the
    would-be savings report.
    """

    name: str
    plan: bool       # run the #Edges-in-bytes planner
    share: bool      # honor a positive sharing decision


PREFIX_POLICIES = Registry("prefix policy")
PREFIX_POLICIES.register("auto", PrefixPolicy("auto", plan=True, share=True))
PREFIX_POLICIES.register("flat", PrefixPolicy("flat", plan=False,
                                              share=False))
PREFIX_POLICIES.register("measure", PrefixPolicy("measure", plan=True,
                                                 share=False))


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray               # (L,) prompt
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, model, params, *, cache_len: int = 512,
                 chunk: int = 64, ctx: Ctx | None = None,
                 policy: str | PrefixPolicy = "auto",
                 share_prefixes: bool | None = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.chunk = chunk
        if share_prefixes is not None:
            warnings.warn(
                "Engine(share_prefixes=...) is deprecated; use "
                "policy='auto' or 'flat'", DeprecationWarning, stacklevel=2)
            policy = "auto" if share_prefixes else "flat"
        self.policy = (policy if isinstance(policy, PrefixPolicy)
                       else PREFIX_POLICIES.get(policy))
        self.ctx = ctx or Ctx(cfg=model.cfg)
        self._prefill = jax.jit(make_prefill_step(
            model, ctx=self.ctx, cache_len=cache_len))
        self._decode = jax.jit(make_decode_step(model, ctx=self.ctx))
        self.queue: list[Request] = []
        self.last_plan = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- internals -------------------------------------------------------------
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim \
            * jnp.dtype(cfg.dtype).itemsize
        return float(per_layer * cfg.n_layers)

    def _plan_prefixes(self, tokens: np.ndarray):
        plan = plan_prefix_sharing(
            tokens, chunk=self.chunk,
            kv_bytes_per_token=self._kv_bytes_per_token())
        self.last_plan = plan
        return plan

    def _prefill_shared(self, tokens: np.ndarray, plan):
        if not plan.shares or plan.molecule_tokens.shape[0] == len(tokens):
            _, cache = self._prefill(self.params, jnp.asarray(tokens))
            return cache, tokens.shape[1]
        # 1. prefill molecules once each
        _, mol_cache = self._prefill(self.params,
                                     jnp.asarray(plan.molecule_tokens))
        # 2. expand to instances (the physical instanceOf edge), then
        #    prefill suffixes against the expanded cache
        idx = jnp.asarray(plan.instance_of)
        cache = jax.tree.map(lambda m: jnp.take(m, idx, axis=1), mol_cache)
        suffix = tokens[:, plan.suffix_start:]
        cur = cache
        b = tokens.shape[0]
        for t in range(suffix.shape[1]):       # suffix decode-extend
            pos = jnp.full((b, 1), plan.suffix_start + t, jnp.int32)
            _, cur = self._decode(self.params,
                                  jnp.asarray(suffix[:, t:t + 1]), cur, pos)
        return cur, tokens.shape[1]

    # -- main loop ---------------------------------------------------------------
    def run(self, *, max_new: int | None = None) -> dict[int, list[int]]:
        if not self.queue:
            return {}
        batch, self.queue = self.queue, []
        lens = {r.tokens.shape[0] for r in batch}
        if len(lens) != 1:
            # left-pad to a common length (static shapes)
            m = max(lens)
            toks = np.stack([np.pad(r.tokens, (m - len(r.tokens), 0))
                             for r in batch])
        else:
            toks = np.stack([r.tokens for r in batch])
        steps = max_new if max_new is not None else max(r.max_new
                                                        for r in batch)
        plan = self._plan_prefixes(toks) if self.policy.plan else None
        if self.policy.share and plan is not None:
            cache, pos0 = self._prefill_shared(toks, plan)
            # next token from one decode of the last prompt token
            last = jnp.asarray(toks[:, -1:])
            posv = jnp.full((len(batch), 1), pos0 - 1, jnp.int32)
            nxt, cache = self._decode(self.params, last, cache, posv)
        else:
            nxt, cache = self._prefill(self.params, jnp.asarray(toks))
            pos0 = toks.shape[1]
        outs = {r.rid: [int(t)] for r, t in zip(batch, np.asarray(nxt))}
        cur = nxt[:, None]
        for t in range(1, steps):
            pos = jnp.full((len(batch), 1), pos0 + t - 1, jnp.int32)
            cur, cache = self._decode(self.params, cur, cache, pos)
            for r, tok in zip(batch, np.asarray(cur)):
                outs[r.rid].append(int(tok))
            cur = cur[:, None]
        for r in batch:
            r.out = outs[r.rid]
        return outs

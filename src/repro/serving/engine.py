"""Batched serving engine with factorized shared prefixes.

Flow per admission wave (continuous batching):

  1. collect queued requests into the next batch;
  2. ``plan_prefix_sharing`` (the paper's #Edges-in-bytes objective)
     decides the shared depth d*;
  3. prefill each distinct MOLECULE once (batch of n_molecules), then
     broadcast molecule KV into the per-request slots ("instanceOf"
     expansion) and prefill only the per-request suffixes;
  4. greedy decode steps over the whole batch until max_new or eos.

When the planner declines to share (paper Fig. 7 overhead case) the
engine transparently falls back to plain batched prefill.  Shared and
unshared paths produce identical tokens (asserted in tests/test_serving).

Prefix compaction is selected by *named policy* (same strategy style as
``repro.api``): ``"auto"`` runs the bytes-objective planner and honors
its decision, ``"flat"`` skips planning and serves plain batched
prefill, ``"measure"`` plans (populating ``Engine.last_plan`` with the
would-be savings) but serves flat.  The old ``share_prefixes=`` boolean
is kept as a deprecated alias.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.registry import Registry
from repro.models.blocks import Ctx
from repro.train.serve_step import make_decode_step, make_prefill_step
from .prefix_factorization import plan_prefix_sharing


@dataclasses.dataclass(frozen=True)
class PrefixPolicy:
    """Named KV-prefix compaction strategy.

    ``plan`` runs the #Edges-in-bytes planner (``Engine.last_plan`` is
    populated); ``share`` additionally honors a positive sharing
    decision.  ``measure`` plans without sharing -- flat serving plus the
    would-be savings report.
    """

    name: str
    plan: bool       # run the #Edges-in-bytes planner
    share: bool      # honor a positive sharing decision


PREFIX_POLICIES = Registry("prefix policy")
PREFIX_POLICIES.register("auto", PrefixPolicy("auto", plan=True, share=True))
PREFIX_POLICIES.register("flat", PrefixPolicy("flat", plan=False,
                                              share=False))
PREFIX_POLICIES.register("measure", PrefixPolicy("measure", plan=True,
                                                 share=False))


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray               # (L,) prompt
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


# -- graph-query endpoint -----------------------------------------------------

@dataclasses.dataclass
class GraphQueryRequest:
    """One star BGP at the term level: ``arms`` are (property term,
    object term or None-for-variable) pairs, plus an optional class."""

    rid: int
    arms: tuple[tuple[str, str | None], ...]
    class_term: str | None = None
    strategy: str = "factorized"     # "factorized" | "raw"


@dataclasses.dataclass
class GraphQueryResponse:
    rid: int
    subjects: list[str]
    var_props: tuple[str, ...]
    var_objects: list[tuple[str, ...]]   # aligned with subjects
    strategy: str
    n_rows: int
    # "ok" | "degraded" (factorized path failed; answered via raw
    # fallback) | "shed" (per-wave deadline exhausted; NOT evaluated)
    status: str = "ok"


@dataclasses.dataclass
class BGPQueryRequest:
    """A multi-star BGP at the term level.  Each star is ``(subject,
    arms, class_term)`` with arms as (property term, object term) pairs;
    any term starting with ``"?"`` is a variable (subjects must be
    variables).  ``filters`` are ``(var, op, value term)`` triples with
    ``op`` one of ``== != < <= > >=``."""

    rid: int
    stars: tuple[tuple[str, tuple[tuple[str, str], ...], str | None], ...]
    filters: tuple[tuple[str, str, str], ...] = ()
    strategy: str = "auto"           # "auto" | "raw" | "factorized"


@dataclasses.dataclass
class BGPQueryResponse:
    rid: int
    variables: tuple[str, ...]       # canonical output column order
    rows: list[tuple[str, ...]]      # decoded bindings, aligned
    strategies: tuple[str, ...]      # planner's per-star choices
    n_rows: int
    status: str = "ok"               # "ok" | "degraded" | "shed"


class GraphQueryService:
    """Star-query endpoint over a compacted graph (the paper's "queries
    get faster on G'" claim, served).

    Wraps a ``repro.query.QueryEngine`` with the same queue/run shape as
    the LM :class:`Engine`: requests accumulate via :meth:`submit`, and
    :meth:`run` drains the queue -- class-constrained in-SP queries of
    one wave ride the batched device molecule-match lowering when
    ``backend="device"``, everything else evaluates on host.  Terms
    unknown to the dictionary yield empty binding sets (nothing can
    match a term the graph has never seen).

    :class:`BGPQueryRequest` entries in the same queue route through the
    cost-based BGP engine (``repro.query.bgp``): per-star raw-vs-
    factorized planning, filter pushdown, and molecule-level joins, with
    deferred stars of a request riding the batched device path.

    ``source`` is a *snapshot handle*, any of:

    * a bare ``FactorizedGraph`` (static graph, the original surface);
    * a ``repro.api.GraphSnapshot``;
    * an object with a ``.snapshot`` property (``repro.online.
      OnlineCompactionService``, ``repro.api.Compactor``) -- the live
      handle;
    * a zero-arg callable returning any of the above.

    Each ``run`` wave resolves the handle ONCE and serves the whole
    wave from that immutable snapshot: queries issued during an
    in-flight recompaction are answered from the old epoch (consistent,
    never torn) and the next wave picks up the swap.  The engine's
    device buffers are epoch-keyed, so a swap invalidates them without
    any cross-thread coordination.

    **Graceful degradation** (all counted, never silent):

    * ``max_pending`` bounds the admission queue -- a full queue sheds
      the submit (``submit`` returns ``False``; ``admission.shed``
      channel) instead of growing unboundedly;
    * ``wave_deadline_s`` budgets one ``run`` wave -- requests the
      budget cannot reach are answered with ``status="shed"`` and empty
      bindings (``wave.deadline_shed``), never dropped on the floor;
    * a factorized-path failure mid-wave falls back to raw ``expand()``
      evaluation for the affected requests (``status="degraded"``,
      ``wave.raw_fallback``) -- answers stay correct, only slower.
    """

    def __init__(self, source, *, backend: str = "host",
                 use_kernel: bool = True,
                 max_pending: int | None = None,
                 wave_deadline_s: float | None = None,
                 metrics=None, clock=None):
        import time

        from repro.online.metrics import MetricsHub
        from repro.query import QueryEngine
        self._source = source
        self.backend = backend
        snap = self._resolve()
        self.engine = QueryEngine(snap.fgraph, use_kernel=use_kernel,
                                  epoch=snap.epoch)
        self.queue: list[GraphQueryRequest] = []
        self.max_pending = max_pending
        self.wave_deadline_s = wave_deadline_s
        self.metrics = metrics if metrics is not None else MetricsHub()
        self._clock = clock if clock is not None else time.monotonic
        for ch in ("admission.shed", "wave.deadline_shed",
                   "wave.raw_fallback"):
            self.metrics.channel(ch)

    def _resolve(self):
        """Current snapshot from the handle (one atomic read)."""
        from repro.api.snapshot import GraphSnapshot
        src = self._source
        if callable(src):
            src = src()
        if isinstance(src, GraphSnapshot):
            return src
        snap = getattr(src, "snapshot", None)
        if snap is not None:
            return snap
        return GraphSnapshot(fgraph=src, epoch=0)   # bare FactorizedGraph

    @property
    def fgraph(self):
        """The fgraph a wave starting now would serve from."""
        return self._resolve().fgraph

    @property
    def epoch(self) -> int:
        return int(self._resolve().epoch)

    def submit(self, req: GraphQueryRequest) -> bool:
        """Admit ``req`` into the next wave.  Returns ``False`` (and
        counts ``admission.shed``) when the bounded queue is full --
        the caller owns retry/backpressure, the service never grows an
        unbounded backlog."""
        if self.max_pending is not None \
                and len(self.queue) >= self.max_pending:
            self.metrics.observe("admission.shed", 1)
            return False
        self.queue.append(req)
        return True

    def _compile(self, req: GraphQueryRequest, fgraph):
        from repro.query import StarQuery
        d = fgraph.store.dict
        cid = None
        if req.class_term is not None:
            cid = d.lookup(req.class_term)
            if cid is None:
                return None
        arms = []
        for p, o in req.arms:
            pid = d.lookup(p)
            if pid is None:
                return None
            if o is None:
                arms.append((pid, None))
            else:
                oid = d.lookup(o)
                if oid is None:
                    return None
                arms.append((pid, oid))
        return StarQuery(arms=tuple(arms), class_id=cid)

    def _compile_bgp(self, req: BGPQueryRequest, fgraph):
        from repro.query import BGPQuery, Filter, StarPattern
        from repro.query.bgp import is_var
        d = fgraph.store.dict

        def enc(t):
            return t if is_var(t) else d.lookup(t)

        stars = []
        for subject, arms, class_term in req.stars:
            cid = None
            if class_term is not None:
                cid = d.lookup(class_term)
                if cid is None:
                    return None
            enc_arms = []
            for p, o in arms:
                pid, oid = d.lookup(p), enc(o)
                if pid is None or oid is None:
                    return None
                enc_arms.append((pid, oid))
            stars.append(StarPattern(subject, tuple(enc_arms),
                                     class_id=cid))
        filters = []
        for var, op, value in req.filters:
            vid = d.lookup(value)
            if vid is None:
                return None
            filters.append(Filter(var, op, vid))
        return BGPQuery(stars=tuple(stars), filters=tuple(filters))

    def _run_bgp(self, req: BGPQueryRequest, snap) -> BGPQueryResponse:
        q = self._compile_bgp(req, snap.fgraph)
        if q is None:        # unknown term: nothing can match it
            return BGPQueryResponse(req.rid, (), [], (), 0)
        term = snap.fgraph.store.dict.term
        try:
            b, stats = self.engine.query_bgp(
                q, strategy=req.strategy, backend=self.backend,
                return_stats=True)
            strategies = stats["plan"].strategies
            status = "ok"
        except Exception:
            if req.strategy == "raw":
                raise            # the fallback path itself failed
            # factorized/auto path failed mid-wave: answer from the
            # raw expansion instead of failing the request (counted)
            self.metrics.observe("wave.raw_fallback", 1)
            b, stats = self.engine.query_bgp(
                q, strategy="raw", backend="host", return_stats=True)
            strategies = stats["plan"].strategies
            status = "degraded"
        return BGPQueryResponse(
            rid=req.rid, variables=b.columns,
            rows=[tuple(term(int(v)) for v in row) for row in b.rows],
            strategies=strategies, n_rows=b.n_rows, status=status)

    def _shed(self, req) -> "GraphQueryResponse | BGPQueryResponse":
        self.metrics.observe("wave.deadline_shed", 1)
        if isinstance(req, BGPQueryRequest):
            return BGPQueryResponse(req.rid, (), [], (), 0,
                                    status="shed")
        return GraphQueryResponse(req.rid, [], (), [], req.strategy, 0,
                                  status="shed")

    def run(self) -> dict[int, GraphQueryResponse]:
        batch, self.queue = self.queue, []
        if not batch:
            return {}
        deadline = (None if self.wave_deadline_s is None
                    else self._clock() + self.wave_deadline_s)

        def overdue():
            return deadline is not None and self._clock() >= deadline

        # resolve the handle once: the ENTIRE wave -- compilation,
        # batched match, term decoding -- reads this one immutable
        # snapshot, so a concurrent swap cannot tear a wave
        snap = self._resolve()
        self.engine.rebind(snap.fgraph, snap.epoch)
        term = snap.fgraph.store.dict.term
        out: dict[int, GraphQueryResponse] = {}
        bgps = [r for r in batch if isinstance(r, BGPQueryRequest)]
        batch = [r for r in batch if not isinstance(r, BGPQueryRequest)]
        for req in bgps:      # multi-star: planned + joined per request
            if overdue():     # deadline spent: explicit shed, not a drop
                out[req.rid] = self._shed(req)
                continue
            out[req.rid] = self._run_bgp(req, snap)
        if overdue():
            for req in batch:
                out[req.rid] = self._shed(req)
            return out
        compiled = [(req, self._compile(req, snap.fgraph)) for req in batch]
        # factorized queries of the wave evaluate as ONE batch (device
        # backend: one molecule-match lowering per class chunk)
        fact = [(req, q) for req, q in compiled
                if q is not None and req.strategy == "factorized"]
        degraded: set[int] = set()
        try:
            results = self.engine.query_batch([q for _, q in fact],
                                              backend=self.backend)
        except Exception:
            # batched factorized path failed mid-wave: every factorized
            # request of this wave re-evaluates on the raw expansion
            self.metrics.observe("wave.raw_fallback", len(fact))
            results = [self.engine.query(q, strategy="raw")
                       for _, q in fact]
            degraded = {req.rid for req, _ in fact}
        by_rid = {req.rid: b for (req, _), b in zip(fact, results)}
        for req, q in compiled:
            if q is None:
                out[req.rid] = GraphQueryResponse(
                    req.rid, [], (), [], req.strategy, 0)
                continue
            b = by_rid.get(req.rid)
            if b is None:                       # raw strategy, host only
                if overdue():
                    out[req.rid] = self._shed(req)
                    continue
                b = self.engine.query(q, strategy=req.strategy)
            out[req.rid] = GraphQueryResponse(
                rid=req.rid,
                subjects=[term(int(s)) for s in b.subjects],
                var_props=tuple(term(int(p)) for p in b.var_props),
                var_objects=[tuple(term(int(v)) for v in row)
                             for row in b.var_objects],
                strategy="raw" if req.rid in degraded else req.strategy,
                n_rows=b.n_rows,
                status="degraded" if req.rid in degraded else "ok")
        return out


class Engine:
    def __init__(self, model, params, *, cache_len: int = 512,
                 chunk: int = 64, ctx: Ctx | None = None,
                 policy: str | PrefixPolicy = "auto",
                 share_prefixes: bool | None = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.cache_len = cache_len
        self.chunk = chunk
        if share_prefixes is not None:
            warnings.warn(
                "Engine(share_prefixes=...) is deprecated; use "
                "policy='auto' or 'flat'", DeprecationWarning, stacklevel=2)
            policy = "auto" if share_prefixes else "flat"
        self.policy = (policy if isinstance(policy, PrefixPolicy)
                       else PREFIX_POLICIES.get(policy))
        self.ctx = ctx or Ctx(cfg=model.cfg)
        self._prefill = jax.jit(make_prefill_step(
            model, ctx=self.ctx, cache_len=cache_len))
        self._decode = jax.jit(make_decode_step(model, ctx=self.ctx))
        self.queue: list[Request] = []
        self.last_plan = None

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- internals -------------------------------------------------------------
    def _kv_bytes_per_token(self) -> float:
        cfg = self.cfg
        per_layer = 2 * cfg.n_kv_heads * cfg.resolved_head_dim \
            * jnp.dtype(cfg.dtype).itemsize
        return float(per_layer * cfg.n_layers)

    def _plan_prefixes(self, tokens: np.ndarray):
        plan = plan_prefix_sharing(
            tokens, chunk=self.chunk,
            kv_bytes_per_token=self._kv_bytes_per_token())
        self.last_plan = plan
        return plan

    def _prefill_shared(self, tokens: np.ndarray, plan):
        if not plan.shares or plan.molecule_tokens.shape[0] == len(tokens):
            _, cache = self._prefill(self.params, jnp.asarray(tokens))
            return cache, tokens.shape[1]
        # 1. prefill molecules once each
        _, mol_cache = self._prefill(self.params,
                                     jnp.asarray(plan.molecule_tokens))
        # 2. expand to instances (the physical instanceOf edge), then
        #    prefill suffixes against the expanded cache
        idx = jnp.asarray(plan.instance_of)
        cache = jax.tree.map(lambda m: jnp.take(m, idx, axis=1), mol_cache)
        suffix = tokens[:, plan.suffix_start:]
        cur = cache
        b = tokens.shape[0]
        for t in range(suffix.shape[1]):       # suffix decode-extend
            pos = jnp.full((b, 1), plan.suffix_start + t, jnp.int32)
            _, cur = self._decode(self.params,
                                  jnp.asarray(suffix[:, t:t + 1]), cur, pos)
        return cur, tokens.shape[1]

    # -- main loop ---------------------------------------------------------------
    def run(self, *, max_new: int | None = None) -> dict[int, list[int]]:
        if not self.queue:
            return {}
        batch, self.queue = self.queue, []
        lens = {r.tokens.shape[0] for r in batch}
        if len(lens) != 1:
            # left-pad to a common length (static shapes)
            m = max(lens)
            toks = np.stack([np.pad(r.tokens, (m - len(r.tokens), 0))
                             for r in batch])
        else:
            toks = np.stack([r.tokens for r in batch])
        steps = max_new if max_new is not None else max(r.max_new
                                                        for r in batch)
        plan = self._plan_prefixes(toks) if self.policy.plan else None
        if self.policy.share and plan is not None:
            cache, pos0 = self._prefill_shared(toks, plan)
            # next token from one decode of the last prompt token
            last = jnp.asarray(toks[:, -1:])
            posv = jnp.full((len(batch), 1), pos0 - 1, jnp.int32)
            nxt, cache = self._decode(self.params, last, cache, posv)
        else:
            nxt, cache = self._prefill(self.params, jnp.asarray(toks))
            pos0 = toks.shape[1]
        outs = {r.rid: [int(t)] for r, t in zip(batch, np.asarray(nxt))}
        cur = nxt[:, None]
        for t in range(1, steps):
            pos = jnp.full((len(batch), 1), pos0 + t - 1, jnp.int32)
            cur, cache = self._decode(self.params, cur, cache, pos)
            for r, tok in zip(batch, np.asarray(cur)):
                outs[r.rid].append(int(tok))
            cur = cur[:, None]
        for r in batch:
            r.out = outs[r.rid]
        return outs

"""Train-step builder: loss, grad (+ accumulation), optimizer apply.

Design points for the 512-chip mesh:

* **Gradient accumulation** (``cfg.grad_accum``) runs microbatches under
  ``jax.lax.scan``; the accumulator dtype follows ``cfg.param_dtype`` for
  FSDP archs (405B-class: a second f32 copy of the grads does not fit) and
  f32 otherwise.
* **Gradient compression hook**: when a ``pod`` axis is present, the
  cross-pod gradient reduction can be routed through
  ``dist/compression.py`` (int8 + error feedback) -- plumbed via
  ``compress_fn``; identity by default so the baseline stays faithful.
* All functions are pure; sharding is injected from the outside
  (``dist/sharding.py``) via jit in/out shardings + internal
  ``with_sharding_constraint`` hints carried in the model ``Ctx``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import causal_cross_entropy
from .optimizer import Optimizer, global_norm


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array     # () int32


def init_train_state(model, optimizer: Optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def loss_fn(model, params, batch, *, ctx, aux_coef: float = 0.01):
    logits, aux = model.forward(
        params, batch["tokens"], ctx=ctx,
        frontend_embeds=batch.get("frontend"))
    ce = causal_cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + aux_coef * aux, {"ce": ce, "aux": aux}


def make_train_step(model, optimizer: Optimizer, *, ctx,
                    grad_accum: int = 1,
                    compress_fn: Callable | None = None,
                    grad_shardings=None,
                    donate: bool = True) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch``: {"tokens": (B, T) i32, "labels": (B, T) i32,
                optional "mask": (B, T), optional "frontend": (B, P, F)}.
    With ``grad_accum=k`` the leading batch dim is split into k
    microbatches; ``grad_shardings`` (param-tree of NamedShardings) anchors
    the accumulator -- an unconstrained scan carry of the full gradient
    tree otherwise replicates onto every device (405B: 1.6 TB).
    """
    cfg = model.cfg
    accum_dtype = jnp.dtype(cfg.param_dtype) if cfg.fsdp else jnp.float32

    def _anchor(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, ctx=ctx), has_aux=True)(params)
        return loss, metrics, grads

    def accumulate(params, batch):
        if grad_accum <= 1:
            return grads_of(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                + x.shape[1:]), batch)

        def body(acc, mb):
            loss_a, g_acc = acc
            loss, metrics, g = grads_of(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), g_acc, g)
            return (loss_a + loss, _anchor(g_acc)), metrics

        zeros = _anchor(jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params))
        (loss_sum, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        grads = jax.tree.map(lambda g: g / grad_accum, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = accumulate(state.params, batch)
        if compress_fn is not None:
            grads = compress_fn(grads)
        new_params, new_opt = optimizer.update(
            grads, state.opt, state.params, state.step)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads),
                       step=state.step)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def batch_specs(cfg, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for one training batch (dry-run inputs)."""
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend != "none":
        fd = cfg.frontend_dim or cfg.d_model
        out["frontend"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, fd), jnp.dtype(cfg.dtype))
    return out

"""Serve-step builders: prefill (build cache) and decode (one token).

The assigned ``decode_32k`` / ``long_500k`` cells lower ``decode_step``:
one new token against a KV/state cache of ``seq_len``.  Sampling is greedy
(argmax) by default with a temperature path; batched requests share one
compiled step (continuous batching happens in ``serving/engine.py``).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import specs_to_shapes


def make_prefill_step(model, *, ctx, cache_len: int) -> Callable:
    def prefill(params, tokens, frontend_embeds=None):
        logits, cache = model.prefill(params, tokens, ctx=ctx,
                                      cache_len=cache_len,
                                      frontend_embeds=frontend_embeds)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill


def make_decode_step(model, *, ctx, temperature: float = 0.0) -> Callable:
    def decode(params, tokens, cache, positions, rng=None):
        """tokens, positions: (B, 1).  Returns (next (B,), new_cache)."""
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              positions, ctx=ctx)
        last = logits[:, -1].astype(jnp.float32)
        if temperature > 0.0:
            next_tok = jax.random.categorical(rng, last / temperature)
        else:
            next_tok = jnp.argmax(last, axis=-1)
        return next_tok.astype(jnp.int32), new_cache
    return decode


def decode_input_specs(model, batch: int, cache_len: int) -> dict[str, Any]:
    """ShapeDtypeStructs for one decode step (dry-run inputs)."""
    cache = specs_to_shapes(model.cache_specs(batch, cache_len))
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "positions": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "cache": cache,
    }

"""Optimizers, from scratch (no optax in the offline environment).

Two production optimizers:

* ``adamw``     -- decoupled weight decay Adam; first/second moments stored
  in ``cfg.opt_state_dtype`` (f32 default, bf16 for the 405B-class archs
  where f32 moments do not fit 16 GB/chip HBM -- see DESIGN.md §5).
* ``adafactor`` -- factored second moment for rank >= 2 tensors (row/col
  statistics), full second moment for vectors.  ~0.5 byte/param of state
  for the big embeddings; the memory-bound option.

State trees mirror the parameter tree leaf-for-leaf, so the FSDP/TP
shardings derived for parameters apply verbatim to optimizer state (ZeRO-3
by construction: whoever owns a param shard owns its moment shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    """A pair of pure functions (same contract as optax)."""
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def _decay_mask(path) -> bool:
    """Weight decay only on matrices (skip norms/bias vectors)."""
    name = str(path[-1]) if path else ""
    return "ln" not in name and "norm" not in name


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0, state_dtype: str = "float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))
    sdt = jnp.dtype(state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sdt)  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        stepf = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** stepf
        c2 = 1.0 - b2 ** stepf
        lr_t = lr_fn(step)

        def upd(g, m, v, p, decay):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / c1
            vhat = v_new / c2
            step_dir = mhat / (jnp.sqrt(vhat) + eps)
            if decay:
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * step_dir
            return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

        flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
        decay_flags = [_decay_mask(path) and leaf.ndim >= 2
                       for path, leaf in flat_g]
        leaves_g = [leaf for _, leaf in flat_g]
        treedef = jax.tree.structure(grads)
        leaves_m = jax.tree.leaves(state["m"])
        leaves_v = jax.tree.leaves(state["v"])
        leaves_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p, d) for g, m, v, p, d in
               zip(leaves_g, leaves_m, leaves_v, leaves_p, decay_flags)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        del gnorm  # reported by train_step (state tree must be stable)
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(lr: Callable | float, *, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018) with factored 2nd moment."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        def state_of(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(state_of, params,
                                  is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        stepf = (step + 1).astype(jnp.float32)
        beta = 1.0 - stepf ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, eps))[..., None]
                u = gf * jax.lax.rsqrt(jnp.maximum(r, eps)) \
                    * jax.lax.rsqrt(jnp.maximum(vc[..., None, :], eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * pf
            return (pf - lr_t * u).astype(p.dtype), new_s

        is_state = lambda x: isinstance(x, dict) and (  # noqa: E731
            "v" in x or "vr" in x)
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = jax.tree.leaves(state["f"], is_leaf=is_state)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_f = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, {"f": new_f}

    return Optimizer(init, update)


def make_optimizer(cfg, *, base_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000) -> Optimizer:
    """Config-driven optimizer selection (cfg.optimizer, cfg.opt_state_dtype)."""
    sched = cosine_schedule(base_lr, warmup, total)
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched, state_dtype=cfg.opt_state_dtype)

"""JAX version compatibility shims (one place for every 0.4/0.5 split).

The installed toolchain pins jax 0.4.37; newer API names used across the
codebase resolve here:

* ``shard_map`` -- top-level ``jax.shard_map(..., check_vma=...)`` vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
* ``make_mesh`` -- ``axis_types=(AxisType.Auto, ...)`` vs no such kwarg
  (0.4.x meshes are unconditionally Auto, so dropping it is exact).
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:                    # jax 0.4.x: no axis_types concept
    AxisType = None

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
if not _NEW_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the API split.  ``check_vma`` (new name)
    maps onto ``check_rep`` (old name); both gate the same replication-
    invariance check."""
    if _NEW_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis_types where supported."""
    kwargs = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes),
                                 **kwargs)
        except TypeError:   # AxisType exists but make_mesh predates kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)

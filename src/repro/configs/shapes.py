"""Assigned input shapes (LM-family): each cell is (arch x shape).

  train_4k     seq_len=4,096   global_batch=256   -> train_step
  prefill_32k  seq_len=32,768  global_batch=32    -> serve prefill
  decode_32k   seq_len=32,768  global_batch=128   -> serve_step (1 new token,
                                                     KV/state cache = seq_len)
  long_500k    seq_len=524,288 global_batch=1     -> serve_step; requires a
                sub-quadratic arch (SSM / hybrid) -- full-attention archs are
                SKIPPED per the assignment and noted in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Kind


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("SKIP: long_500k needs sub-quadratic attention; "
                       f"{cfg.name} has global full attention")
    return True, ""

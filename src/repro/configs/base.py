"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (``src/repro/configs/<id>.py``)
plus the paper's own RDF workload config.  The schema covers every family in
the assignment: dense GQA transformers, MoE, SSM (mamba2/SSD), hybrid
(RG-LRU + local attention), encoder-decoder (whisper) and VLM backbones.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    # attention options
    qkv_bias: bool = False              # qwen2 style
    qk_norm: bool = False               # qwen3 style
    rope_theta: float = 10_000.0
    window: int | None = None           # local-attention window (hybrid)
    # layer pattern for hybrids: tuple of "attn" | "local" | "rglru" | "ssd"
    # cycled over n_layers; () -> all global attention (or all ssd for ssm)
    layer_pattern: tuple[str, ...] = ()
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                   # per-expert hidden (0 -> d_ff)
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU
    rglru_width: int = 0                # 0 -> d_model
    # encoder-decoder
    encoder_layers: int = 0
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    frontend_tokens: int = 1500         # encoder positions / image patches
    frontend_dim: int = 0               # stub embedding dim (0 -> d_model)
    # numerics / memory policy
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    opt_state_dtype: str = "float32"
    remat: bool = True
    # distribution policy
    tp: bool = True                     # tensor-parallel over the model axis;
    # False -> pure DP: batch shards over (pod, data, model).  Right call for
    # sub-1B archs and archs whose head counts do not divide the model axis
    # (qwen2's 14 heads / kv=2 -> GSPMD would shard head_dim and all-reduce
    # every attention chunk; see EXPERIMENTS §Perf iteration log).
    fsdp: bool = False                  # shard params over the data axis
    seq_shard: bool = False             # sequence-parallel residual stream
    grad_accum: int = 1                 # microbatch accumulation steps
    tied_embeddings: bool = True

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern:
            return tuple(self.layer_pattern[i % len(self.layer_pattern)]
                         for i in range(self.n_layers))
        if self.family == "ssm":
            return ("ssd",) * self.n_layers
        return ("attn",) * self.n_layers

    @property
    def homogeneous(self) -> bool:
        p = self.pattern
        return all(t == p[0] for t in p)

    @property
    def sub_quadratic(self) -> bool:
        """True if serving cost is sub-quadratic in sequence length (no
        global-attention layer) -- gates the long_500k shape."""
        return all(t in ("ssd", "rglru", "local") for t in self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tied_embeddings else 2)
        for kind in self.pattern:
            if kind in ("attn", "local"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
                if self.n_experts:
                    e_ff = self.moe_d_ff or f
                    total += d * self.n_experts + \
                        3 * self.n_experts * d * e_ff
                else:
                    total += 3 * d * f
            elif kind == "ssd":
                di, ns, hh = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * ns + hh) + di * d
            elif kind == "rglru":
                w = self.rglru_width or d
                total += 2 * d * w + w * d + 2 * w * w  # in/gate/out + gates
                if True:  # hybrid blocks keep an MLP
                    total += 3 * d * f
        total += self.encoder_layers * (
            d * hd * (self.n_heads + 2 * self.n_kv_heads)
            + self.n_heads * hd * d + 3 * d * f)
        return total

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.n_params
        e_ff = self.moe_d_ff or self.d_ff
        per_layer_unused = 3 * (self.n_experts - self.experts_per_token) \
            * self.d_model * e_ff
        return self.n_params - per_layer_unused * self.n_layers


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    scale = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.layer_pattern
                     else len(cfg.layer_pattern)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_chunk=16,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.experts_per_token else 0,
        moe_d_ff=64 if cfg.n_experts else 0,
        rglru_width=128 if cfg.rglru_width else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_tokens=16,
        frontend_dim=64 if cfg.frontend_dim else 0,
        window=min(cfg.window, 32) if cfg.window else None,
        dtype="float32",
        param_dtype="float32",
        fsdp=False,
        seq_shard=False,
        grad_accum=1,
    )
    scale.update(overrides)
    return dataclasses.replace(cfg, **scale)

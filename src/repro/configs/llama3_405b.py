"""llama3-405b [dense]: 126L d16384 128H (GQA kv=8) ff53248 v128256
[arXiv:2407.21783; unverified].

Memory policy at 256 chips x 16 GB: bf16 params + adafactor (factored
second moment), FSDP over the data axis, sequence-parallel residual
stream, 8-way gradient accumulation."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8,
    d_ff=53_248, vocab_size=128_256, head_dim=128,
    rope_theta=500_000.0, tied_embeddings=False,
    optimizer="adafactor", fsdp=True, seq_shard=True, grad_accum=8,
)

"""whisper-medium [audio]: 24L d1024 16H ff4096 v51865 -- enc-dec backbone;
conv frontend is a STUB (precomputed frame embeddings) [arXiv:2212.04356;
unverified].  rope_theta=0 selects learned absolute positions (whisper
style)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51_865, head_dim=64,
    rope_theta=0.0, encoder_layers=24,
    frontend="audio_stub", frontend_tokens=1500,
    tied_embeddings=True, seq_shard=True,
)

"""internvl2-2b [vlm]: 24L d2048 16H (GQA kv=8) ff8192 v92553 -- InternViT +
InternLM2 backbone; vision frontend is a STUB (precomputed patch embeddings,
256 tokens of dim 1024 projected into the LM) [arXiv:2404.16821; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab_size=92_553, head_dim=128,
    rope_theta=1_000_000.0,
    frontend="vision_stub", frontend_tokens=256, frontend_dim=1024,
    tied_embeddings=True, seq_shard=True,
)

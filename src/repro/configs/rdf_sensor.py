"""The paper's own workload: LinkedSensorData-scale FSP detection +
factorization (not an LM arch; consumed by core/distributed.py and the
benchmarks).  D1/D1D2/D1D2D3 mirror the paper's gradual-merge evaluation."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RDFWorkloadConfig:
    name: str
    n_observations: int
    n_sensors: int
    n_timestamps: int
    n_values: int
    zipf_a: float = 1.8
    seed: int = 0


D1 = RDFWorkloadConfig("rdf-d1", 40_000, 200, 500, 400, seed=1)
D1D2 = RDFWorkloadConfig("rdf-d1d2", 120_000, 200, 1200, 400, seed=2)
D1D2D3 = RDFWorkloadConfig("rdf-d1d2d3", 200_000, 200, 2000, 400, seed=3)
SMALL = RDFWorkloadConfig("rdf-small", 2_000, 20, 50, 40, seed=0)

"""moonshot-v1-16b-a3b [moe]: 48L d2048 16H (GQA kv=16) ff1408 v163840,
64 experts top-6 (kimi/moonlight) [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163_840, head_dim=128,
    n_experts=64, experts_per_token=6, moe_d_ff=1408,
    rope_theta=50_000.0, tied_embeddings=True,
    fsdp=True, seq_shard=True,
)

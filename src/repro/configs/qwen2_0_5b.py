"""qwen2-0.5b [dense]: 24L d896 14H (GQA kv=2) ff4864 v151936 -- GQA + QKV
bias [arXiv:2407.10671; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151_936, head_dim=64,
    qkv_bias=True, rope_theta=1_000_000.0,
    # 14 heads / kv=2 do not divide a 16-way model axis, and at 0.5B pure
    # DP-256 beats TP anyway (replicated state = ~6 GB/chip): tp=False.
    tied_embeddings=True, tp=False, seq_shard=True,
)

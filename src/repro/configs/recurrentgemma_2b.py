"""recurrentgemma-2b [hybrid]: 26L d2560 10H (GQA kv=1) ff7680 v256000 --
RG-LRU + local attention, 1 attn : 2 recurrent [arXiv:2402.19427; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256_000, head_dim=256,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048, rglru_width=2560,
    tied_embeddings=True, seq_shard=True,
)

"""Config registry: ``--arch <id>`` resolution for every assigned
architecture (plus reduced smoke-test variants)."""
from __future__ import annotations

from .base import ArchConfig, reduced  # noqa: F401
from .shapes import SHAPES, ShapeSpec, applicable  # noqa: F401

from . import (dbrx_132b, internvl2_2b, llama3_2_1b, llama3_405b,
               mamba2_780m, moonshot_v1_16b_a3b, qwen2_0_5b, qwen3_32b,
               recurrentgemma_2b, whisper_medium)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (qwen2_0_5b, llama3_2_1b, qwen3_32b, llama3_405b, mamba2_780m,
              recurrentgemma_2b, whisper_medium, dbrx_132b,
              moonshot_v1_16b_a3b, internvl2_2b)
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) ff10752 v100352, 16 experts
top-4 fine-grained [hf:databricks/dbrx-base; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10_752, vocab_size=100_352, head_dim=128,
    n_experts=16, experts_per_token=4, moe_d_ff=10_752,
    rope_theta=500_000.0, tied_embeddings=False,
    fsdp=True, seq_shard=True, grad_accum=2,
)

"""qwen3-32b [dense]: 64L d5120 64H (GQA kv=8) ff25600 v151936 -- qk_norm
[hf:Qwen/Qwen3-8B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25_600, vocab_size=151_936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
    tied_embeddings=False, fsdp=True, seq_shard=True,
)

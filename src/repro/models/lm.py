"""LM assembly: decoder-only, encoder-decoder (whisper) and VLM backbones.

One class serves all ten assigned architectures, dispatching per-layer on
``cfg.pattern`` (attn / local / ssd / rglru) and per-arch on family
(frontend stubs, encoder stack, MoE FFNs).

Homogeneous stacks (dense / moe / ssm / whisper enc+dec) are scanned over a
layer-stacked param tree (keeps HLO compact at 126 layers and enables the
per-block remat policy); heterogeneous stacks (recurrentgemma's 2:1
rglru:local pattern) are unrolled.

Three entry points per model -- ``forward`` (train), ``prefill`` (build the
decode cache), ``decode_step`` (one token) -- matching the assigned shape
kinds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import blocks
from .blocks import Ctx
from .common import TSpec, init_from_specs, rms_norm, shard_hint, specs_to_shapes

MAX_LEARNED_POS = 32_768     # whisper-style learned positions (decode_32k)


def _add_layer_dim(tree, n: int):
    return jax.tree.map(
        lambda s: TSpec((n,) + s.shape, s.dtype, ("layers",) + s.axes,
                        s.init),
        tree, is_leaf=lambda x: isinstance(x, TSpec))


def _layer_specs(cfg, kind: str) -> dict:
    if kind in ("attn", "local"):
        d = {"attn": blocks.attn_specs(cfg)}
        if cfg.n_experts:
            d["moe"] = blocks.moe_specs(cfg)
        else:
            d["mlp"] = blocks.mlp_specs(cfg)
        return d
    if kind == "ssd":
        return {"ssd": blocks.ssd_specs(cfg)}
    if kind == "rglru":
        return {"rglru": blocks.rglru_specs(cfg),
                "mlp": blocks.mlp_specs(cfg)}
    raise ValueError(kind)


def _layer_cache_specs(cfg, kind: str, batch: int, cache_len: int) -> dict:
    if kind == "attn":
        return {"attn": blocks.attn_cache_specs(cfg, batch, cache_len,
                                                cfg.dtype)}
    if kind == "local":
        return {"attn": blocks.attn_cache_specs(cfg, batch, cache_len,
                                                cfg.dtype, window=cfg.window)}
    if kind == "ssd":
        return {"ssd": blocks.ssd_cache_specs(cfg, batch)}
    if kind == "rglru":
        return {"rglru": blocks.rglru_cache_specs(cfg, batch)}
    raise ValueError(kind)


class LM:
    """All assigned architectures behind one functional interface."""

    def __init__(self, cfg):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        pd = cfg.param_dtype
        p: dict[str, Any] = {
            "embed": TSpec((cfg.vocab_size, cfg.d_model), pd,
                           ("vocab", "embed")),
            "final_ln": TSpec((cfg.d_model,), "float32", ("embed",),
                              init="zeros"),
        }
        if not cfg.tied_embeddings:
            p["lm_head"] = TSpec((cfg.d_model, cfg.vocab_size), pd,
                                 ("embed", "vocab"))
        if cfg.rope_theta == 0:
            p["pos_embed"] = TSpec((MAX_LEARNED_POS, cfg.d_model), pd,
                                   (None, "embed"))
        if cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            p["frontend_proj"] = TSpec((fd, cfg.d_model), pd,
                                       (None, "embed"))
        if cfg.homogeneous:
            p["layers"] = _add_layer_dim(_layer_specs(cfg, cfg.pattern[0]),
                                         cfg.n_layers)
        else:
            p["layers"] = [_layer_specs(cfg, k) for k in cfg.pattern]
        if cfg.encoder_layers:
            fd = cfg.frontend_dim or cfg.d_model
            enc_layer = {"attn": blocks.attn_specs(cfg),
                         "mlp": blocks.mlp_specs(cfg)}
            p["encoder"] = {
                "in_proj": TSpec((fd, cfg.d_model), pd, (None, "embed")),
                "pos_embed": TSpec((cfg.frontend_tokens, cfg.d_model), pd,
                                   (None, "embed")),
                "layers": _add_layer_dim(enc_layer, cfg.encoder_layers),
                "final_ln": TSpec((cfg.d_model,), "float32", ("embed",),
                                  init="zeros"),
            }
            # decoder layers gain a cross-attention sublayer
            xa = {"xattn": blocks.attn_specs(cfg)}
            if cfg.homogeneous:
                p["layers"] = {**p["layers"],
                               **_add_layer_dim(xa, cfg.n_layers)}
        return p

    def init(self, key):
        return init_from_specs(self.param_specs(), key)

    def input_shapes(self) -> dict:
        return specs_to_shapes(self.param_specs())

    # -- caches --------------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int) -> Any:
        cfg = self.cfg
        if cfg.homogeneous:
            c = _layer_cache_specs(cfg, cfg.pattern[0], batch, cache_len)
            c = _add_layer_dim(c, cfg.n_layers)
        else:
            c = [_layer_cache_specs(cfg, k, batch, cache_len)
                 for k in cfg.pattern]
        out = {"layers": c}
        if cfg.encoder_layers:
            hd = cfg.resolved_head_dim
            enc_kv = {
                "k": TSpec((cfg.n_layers, batch, cfg.n_kv_heads,
                            cfg.frontend_tokens, hd), cfg.dtype,
                           ("layers", "batch", "heads", None, "hd"),
                           init="zeros"),
                "v": TSpec((cfg.n_layers, batch, cfg.n_kv_heads,
                            cfg.frontend_tokens, hd), cfg.dtype,
                           ("layers", "batch", "heads", None, "hd"),
                           init="zeros"),
            }
            out["encoder_kv"] = enc_kv
        return out

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params, tokens, positions, act_spec=None,
               embed_spec=None):
        cfg = self.cfg
        table = shard_hint(params["embed"], embed_spec)
        x = jnp.take(table, tokens, axis=0).astype(cfg.dtype)
        # anchor the gather output immediately: a gather from a
        # (vocab x embed)-sharded table gets an "involuntary full
        # rematerialization" sharding from SPMD unless pinned here
        x = shard_hint(x, act_spec)
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
        if cfg.rope_theta == 0:
            pe = jnp.take(params["pos_embed"], positions, axis=0)
            pe = shard_hint(pe, act_spec)
            x = x + pe.astype(cfg.dtype)
        return x

    def _head(self, params, x, gather_spec=None):
        cfg = self.cfg
        x = rms_norm(x, params["final_ln"])
        x = shard_hint(x, gather_spec)
        if cfg.tied_embeddings:
            w = params["embed"].astype(cfg.dtype)
            return jnp.einsum("btd,vd->btv", x, w)
        return jnp.einsum("btd,dv->btv", x, params["lm_head"].astype(cfg.dtype))

    # -- block application ----------------------------------------------------
    def _block(self, ctx: Ctx, kind: str, p, x, positions, *, cache=None,
               return_cache=False, enc_kv=None, encoder_mode=False):
        """One residual block.  Returns (x, new_cache, aux)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_cache: dict = {}
        if kind in ("attn", "local"):
            window = cfg.window if kind == "local" else None
            h = rms_norm(x, p["attn"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            a_cache = cache.get("attn") if cache else None
            out, nc = blocks.attn_apply(
                ctx, p["attn"], h, positions, causal=not encoder_mode,
                window=window, cache=a_cache)
            out = shard_hint(out, ctx.gather_spec)
            if return_cache and a_cache is None:
                # prefill: rebuild k/v for the cache (cheap vs attention)
                q, k, v = blocks._project_qkv(cfg, p["attn"], h)
                if cfg.rope_theta > 0:
                    from .common import rope as _rope
                    k = _rope(k, positions, cfg.rope_theta)
                nc = blocks.attn_prefill_cache(
                    cfg, k, v, positions, cache_len=ctx.cache_len,
                    window=window, dtype=cfg.dtype)
            if nc is not None:
                new_cache["attn"] = nc
            x = x + out
            if enc_kv is not None:
                h = rms_norm(x, p["xattn"]["ln"])
                h = shard_hint(h, ctx.gather_spec)
                out, _ = blocks.attn_apply(ctx, p["xattn"], h, positions,
                                           kv_override=enc_kv)
                x = x + shard_hint(out, ctx.gather_spec)
            if "moe" in p:
                h = rms_norm(x, p["moe"]["ln"])
                h = shard_hint(h, ctx.gather_spec)
                out, aux = blocks.moe_apply(ctx, p["moe"], h)
                x = x + shard_hint(out, ctx.gather_spec)
            else:
                h = rms_norm(x, p["mlp"]["ln"])
                h = shard_hint(h, ctx.gather_spec)
                x = x + shard_hint(blocks.mlp_apply(ctx, p["mlp"], h),
                                   ctx.gather_spec)
        elif kind == "ssd":
            h = rms_norm(x, p["ssd"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            out, nc = blocks.ssd_apply(ctx, p["ssd"], h,
                                       cache=cache.get("ssd") if cache else None,
                                       return_cache=return_cache)
            if nc is not None:
                new_cache["ssd"] = nc
            x = x + shard_hint(out, ctx.gather_spec)
        elif kind == "rglru":
            h = rms_norm(x, p["rglru"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            out, nc = blocks.rglru_apply(
                ctx, p["rglru"], h,
                cache=cache.get("rglru") if cache else None,
                return_cache=return_cache)
            if nc is not None:
                new_cache["rglru"] = nc
            x = x + shard_hint(out, ctx.gather_spec)
            h = rms_norm(x, p["mlp"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            x = x + shard_hint(blocks.mlp_apply(ctx, p["mlp"], h),
                               ctx.gather_spec)
        else:
            raise ValueError(kind)
        x = shard_hint(x, ctx.act_spec)
        return x, new_cache, aux

    # -- stacks ---------------------------------------------------------------
    def _run_layers(self, ctx: Ctx, params, x, positions, *, caches=None,
                    return_cache=False, enc_out=None):
        cfg = self.cfg
        kind0 = cfg.pattern[0]
        aux_total = jnp.zeros((), jnp.float32)
        if cfg.homogeneous:
            def body(carry, layer):
                xc, aux = carry
                lp, lcache, lenc_kv = layer
                if ctx.layer_param_specs is not None:
                    lp = jax.tree.map(shard_hint, lp,
                                      ctx.layer_param_specs)
                ek = None
                if lenc_kv is not None:
                    ek = (lenc_kv["k"], lenc_kv["v"])
                xc, nc, a = self._block(ctx, kind0, lp, xc, positions,
                                        cache=lcache,
                                        return_cache=return_cache,
                                        enc_kv=ek)
                return (xc, aux + a), nc

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            layer_caches = caches["layers"] if caches else None
            enc_kv = caches.get("encoder_kv") if caches else None
            if enc_kv is None and enc_out is not None:
                enc_kv = self._encoder_kv(params, enc_out)
            xs = (params["layers"], layer_caches, enc_kv)
            # scan needs every xs leaf to have the layer leading dim; for
            # missing caches pass None via a length-L dummy
            if layer_caches is None and enc_kv is None:
                (x, aux_total), ys = jax.lax.scan(
                    lambda c, lp: body(c, (lp, None, None)),
                    (x, aux_total), params["layers"])
            elif layer_caches is None:
                (x, aux_total), ys = jax.lax.scan(
                    lambda c, l: body(c, (l[0], None, l[1])),
                    (x, aux_total), (params["layers"], enc_kv))
            elif enc_kv is None:
                (x, aux_total), ys = jax.lax.scan(
                    lambda c, l: body(c, (l[0], l[1], None)),
                    (x, aux_total), (params["layers"], layer_caches))
            else:
                (x, aux_total), ys = jax.lax.scan(
                    body, (x, aux_total),
                    (params["layers"], layer_caches, enc_kv))
            new_caches = ys if (return_cache or caches is not None) else None
            return x, new_caches, aux_total
        # heterogeneous: unrolled
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            lp = params["layers"][i]
            if ctx.layer_param_specs is not None:
                lp = jax.tree.map(shard_hint, lp,
                                  ctx.layer_param_specs[i])
            lcache = caches["layers"][i] if caches else None

            def blk(lp_, x_, lcache_, _kind=kind):
                return self._block(ctx, _kind, lp_, x_, positions,
                                   cache=lcache_, return_cache=return_cache)

            if cfg.remat:
                blk = jax.checkpoint(
                    blk, policy=jax.checkpoint_policies.nothing_saveable)
            x, nc, a = blk(lp, x, lcache)
            aux_total = aux_total + a
            new_caches.append(nc)
        out_caches = (new_caches
                      if (return_cache or caches is not None) else None)
        return x, out_caches, aux_total

    # -- encoder (whisper) -----------------------------------------------------
    def encode(self, ctx: Ctx, params, frontend_embeds):
        cfg = self.cfg
        enc = params["encoder"]
        x = jnp.einsum("btf,fd->btd", frontend_embeds.astype(cfg.dtype),
                       enc["in_proj"].astype(cfg.dtype))
        x = x + enc["pos_embed"][None, :x.shape[1]].astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                     x.shape[:2])

        def body(carry, lp):
            xc, _ = carry
            if ctx.enc_param_specs is not None:
                lp = jax.tree.map(shard_hint, lp, ctx.enc_param_specs)
            h = rms_norm(xc, lp["attn"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            out, _ = blocks.attn_apply(ctx, lp["attn"], h, positions,
                                       causal=False)
            xc = xc + shard_hint(out, ctx.gather_spec)
            h = rms_norm(xc, lp["mlp"]["ln"])
            h = shard_hint(h, ctx.gather_spec)
            xc = xc + shard_hint(blocks.mlp_apply(ctx, lp["mlp"], h),
                                 ctx.gather_spec)
            xc = shard_hint(xc, ctx.act_spec)
            return (xc, carry[1]), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 enc["layers"])
        return rms_norm(x, enc["final_ln"])

    def _encoder_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V from encoder output (stacked)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def kv_of_layer(lp):
            h = rms_norm(enc_out, lp["xattn"]["ln"])
            _, k, v = blocks._project_qkv(cfg, lp["xattn"], h)
            return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

        return jax.vmap(kv_of_layer)(params["layers"])

    # -- entry points -----------------------------------------------------------
    def forward(self, params, tokens, *, ctx: Ctx, frontend_embeds=None,
                positions=None):
        """Train-mode full-sequence forward.  Returns (logits, aux_loss)."""
        cfg = self.cfg
        b, t = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32),
                                         (b, t))
        x = self._embed(params, tokens, positions, ctx.act_spec,
                        ctx.embed_spec)
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self.encode(ctx, params, frontend_embeds)
        elif cfg.frontend == "vision_stub":
            img = jnp.einsum("bpf,fd->bpd",
                             frontend_embeds.astype(cfg.dtype),
                             params["frontend_proj"].astype(cfg.dtype))
            x = jnp.concatenate([img, x], axis=1)
            t_full = x.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(t_full, dtype=jnp.int32), (b, t_full))
        x = shard_hint(x, ctx.act_spec)
        x, _, aux = self._run_layers(ctx, params, x, positions,
                                     enc_out=enc_out)
        if cfg.frontend == "vision_stub":
            x = x[:, -t:]                       # text positions only
        logits = self._head(params, x, ctx.gather_spec)
        return logits, aux

    def prefill(self, params, tokens, *, ctx: Ctx, cache_len: int,
                frontend_embeds=None):
        """Prefill: forward + build the decode cache."""
        cfg = self.cfg
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        ctx = dataclasses.replace(ctx, cache_len=cache_len)
        x = self._embed(params, tokens, positions, ctx.act_spec,
                        ctx.embed_spec)
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self.encode(ctx, params, frontend_embeds)
        elif cfg.frontend == "vision_stub":
            img = jnp.einsum("bpf,fd->bpd",
                             frontend_embeds.astype(cfg.dtype),
                             params["frontend_proj"].astype(cfg.dtype))
            x = jnp.concatenate([img, x], axis=1)
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32), (b, x.shape[1]))
        x = shard_hint(x, ctx.act_spec)
        x, caches, _ = self._run_layers(ctx, params, x, positions,
                                        return_cache=True, enc_out=enc_out)
        logits = self._head(params, x[:, -1:], None)
        out = {"layers": caches}
        if enc_out is not None:
            out["encoder_kv"] = self._encoder_kv(params, enc_out)
        return logits, out

    def decode_step(self, params, tokens, cache, positions, *, ctx: Ctx):
        """One decode step.  tokens: (B, 1); positions: (B, 1)."""
        x = self._embed(params, tokens, positions, ctx.act_spec,
                        ctx.embed_spec)
        x = shard_hint(x, ctx.act_spec)
        x, new_caches, _ = self._run_layers(ctx, params, x, positions,
                                            caches=cache)
        logits = self._head(params, x, None)
        new_cache = dict(cache)
        new_cache["layers"] = new_caches
        return logits, new_cache

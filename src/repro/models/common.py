"""Shared model components: param specs, norms, rotary embeddings."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TSpec:
    """Tensor spec: shape + dtype + logical sharding axes (one per dim).

    Logical axes vocabulary: "vocab", "embed", "ff", "heads", "experts",
    "layers", "rnn", "state", "seq", None.  ``dist/sharding.py`` maps these
    to mesh axes per config (TP on ff/heads/vocab/experts, FSDP on embed).
    """
    shape: tuple[int, ...]
    dtype: str = "bfloat16"
    axes: tuple[str | None, ...] = ()
    init: str = "normal"            # normal | zeros | ones | scaled

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} rank != shape {self.shape}")

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def specs_to_shapes(tree):
    """TSpec tree -> ShapeDtypeStruct tree (dry-run inputs, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.jdtype), tree,
        is_leaf=lambda x: isinstance(x, TSpec))


def init_from_specs(tree, key, base_scale: float = 0.02):
    """Materialize a TSpec tree with sensible LM init."""
    leaves, treedef = jax.tree.flatten(
        tree, is_leaf=lambda x: isinstance(x, TSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.jdtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.jdtype)
        else:
            scale = base_scale
            if spec.init == "scaled":
                scale = base_scale * 0.5
            arr = (jax.random.normal(k, spec.shape, jnp.float32)
                   * scale).astype(spec.jdtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (B, T, H, hd); positions: (B, T) int32."""
    b, t, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def causal_cross_entropy_ref(logits, labels, mask=None):
    """Reference CE (materializes f32 logits; used as the test oracle)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _ce_core(logits, labels, mask):
    """(loss, lse, denom) -- all reductions stream over bf16 logits.

    f32 conversion feeds each reduction as a fused elementwise producer, so
    no f32 copy of the (B, T, V) logits is materialized; the gold logit is
    gathered with an iota-compare+sum (take_along_axis over a TP-sharded
    vocab axis would force an all-gather -- the masked sum reduces locally
    then all-reduces a (B, T) scalar field instead).
    """
    m = jnp.max(logits, axis=-1).astype(jnp.float32)      # max exact in bf16
    z = jnp.sum(jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1)
    lse = m + jnp.log(z)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None],
                             logits.astype(jnp.float32), 0.0), axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((lse - gold) * mask) / denom
    return loss, lse, denom


@jax.custom_vjp
def _fused_ce(logits, labels, mask):
    return _ce_core(logits, labels, mask)[0]


def _fused_ce_fwd(logits, labels, mask):
    loss, lse, denom = _ce_core(logits, labels, mask)
    return loss, (logits, labels, mask, lse, denom)


def _fused_ce_bwd(res, g):
    """dlogits = (softmax - onehot) * scale, with the onehot applied as a
    scatter of -scale at the label positions: avoids materializing a
    (B, T, V) iota + onehot pair (3.9 GB each for a 256k vocab -- §Perf)."""
    logits, labels, mask, lse, denom = res
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    scale = mask * (g / denom)
    dl = (p * scale[..., None]).astype(logits.dtype)   # bf16 dlogits
    b, t = labels.shape
    bi = jnp.arange(b)[:, None]
    ti = jnp.arange(t)[None, :]
    dl = dl.at[bi, ti, labels].add(-scale.astype(dl.dtype))
    return dl, None, None


_fused_ce.defvjp(_fused_ce_fwd, _fused_ce_bwd)


def causal_cross_entropy(logits, labels, mask=None):
    """Mean next-token CE; fused fwd/bwd keeps dlogits in logits dtype and
    avoids any (B, T, V) f32 materialization (see _ce_core)."""
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    return _fused_ce(logits, labels, mask.astype(jnp.float32))


def shard_hint(x, spec_or_none):
    """with_sharding_constraint; None spec -> no-op.

    NOTE: a bare PartitionSpec binds to the *ambient* mesh -- callers that
    lower with sharding hints must run under ``with mesh:`` (launch/dryrun
    does).  A failed bind raises rather than silently dropping the hint; a
    dropped hint at 405B scale replicates the scan carry (63 GB/device --
    see EXPERIMENTS §Perf iteration log)."""
    if spec_or_none is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_or_none)

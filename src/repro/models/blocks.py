"""Layer blocks for every assigned family.

Each block provides ``*_specs(cfg)`` (TSpec tree -- shapes/dtypes/logical
axes) and ``*_apply(cfg, params, x, ...)``.  Mixers: global/local GQA
attention (rope or learned positions, qk-norm, qkv-bias), mamba2 SSD
(chunked state-space duality), RG-LRU (recurrentgemma).  FFNs: gated dense,
dropless MoE (top-k, grouped GEMM via ``jax.lax.ragged_dot``).

Every mixer supports three modes:
  * train/prefill: full sequence, optionally emitting a decode cache;
  * decode: one token against the cache (the assigned decode_* shapes);
sub-quadratic mixers (ssd / rglru / local) carry O(1)-in-T state, which is
what makes the long_500k cells runnable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.kernels import ops as kops

from .common import TSpec, rms_norm, rope, shard_hint

Params = dict


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call context: config + sharding hints + kernel selection."""
    cfg: Any
    attn_impl: str = "xla"          # xla | pallas | pallas_interpret
    scan_impl: str = "xla"
    act_spec: Any = None            # sharding hint for the residual stream
    gather_spec: Any = None         # SP boundary: (B, T, D) with seq
    # gathered -- applied to the small normed activations entering each TP
    # sublayer so GSPMD un-shards 16 MB of activations instead of
    # replicating 15 GB of weights (Megatron sequence-parallel pattern)
    q_spec: Any = None              # (B, Hq, T, hd) hint inside attention
    kv_spec: Any = None             # (B, Hkv, S, hd) hint inside attention
    group_spec: Any = None          # (B, Hkv, G, T, hd) chunked-attn layout
    layer_param_specs: Any = None   # per-layer params in COMPUTE layout
    enc_param_specs: Any = None     # encoder layer params, compute layout
    embed_spec: Any = None          # pre-gather embedding-table re-shard
    # (vocab replicated, d sharded) -- see dist/sharding.embed_gather_spec
    moe_impl: str = "ragged"        # ragged (1-device dropless gmm) |
    # shard_map (manual EP: local expert FFNs + one psum -- the production
    # path; GSPMD lowers ragged_dot/argsort dispatch to full replication)
    mesh: Any = None                # required by moe_impl="shard_map"
    moe_capacity_factor: float = 1.25
    decode_kv_specs: Any = None     # (q_spec, kv_spec, bias_spec) -> use the
    # shard_map flash-decode over a sequence-sharded KV cache (needs mesh)
    moe_aux_coef: float = 0.01
    cache_len: int = 0              # decode-cache length during prefill


# ---------------------------------------------------------------------------
# attention (global + local window), GQA, rope / learned positions
# ---------------------------------------------------------------------------

def attn_specs(cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    pd = cfg.param_dtype
    p = {
        "wq": TSpec((d, h * hd), pd, ("embed", "heads")),
        "wk": TSpec((d, kv * hd), pd, ("embed", "heads")),
        "wv": TSpec((d, kv * hd), pd, ("embed", "heads")),
        "wo": TSpec((h * hd, d), pd, ("heads", "embed"), init="scaled"),
        "ln": TSpec((d,), "float32", ("embed",), init="zeros"),
    }
    if cfg.qkv_bias:
        p["bq"] = TSpec((h * hd,), "float32", ("heads",), init="zeros")
        p["bk"] = TSpec((kv * hd,), "float32", ("heads",), init="zeros")
        p["bv"] = TSpec((kv * hd,), "float32", ("heads",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = TSpec((hd,), "float32", (None,), init="zeros")
        p["k_norm"] = TSpec((hd,), "float32", (None,), init="zeros")
    return p


def attn_cache_specs(cfg, batch: int, cache_len: int, dtype: str,
                     window: int | None = None) -> Params:
    """KV decode cache.  Sharding: batch over DP, kv-heads over TP; when
    kv-heads do not divide the model axis (GQA kv=2/8 on a 16-way axis) the
    SEQUENCE dim takes it (flash-decode style: local max/sum + tiny stat
    all-reduces) -- without either, the 405B decode_32k cache (2.2 TB)
    would replicate, and sharding head_dim instead would all-reduce the
    full attention-logit tensor every step (contraction over a sharded
    dim).  "hd" is the last resort for non-divisible sequence lengths."""
    hd = cfg.resolved_head_dim
    s = min(cache_len, window) if window else cache_len
    kv = cfg.n_kv_heads
    return {
        "k": TSpec((batch, kv, s, hd), dtype,
                   ("batch", "heads", "seq", "hd"), init="zeros"),
        "v": TSpec((batch, kv, s, hd), dtype,
                   ("batch", "heads", "seq", "hd"), init="zeros"),
        "pos": TSpec((batch, s), "int32", ("batch", "seq"), init="zeros"),
    }


def _project_qkv(cfg, p, x):
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dk->btk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dk->btk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dk->btk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def attn_apply(ctx: Ctx, p: Params, x, positions, *, causal: bool = True,
               window: int | None = None, cache: Params | None = None,
               kv_override=None):
    """Returns (out, new_cache).  x: (B, T, D); positions: (B, T).

    ``cache`` (decode): ring buffer of size S (or window); one-step update.
    ``kv_override``: (k, v) already in (B, Hkv, S, D) -- cross-attention.
    """
    cfg = ctx.cfg
    b, t, d = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(cfg, p, x)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = rope(k, positions, cfg.rope_theta)
    qh = q.transpose(0, 2, 1, 3)                       # (B, H, T, hd)
    new_cache = None

    if kv_override is not None:                        # cross-attention
        kh, vh = kv_override
        out = kops.attention(qh, kh, vh, causal=False, impl=ctx.attn_impl,
                             group_spec=ctx.group_spec)
    elif cache is not None:                            # decode: T == 1
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        s = cache["k"].shape[2]
        slot = (positions[:, -1] % s) if window else \
            jnp.minimum(positions[:, -1], s - 1)
        bidx = jnp.arange(b)
        ck = cache["k"].at[bidx, :, slot].set(kh[:, :, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, :, slot].set(vh[:, :, 0].astype(cache["v"].dtype))
        cpos = cache["pos"].at[bidx, slot].set(positions[:, -1])
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        # flash-decode over the (ring) buffer: online softmax per key
        # chunk -- the naive path materializes (B, Hkv, G, S) f32 logits
        # (4.3 GB/layer/token at qwen3 decode_32k; see EXPERIMENTS §Perf)
        from repro.kernels.chunked_attention import decode_attention
        qpos = positions[:, -1][:, None]                       # (B, 1)
        valid = cpos <= qpos                                    # causal
        if window:
            valid &= cpos > qpos - window
        group = cfg.n_heads // cfg.n_kv_heads
        qg = qh[:, :, 0].reshape(b, cfg.n_kv_heads, group, hd)
        bias = jnp.where(valid, 0.0, -1e30)                     # (B, S)
        if ctx.decode_kv_specs is not None and ctx.mesh is not None:
            from repro.kernels.chunked_attention import \
                decode_attention_sharded
            qs, ks, bs = ctx.decode_kv_specs
            o = decode_attention_sharded(qg, ck, cv, bias, mesh=ctx.mesh,
                                         q_spec=qs, kv_spec=ks,
                                         bias_spec=bs)
        else:
            o = decode_attention(qg, ck, cv, bias)
        out = o.reshape(b, cfg.n_heads, 1, hd).astype(x.dtype)
    else:                                              # train / prefill
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        qh = shard_hint(qh, ctx.q_spec)
        kh = shard_hint(kh, ctx.kv_spec)
        vh = shard_hint(vh, ctx.kv_spec)
        out = kops.attention(qh, kh, vh, causal=causal, window=window,
                             impl=ctx.attn_impl, group_spec=ctx.group_spec)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * hd)
    return jnp.einsum("btk,kd->btd", out, p["wo"].astype(x.dtype)), new_cache


def attn_prefill_cache(cfg, k, v, positions, cache_len: int,
                       window: int | None, dtype):
    """Build the decode cache from prefill K/V.  k, v: (B, T, Hkv, hd)."""
    b, t, kvh, hd = k.shape
    s = min(cache_len, window) if window else cache_len
    kh = k.transpose(0, 2, 1, 3).astype(dtype)
    vh = v.transpose(0, 2, 1, 3).astype(dtype)
    if t >= s:
        return {"k": kh[:, :, t - s:], "v": vh[:, :, t - s:],
                "pos": positions[:, t - s:]}
    pad = s - t
    return {
        "k": jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0))),
        "pos": jnp.pad(positions, ((0, 0), (0, pad)),
                       constant_values=jnp.iinfo(jnp.int32).max // 2),
    }


# ---------------------------------------------------------------------------
# dense gated FFN
# ---------------------------------------------------------------------------

def mlp_specs(cfg) -> Params:
    d, f, pd = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "w_gate": TSpec((d, f), pd, ("embed", "ff")),
        "w_up": TSpec((d, f), pd, ("embed", "ff")),
        "w_down": TSpec((f, d), pd, ("ff", "embed"), init="scaled"),
        "ln": TSpec((d,), "float32", ("embed",), init="zeros"),
    }


def mlp_apply(ctx: Ctx, p: Params, x):
    h = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# dropless MoE (top-k router + grouped GEMM)
# ---------------------------------------------------------------------------

def moe_specs(cfg) -> Params:
    d, pd = cfg.d_model, cfg.param_dtype
    e, f = cfg.n_experts, (cfg.moe_d_ff or cfg.d_ff)
    return {
        "router": TSpec((d, e), "float32", ("embed", None)),
        "w_gate": TSpec((e, d, f), pd, ("experts", "embed", "ff")),
        "w_up": TSpec((e, d, f), pd, ("experts", "embed", "ff")),
        "w_down": TSpec((e, f, d), pd, ("experts", "ff", "embed"),
                        init="scaled"),
        "ln": TSpec((d,), "float32", ("embed",), init="zeros"),
    }


def _router(cfg, p, xf):
    """Shared router: (top_weights (n,k), top_experts (n,k), aux scalar)."""
    e, k = cfg.n_experts, cfg.experts_per_token
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)               # (n, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(tope, e, dtype=jnp.float32),
                       axis=(0, 1))
    router_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * router_mean)
    return topw, tope, aux


def moe_apply(ctx: Ctx, p: Params, x):
    """Top-k MoE.  Returns (out, aux_loss).

    Two implementations:
      * ``ragged``   -- dropless grouped GEMM (``lax.ragged_dot``); the
        right kernel on one device / real-TPU megablox, but GSPMD has no
        sharding rule for it (dbrx train lowered to 787 GB/device);
      * ``shard_map``-- manual expert parallelism (production path): the
        residual stream is replicated across the model axis at the SP
        boundary, each device runs its e/TP local experts over all local
        tokens with a static per-expert capacity, and ONE psum over the
        model axis merges expert outputs (same wire cost as a dense TP
        layer; no all-to-all needed).  Identical numerics when nothing
        overflows capacity (tests/test_moe.py).
    """
    if ctx.moe_impl == "shard_map":
        return _moe_shard_map(ctx, p, x)
    cfg = ctx.cfg
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    n = b * t
    xf = x.reshape(n, d)
    topw, tope, aux = _router(cfg, p, xf)

    flat_e = tope.reshape(-1)                          # (n*k,)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e)
    xs = xf[flat_tok[order]]                           # (n*k, d) sorted
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"].astype(xs.dtype),
                                       group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["w_up"].astype(xs.dtype), group_sizes)
    ys = jax.lax.ragged_dot(h, p["w_down"].astype(xs.dtype), group_sizes)

    inv = jnp.argsort(order)
    ys = ys[inv] * topw.reshape(-1)[:, None].astype(ys.dtype)
    out = jnp.zeros((n, d), ys.dtype).at[flat_tok].add(ys)
    return out.reshape(b, t, d), aux


def _moe_local_experts(cfg, p_local, xf, topw, tope, e_lo, e_local,
                       capacity):
    """One device's experts over all its tokens (static shapes).

    xf: (n, d); p_local: expert weights for experts [e_lo, e_lo+e_local).
    Returns the (n, d) partial output from the local experts only."""
    n, d = xf.shape
    k = tope.shape[1]
    flat_e = tope.reshape(-1)
    rel = flat_e - e_lo
    mine = (rel >= 0) & (rel < e_local)
    key = jnp.where(mine, rel, e_local)       # foreign slots sort last
    order = jnp.argsort(key)
    sorted_rel = key[order]                    # (n*k,)
    starts = jnp.searchsorted(sorted_rel, jnp.arange(e_local))
    pos = jnp.arange(n * k) - starts[jnp.minimum(sorted_rel,
                                                 e_local - 1)]
    keep = (sorted_rel < e_local) & (pos < capacity)
    tok_sorted = order // k
    src = jnp.where(keep[:, None], xf[tok_sorted], 0).astype(xf.dtype)
    slot_e = jnp.where(keep, sorted_rel, 0)
    slot_c = jnp.where(keep, pos, 0)
    xe = jnp.zeros((e_local, capacity, d), xf.dtype) \
        .at[slot_e, slot_c].add(src)           # dropped rows add 0
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                               p_local["w_gate"].astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe,
                       p_local["w_up"].astype(xf.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p_local["w_down"].astype(xf.dtype))
    y_sorted = ye[slot_e, slot_c] * keep[:, None]
    w_sorted = topw.reshape(-1)[order][:, None].astype(xf.dtype)
    out = jnp.zeros((n, d), xf.dtype) \
        .at[tok_sorted].add(y_sorted * w_sorted)
    return out


def _moe_shard_map(ctx: Ctx, p: Params, x):
    from jax.sharding import PartitionSpec as P

    cfg = ctx.cfg
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    mesh = ctx.mesh
    tp = mesh.devices.shape[list(mesh.axis_names).index("model")]
    assert e % tp == 0, f"experts {e} must divide model axis {tp}"
    e_local = e // tp
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    # per-device token count: batch is sharded over the dp axes
    n_dev = 1
    for a, s_ in zip(mesh.axis_names, mesh.devices.shape):
        if a != "model":
            n_dev *= s_
    batch_sharded = b % n_dev == 0
    b_local = b // n_dev if batch_sharded else b
    n_local = b_local * t
    capacity = max(1, math.ceil(n_local * k
                                * ctx.moe_capacity_factor / e))

    def body(xb, router, wg, wu, wd):
        nl = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(nl, d)
        topw, tope, aux = _router(cfg, {"router": router}, xf)
        e_lo = jax.lax.axis_index("model") * e_local
        local = {"w_gate": wg, "w_up": wu, "w_down": wd}
        out = _moe_local_experts(cfg, local, xf, topw, tope, e_lo,
                                 e_local, capacity)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, dp_axes)  # invariant over model
        return out.reshape(xb.shape), aux

    x_spec = P(dp_axes if batch_sharded else None, None, None)
    w_spec = P("model", None, None)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()))(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux


# ---------------------------------------------------------------------------
# mamba2 SSD (chunked state-space duality)
# ---------------------------------------------------------------------------

def ssd_specs(cfg) -> Params:
    d, pd = cfg.d_model, cfg.param_dtype
    di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ns
    return {
        "w_in": TSpec((d, 2 * di + 2 * ns + h), pd, ("embed", "ff")),
        "conv": TSpec((cfg.conv_width, conv_dim), "float32", (None, "ff")),
        "dt_bias": TSpec((h,), "float32", (None,), init="zeros"),
        "a_log": TSpec((h,), "float32", (None,), init="zeros"),
        "d_skip": TSpec((h,), "float32", (None,), init="zeros"),
        "norm": TSpec((di,), "float32", ("ff",), init="zeros"),
        "w_out": TSpec((di, d), pd, ("ff", "embed"), init="scaled"),
        "ln": TSpec((d,), "float32", ("embed",), init="zeros"),
    }


def ssd_cache_specs(cfg, batch: int) -> Params:
    di, ns = cfg.d_inner, cfg.ssm_state
    h, hp = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": TSpec((batch, h, ns, hp), "float32",
                       ("batch", "ff", None, None), init="zeros"),
        "conv": TSpec((batch, cfg.conv_width - 1, di + 2 * ns), "float32",
                      ("batch", None, "ff"), init="zeros"),
    }


def _ssd_split(cfg, zxbcdt):
    di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    bb = zxbcdt[..., 2 * di:2 * di + ns]
    cc = zxbcdt[..., 2 * di + ns:2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns:]
    return z, xin, bb, cc, dt


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv.  x: (B, T, C); kernel: (W, C).

    ``state``: (B, W-1, C) tail of the previous segment (decode)."""
    w = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
              for i in range(w))
    new_state = xp[:, -(w - 1):] if w > 1 else None
    return out, new_state


def ssd_apply(ctx: Ctx, p: Params, x, *, cache: Params | None = None,
              return_cache: bool = False):
    """mamba2 SSD mixer.  Returns (out, new_cache)."""
    cfg = ctx.cfg
    b, t, d = x.shape
    di, ns = cfg.d_inner, cfg.ssm_state
    h, hp = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,dk->btk", x, p["w_in"].astype(x.dtype))
    z, xin, bb, cc, dt = _ssd_split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :di].reshape(b, t, h, hp)
    bb = conv_out[..., di:di + ns]
    cc = conv_out[..., di + ns:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    a_neg = -jnp.exp(p["a_log"])                                  # (H,)
    da = dt * a_neg                                               # log decay
    xdt = xin.astype(jnp.float32) * dt[..., None]

    if cache is not None and t == 1:                  # single-step decode
        a = jnp.exp(da[:, 0])                                     # (B,H)
        s_prev = cache["state"]
        upd = jnp.einsum("bn,bhp->bhnp", bb[:, 0].astype(jnp.float32),
                         xdt[:, 0])
        s_new = a[..., None, None] * s_prev + upd
        y = jnp.einsum("bn,bhnp->bhp", cc[:, 0].astype(jnp.float32), s_new)
        y = y[:, None]                                            # (B,1,H,P)
        new_cache = {"state": s_new, "conv": new_conv}
    else:
        y, last_state = _ssd_chunked(cfg, xdt, da, bb.astype(jnp.float32),
                                     cc.astype(jnp.float32), ctx)
        new_cache = ({"state": last_state, "conv": new_conv}
                     if return_cache else None)

    y = y + xdt * p["d_skip"][..., None]              # per-head skip
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    return jnp.einsum("btk,kd->btd", y, p["w_out"].astype(x.dtype)), new_cache


def _ssd_chunked(cfg, x, da, bb, cc, ctx: Ctx):
    """Chunked SSD: intra-chunk quadratic + inter-chunk linear scan.

    x: (B,T,H,P) f32 (already dt-scaled); da: (B,T,H) log-decay;
    bb, cc: (B,T,N).  Returns (y (B,T,H,P), last_state (B,H,N,P))."""
    b, t, h, hp = x.shape
    ns = bb.shape[-1]
    lc = min(cfg.ssm_chunk, t)
    t_orig = t
    if t % lc:
        # pad with NO-OP steps: x=0 (no state update), da=0 (decay = 1)
        pad = lc - t % lc
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        t = t + pad
    nc = t // lc
    xr = x.reshape(b, nc, lc, h, hp)
    dar = da.reshape(b, nc, lc, h)
    br = bb.reshape(b, nc, lc, ns)
    cr = cc.reshape(b, nc, lc, ns)

    cs = jnp.cumsum(dar, axis=2)                       # (B,nc,Lc,H)
    # intra-chunk: y[l] += sum_{s<=l} exp(cs[l]-cs[s]) (C_l.B_s) x_s
    gb = jnp.einsum("bcln,bcsn->bcls", cr, br)          # (B,nc,Lc,Lc)
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Lc,Lc,H)
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    y = jnp.einsum("bcls,bclsh,bcshp->bclhp", gb, decay, xr)

    # chunk states: S_c = sum_s exp(cs_last - cs_s) B_s (x) x_s
    seg = jnp.exp(cs[:, :, -1:, :] - cs)                # (B,nc,Lc,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", br, seg, xr)

    # inter-chunk linear recurrence over nc (kernels.linear_scan)
    chunk_decay = jnp.exp(cs[:, :, -1, :])              # (B,nc,H)
    a_flat = jnp.repeat(chunk_decay.transpose(0, 2, 1).reshape(b * h, nc),
                        ns * hp, axis=0).reshape(b * h, ns * hp, nc)
    a_flat = a_flat.transpose(0, 2, 1)                  # (B*H, nc, N*P)
    s_flat = states.transpose(0, 2, 1, 3, 4).reshape(b * h, nc, ns * hp)
    all_states, last = kops.linear_scan(s_flat, a_flat, impl=ctx.scan_impl)
    # states *entering* each chunk: shift right by one
    prev = jnp.concatenate(
        [jnp.zeros_like(all_states[:, :1]), all_states[:, :-1]], axis=1)
    prev = prev.reshape(b, h, nc, ns, hp).transpose(0, 2, 1, 3, 4)

    # inter-chunk contribution: C_l . exp(cs_l) S_prev
    y_off = jnp.einsum("bcln,bclh,bchnp->bclhp", cr, jnp.exp(cs), prev)
    y = (y + y_off).reshape(b, t, h, hp)[:, :t_orig]
    return y, last.reshape(b, h, ns, hp)


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma) recurrent block
# ---------------------------------------------------------------------------

def rglru_specs(cfg) -> Params:
    d, pd = cfg.d_model, cfg.param_dtype
    w = cfg.rglru_width or d
    return {
        "w_x": TSpec((d, w), pd, ("embed", "rnn")),
        "w_y": TSpec((d, w), pd, ("embed", "rnn")),
        "conv": TSpec((cfg.conv_width, w), "float32", (None, "rnn")),
        # gate projections: column-parallel (output dim sharded) -- sharding
        # the CONTRACTION dim instead all-reduces the full-width (B, T, W)
        # gate tensors every layer (115 GB/device at rg-2b train_4k)
        "w_a": TSpec((w, w), pd, (None, "rnn")),
        "w_i": TSpec((w, w), pd, (None, "rnn")),
        "lam": TSpec((w,), "float32", ("rnn",), init="ones"),
        "w_out": TSpec((w, d), pd, ("rnn", "embed"), init="scaled"),
        "ln": TSpec((d,), "float32", ("embed",), init="zeros"),
    }


def rglru_cache_specs(cfg, batch: int) -> Params:
    w = cfg.rglru_width or cfg.d_model
    return {
        "state": TSpec((batch, w), "float32", ("batch", "rnn"), init="zeros"),
        "conv": TSpec((batch, cfg.conv_width - 1, w), "float32",
                      ("batch", None, "rnn"), init="zeros"),
    }


def rglru_apply(ctx: Ctx, p: Params, x, *, cache: Params | None = None,
                return_cache: bool = False):
    """Griffin recurrent block: conv -> RG-LRU, gated by a GeLU branch."""
    cfg = ctx.cfg
    u = jnp.einsum("btd,dw->btw", x, p["w_x"].astype(x.dtype))
    g = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_y"].astype(x.dtype)))
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_state)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btw,wk->btk", uf,
                                  p["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("btw,wk->btk", uf,
                                  p["w_i"].astype(jnp.float32)))
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"]) * r          # (B,T,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    h0 = cache["state"] if cache is not None else None
    hs, last = kops.linear_scan(gated, a, h0, impl=ctx.scan_impl)
    y = (hs.astype(x.dtype) * g)
    out = jnp.einsum("btw,wd->btd", y, p["w_out"].astype(x.dtype))
    new_cache = ({"state": last, "conv": new_conv}
                 if (cache is not None or return_cache) else None)
    return out, new_cache

"""Model zoo: every assigned architecture family as composable JAX modules.

Pure-function style (no flax): parameters are pytrees of arrays described by
``TSpec`` trees (single source of truth for shapes, dtypes and logical
sharding axes), so the same definition serves real initialization (smoke
tests, examples) and ShapeDtypeStruct-only dry-run lowering.
"""
from .common import TSpec, specs_to_shapes, init_from_specs  # noqa: F401
from .lm import LM  # noqa: F401

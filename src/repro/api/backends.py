"""Execution backends: *where* subset evaluations run.

The paper's algorithms are backend-agnostic -- every step of E.FSP / G.FSP
reduces to "evaluate ``#Edges(SP', C, G)`` for candidate subsets SP'".
A backend owns the execution substrate behind two methods:

* ``evaluate(store, class_id, props, n_s, am)`` -- one candidate subset
  (Def. 4.8 objective), exact host arithmetic.
* ``workspace(store, class_id, props, n_s, am)`` -- a per-(class, descent)
  :class:`repro.core.sweep.SweepWorkspace`: the object matrix is
  extracted through the ``GraphIndex`` joins ONCE, device backends upload
  it ONCE, and every candidate batch -- a greedy drop-one sweep or a
  whole E.FSP lattice level fed to ``sweep_candidates`` -- is served
  from that parent buffer (host backends slice it; device backends mask
  columns on device inside a shape-bucketed jitted sweep that compiles
  once per power-of-two ``(n_b, k_b, c_b)`` bucket and dispatches ONE
  lowering per batch, sharded backends one ``shard_map`` collective
  schedule per batch).

The greedy loop itself (``GreedyDetector``) charges the SAME evaluation
count for the same sweep on every backend -- ``len(SP)`` when the sweep
runs, 0 when the children would be sub-star (``|SP'| < 2``) -- so
``FSPResult.evaluations`` is backend-invariant.

Three implementations are registered by name:

==========  =================================================================
``host``    the paper's sequential numpy loop (reference semantics)
``device``  one batched jax lowering per sweep (vmapped signature group-by,
            Pallas kernels when available), bucket-cached across classes
``sharded`` the bucketed sweep with rows sharded over the mesh's
            data-parallel axes, layout routed through
            ``repro.dist.sharding.make_plan``
==========  =================================================================
"""
from __future__ import annotations

import types
from typing import Protocol, Sequence, runtime_checkable

from repro.core.star import StarSweepResult, evaluate_subset
from repro.core.sweep import (DeviceSweepWorkspace, HostSweepWorkspace,
                              ShardedSweepWorkspace, SweepWorkspace)
from repro.core.triples import TripleStore

from repro.registry import Registry


@runtime_checkable
class ExecutionBackend(Protocol):
    """Strategy protocol: where candidate-subset evaluations execute."""

    name: str

    def evaluate(self, store: TripleStore, class_id: int,
                 props: Sequence[int], n_s: int, am: int) -> StarSweepResult:
        ...

    def workspace(self, store: TripleStore, class_id: int,
                  props: Sequence[int], n_s: int, am: int) -> SweepWorkspace:
        ...


class HostBackend:
    """The paper-faithful sequential numpy path."""

    name = "host"

    def evaluate(self, store, class_id, props, n_s, am):
        return evaluate_subset(store, class_id, props, n_s, am)

    def workspace(self, store, class_id, props, n_s, am):
        return HostSweepWorkspace(store, class_id, props, n_s, am)


class DeviceBackend:
    """Batched jax sweep: all |SP| candidates in one bucketed lowering."""

    name = "device"

    def __init__(self, use_kernel: bool = True) -> None:
        self.use_kernel = use_kernel

    def evaluate(self, store, class_id, props, n_s, am):
        # single-subset evaluation is cheaper (and exact) on host
        return evaluate_subset(store, class_id, props, n_s, am)

    def workspace(self, store, class_id, props, n_s, am):
        return DeviceSweepWorkspace(store, class_id, props, n_s, am,
                                    use_kernel=self.use_kernel)


class ShardedBackend:
    """Bucketed sweep with the object matrix row-sharded over the mesh.

    Layout policy is routed through the ``repro.dist`` planner: the mesh's
    data-parallel axes come from ``sharding.make_plan`` (DP ladder,
    tensor-parallel axis excluded), rows are bucket-padded to the DP
    degree and placed with ``PartitionSpec(dp_axes, None)``, and padding
    rows are masked out of the distinct-signature count.  With
    ``mesh=None`` this degrades to the single-device bucketed sweep
    (useful for tests, and it shares the device jit cache).

    On a real mesh the WHOLE candidate stack of a sweep runs through
    ``core.distributed.ami_bucketed_batch`` -- the explicit shard_map
    (hash-bucket all_to_all + psum) path with a leading candidate axis,
    one lowering per descent.  The implicit GSPMD lowering of
    the sort-based sweep silently miscounts distinct rows on multi-axis
    meshes under jax 0.4.x (per-shard segment counts get summed across
    replicas -- a latent seed bug: ``gfsp_distributed`` built the same
    lowering but was only ever executed with ``mesh=None``), so the
    collective schedule must be explicit.
    """

    name = "sharded"

    def __init__(self, mesh=None, cfg=None, use_kernel: bool = True) -> None:
        self.mesh = mesh
        self.use_kernel = use_kernel
        self.plan = None
        if mesh is not None:
            from repro.dist import sharding as dsh
            # tp=True reserves the "model" axis: FSP rows shard over the
            # data-parallel axes only (matching the seed gfsp_distributed)
            cfg = cfg if cfg is not None else types.SimpleNamespace(
                tp=True, fsdp=False, seq_shard=False)
            self.plan = dsh.make_plan(cfg, mesh)

    def evaluate(self, store, class_id, props, n_s, am):
        return evaluate_subset(store, class_id, props, n_s, am)

    def workspace(self, store, class_id, props, n_s, am):
        return ShardedSweepWorkspace(store, class_id, props, n_s, am,
                                     mesh=self.mesh, plan=self.plan,
                                     use_kernel=self.use_kernel)


BACKENDS = Registry("execution backend")
BACKENDS.register("host", HostBackend)
BACKENDS.register("device", DeviceBackend)
BACKENDS.register("sharded", ShardedBackend)


def register_backend(name: str, cls) -> None:
    BACKENDS.register(name, cls)


def get_backend(spec, **opts) -> ExecutionBackend:
    """Resolve a backend: a registered name (instantiated with ``opts``)
    or an already-constructed backend instance (returned as-is)."""
    if isinstance(spec, str):
        return BACKENDS.get(spec)(**opts)
    if isinstance(spec, ExecutionBackend):
        return spec
    raise TypeError(f"not an execution backend: {spec!r}")

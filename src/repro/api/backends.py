"""Execution backends: *where* subset evaluations run.

The paper's algorithms are backend-agnostic -- every step of E.FSP / G.FSP
reduces to "evaluate ``#Edges(SP', C, G)`` for candidate subsets SP'".
Before this module the choice of execution substrate leaked through the
call graph as scattered booleans (``device_sweep=`` in ``core.gfsp``,
``use_kernel=`` in ``core.star`` / ``core.distributed``).  A backend now
owns that decision behind two methods:

* ``evaluate(store, class_id, props, n_s, am)`` -- one candidate subset
  (Def. 4.8 objective), exact host arithmetic.
* ``sweep(store, class_id, current, n_s, am)`` -- all one-property-removed
  children of ``current`` in one shot, returning the best child (AMI == 1
  preferred, else minimum ``#Edges``, first index breaking ties) and the
  number of subset evaluations charged.  Every backend charges the SAME
  count for the same sweep -- ``len(current.props)`` when the sweep runs,
  0 when the children would be sub-star (``|SP'| < 2``) -- so
  ``FSPResult.evaluations`` is backend-invariant (the seed implementation
  disagreed between host and device paths; see ``core/gfsp.py``).

Three implementations are registered by name:

==========  =================================================================
``host``    the paper's sequential numpy loop (reference semantics)
``device``  one batched jax lowering per sweep (vmapped signature group-by,
            Pallas kernels when available)
``sharded`` the device sweep with rows sharded over the mesh's data-parallel
            axes, layout routed through ``repro.dist.sharding.make_plan``
==========  =================================================================
"""
from __future__ import annotations

import types
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.star import StarSweepResult, evaluate_subset
from repro.core.triples import TripleStore

from repro.registry import Registry


@runtime_checkable
class ExecutionBackend(Protocol):
    """Strategy protocol: where candidate-subset evaluations execute."""

    name: str

    def evaluate(self, store: TripleStore, class_id: int,
                 props: Sequence[int], n_s: int, am: int) -> StarSweepResult:
        ...

    def sweep(self, store: TripleStore, class_id: int,
              current: StarSweepResult, n_s: int, am: int
              ) -> tuple[StarSweepResult | None, int]:
        ...


def _pick_child(current: StarSweepResult, edges: np.ndarray,
                amis: np.ndarray, n_s: int, am: int) -> StarSweepResult:
    """Shared selection rule: first AMI == 1 candidate (paper Alg. 2 lines
    14-18), else minimum #Edges, first index breaking ties."""
    single = np.where(amis == 1)[0]
    j = int(single[0]) if single.size else int(np.argmin(edges))
    child_props = tuple(p for i, p in enumerate(current.props) if i != j)
    return StarSweepResult(props=child_props, ami=int(amis[j]), am=am,
                           n_total_props=n_s, edges=int(edges[j]))


class HostBackend:
    """The paper-faithful sequential numpy path."""

    name = "host"

    def evaluate(self, store, class_id, props, n_s, am):
        return evaluate_subset(store, class_id, props, n_s, am)

    def sweep(self, store, class_id, current, n_s, am):
        k = len(current.props)
        if k < 3:        # children would have < 2 properties: not stars
            return None, 0
        edges = np.empty((k,), np.int64)
        amis = np.empty((k,), np.int64)
        for j in range(k):
            child_props = tuple(p for i, p in enumerate(current.props)
                                if i != j)
            child = evaluate_subset(store, class_id, child_props, n_s, am)
            edges[j], amis[j] = child.edges, child.ami
        return _pick_child(current, edges, amis, n_s, am), k


class DeviceBackend:
    """Batched jax sweep: all |SP| candidates in one lowering."""

    name = "device"

    def __init__(self, use_kernel: bool = True) -> None:
        self.use_kernel = use_kernel

    def evaluate(self, store, class_id, props, n_s, am):
        # single-subset evaluation is cheaper (and exact) on host
        return evaluate_subset(store, class_id, props, n_s, am)

    def sweep(self, store, class_id, current, n_s, am):
        k = len(current.props)
        if k < 3:
            return None, 0
        import jax.numpy as jnp
        from repro.core.star import sweep_drop_one_device
        props = np.asarray(current.props, np.int32)
        _, objmat = store.object_matrix(class_id, props)
        edges, amis = sweep_drop_one_device(
            jnp.asarray(objmat), am, n_s, use_kernel=self.use_kernel)
        return _pick_child(current, np.asarray(edges), np.asarray(amis),
                           n_s, am), k


class ShardedBackend:
    """Device sweep with the object matrix row-sharded over the mesh.

    Layout policy is routed through the ``repro.dist`` planner: the mesh's
    data-parallel axes come from ``sharding.make_plan`` (DP ladder,
    tensor-parallel axis excluded), rows are padded to the DP degree and
    placed with ``PartitionSpec(dp_axes, None)``, and padding rows are
    masked out of the distinct-signature count.  With ``mesh=None`` this
    degrades to the single-device batched sweep (useful for tests).

    On a real mesh each candidate's AMI runs through
    ``core.distributed.ami_bucketed`` -- the explicit shard_map
    (hash-bucket all_to_all + psum) path.  The implicit GSPMD lowering of
    the sort-based sweep silently miscounts distinct rows on multi-axis
    meshes under jax 0.4.x (per-shard segment counts get summed across
    replicas -- a latent seed bug: ``gfsp_distributed`` built the same
    lowering but was only ever executed with ``mesh=None``), so the
    collective schedule must be explicit.
    """

    name = "sharded"

    def __init__(self, mesh=None, cfg=None, use_kernel: bool = True) -> None:
        self.mesh = mesh
        self.use_kernel = use_kernel
        self.plan = None
        if mesh is not None:
            from repro.dist import sharding as dsh
            # tp=True reserves the "model" axis: FSP rows shard over the
            # data-parallel axes only (matching the seed gfsp_distributed)
            cfg = cfg if cfg is not None else types.SimpleNamespace(
                tp=True, fsdp=False, seq_shard=False)
            self.plan = dsh.make_plan(cfg, mesh)

    def _dp_degree(self) -> int:
        if self.plan is None:
            return 1
        return int(np.prod([self.plan.size(a) for a in self.plan.dp_axes],
                           initial=1))

    def evaluate(self, store, class_id, props, n_s, am):
        return evaluate_subset(store, class_id, props, n_s, am)

    def sweep(self, store, class_id, current, n_s, am):
        k = len(current.props)
        if k < 3:
            return None, 0
        import jax
        import jax.numpy as jnp
        from repro.core.distributed import (ami_bucketed, pad_rows,
                                            sweep_drop_one)
        from repro.core.star import num_edges
        props = np.asarray(current.props, np.int32)
        _, objmat = store.object_matrix(class_id, props)
        padded, n_real = pad_rows(objmat.astype(np.int32, copy=False),
                                  max(self._dp_degree(), 1))
        valid_h = np.arange(padded.shape[0]) < n_real
        if self.mesh is None:
            edges, amis = sweep_drop_one(jnp.asarray(padded),
                                         jnp.asarray(valid_h), am, n_s=n_s,
                                         use_kernel=self.use_kernel)
            edges, amis = np.asarray(edges), np.asarray(amis)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.sharding import batch_axes_for
            axes = (batch_axes_for(self.plan, padded.shape[0])
                    or tuple(self.plan.dp_axes))
            dev = jax.device_put(padded,
                                 NamedSharding(self.mesh, P(axes, None)))
            valid = jax.device_put(valid_h,
                                   NamedSharding(self.mesh, P(axes)))
            amis = np.empty((k,), np.int64)
            for j in range(k):
                # column drop stays on device (row sharding preserved);
                # one host->device upload per sweep, not per candidate
                cand = jnp.delete(dev, j, axis=1,
                                  assume_unique_indices=True)
                amis[j] = int(ami_bucketed(cand, valid, self.mesh,
                                           dp_axes=axes,
                                           use_kernel=self.use_kernel))
            edges = np.asarray([num_edges(a, am, k - 1, n_s) for a in amis])
        return _pick_child(current, edges, amis, n_s, am), k


BACKENDS = Registry("execution backend")
BACKENDS.register("host", HostBackend)
BACKENDS.register("device", DeviceBackend)
BACKENDS.register("sharded", ShardedBackend)


def register_backend(name: str, cls) -> None:
    BACKENDS.register(name, cls)


def get_backend(spec, **opts) -> ExecutionBackend:
    """Resolve a backend: a registered name (instantiated with ``opts``)
    or an already-constructed backend instance (returned as-is)."""
    if isinstance(spec, str):
        return BACKENDS.get(spec)(**opts)
    if isinstance(spec, ExecutionBackend):
        return spec
    raise TypeError(f"not an execution backend: {spec!r}")

"""Detector strategies: *which algorithm* finds the frequent star pattern.

A ``Detector`` maps ``(store, class_id)`` to the paper's ``FSPResult``
(best property subset SP, its Def. 4.8 ``#Edges`` value, AMI, and the
materialized star patterns).  Three strategies are registered by name:

``gfsp``   Algorithm 2, the greedy one-property-removed descent (moved
           here from ``core.gfsp``; the old ``gfsp()`` free function is a
           deprecated shim over this class).  Backend-parametric: every
           per-sweep candidate batch runs on the configured
           ``ExecutionBackend`` (host loop / batched device / sharded).
``efsp``   Algorithm 1, the exhaustive breadth-first scan over the
           property-subset lattice.  Backend-parametric like ``gfsp``:
           each lattice level (all ``C(n, j)`` size-j subsets) is
           evaluated as ONE candidate batch through
           ``SweepWorkspace.sweep_candidates`` -- AMI and Def. 4.8 edges
           for the whole level come back from a single lowering, and the
           gSpan pattern space is never materialized.  (Passing a
           pre-built ``subgraphs_dict`` selects the legacy gSpan-counted
           scan instead.)
``gspan``  the honest gSpan-cost baseline: the full pattern space is
           enumerated (exponential, as the paper's Table 3 measures) and
           only mined property subsets are scored.  With complete
           molecules the detected SP coincides with efsp/gfsp.

gSpan consumes pre-counted pattern multiplicities, so its result is
backend-independent; it accepts (and ignores) the backend argument to
keep ``Compactor`` wiring uniform.
"""
from __future__ import annotations

import itertools
import time
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.efsp import build_subgraphs_dict
from repro.core.gfsp import FSPResult
from repro.core.star import StarSweepResult, num_edges, star_groups
from repro.core.sweep import MAX_SWEEP_CANDIDATES, pick_child
from repro.core.triples import TripleStore

from .backends import ExecutionBackend, HostBackend, Registry, get_backend


@runtime_checkable
class Detector(Protocol):
    """Strategy protocol: find the best frequent star pattern of a class."""

    name: str

    def detect(self, store: TripleStore, class_id: int, *,
               backend: ExecutionBackend | None = None,
               props: Sequence[int] | None = None) -> FSPResult:
        ...


def _class_setup(store: TripleStore, class_id: int,
                 props: Sequence[int] | None):
    stats = store.class_stats(class_id)
    s_all = (np.asarray(list(props), np.int32)
             if props is not None else stats.properties)
    return s_all, int(s_all.shape[0]), stats.n_instances


def _result(store, class_id, best: StarSweepResult, am: int,
            iterations: int, evaluations: int, t0: float) -> FSPResult:
    fsp = star_groups(store, class_id, best.props) if best.props else []
    return FSPResult(
        class_id=class_id, props=best.props, edges=best.edges,
        ami=best.ami, am=am, iterations=iterations, evaluations=evaluations,
        exec_time_ms=(time.perf_counter() - t0) * 1e3, fsp=fsp)


class GreedyDetector:
    """G.FSP -- Algorithm 2: greedy frequent-star-pattern detection.

    Starting from ``SP = S`` (all properties of class C), each sweep
    evaluates every one-property-removed subset ``SP' = SP - {p}`` on the
    execution backend and keeps the subset with the lowest
    ``#Edges(SP', C, G)``.  The descent stops when

      * no subset improves on the current ``#Edges(SP, C, G)`` (Theorem
        4.1 guarantees no deeper subset can improve either), or
      * ``AMI_G(SP|C) == 1`` (a single star pattern), or
      * ``|SP| < 2`` (star patterns need >= 2 properties).

    The published pseudocode initializes the per-sweep best ``fValue'`` to
    0 and tests ``value < fValue'``, which as written never admits a
    candidate; we implement the evidently intended semantics (per-sweep
    best = min over candidates, accept iff it strictly improves).  Ties
    break by first candidate encountered -- assumption (c) of §4.3.

    The whole descent runs against ONE ``backend.workspace``: the class's
    object matrix is extracted (and, on device backends, uploaded) once,
    and every sweep -- including the initial full-S evaluation -- is
    served from that parent buffer.  Evaluation accounting is
    backend-invariant by construction: 1 for the initial subset, then
    ``len(SP)`` per executed sweep, 0 when the children would be sub-star
    (``|SP'| < 2``, no sweep runs).

    Worst case ``n(n+1)/2`` subset evaluations (paper §4.3) vs E.FSP's
    ``2^n``; each sweep is one ``workspace.sweep`` call.
    """

    name = "gfsp"

    def detect(self, store, class_id, *, backend=None, props=None):
        backend = backend if backend is not None else HostBackend()
        t0 = time.perf_counter()
        s_all, n_s, am = _class_setup(store, class_id, props)
        iterations = evaluations = 0
        if n_s == 0 or am == 0:
            empty = StarSweepResult(props=(), ami=0, am=am,
                                    n_total_props=n_s, edges=0)
            return _result(store, class_id, empty, am, iterations,
                           evaluations, t0)
        ws = backend.workspace(store, class_id,
                               tuple(int(p) for p in s_all), n_s, am)
        current = ws.evaluate_current()
        evaluations += 1
        while True:
            iterations += 1
            k = len(current.props)
            # stop: children would be sub-star (|SP'| < 2) or one pattern
            if k < 3 or current.is_single_pattern:
                break
            edges, amis = ws.sweep()
            evaluations += k
            best_child, j = pick_child(current, edges, amis, n_s, am)
            if best_child.edges >= current.edges:
                break          # Theorem 4.1 prunes everything deeper
            ws.descend(j)
            current = best_child
        return _result(store, class_id, current, am, iterations,
                       evaluations, t0)


class ExhaustiveDetector:
    """E.FSP -- Algorithm 1: exhaustive frequent-star-pattern detection.

    Breadth-first scans ALL property subsets of cardinality ``|S| .. 2``,
    keeping the subset that minimizes the Def. 4.8 edge objective.
    O(2^n) subset *evaluations* in the number of class properties -- but
    the evaluations no longer pay gSpan's pattern-space enumeration: each
    lattice level is packed into one column-mask stack and evaluated as a
    single candidate batch through the backend's
    ``SweepWorkspace.sweep_candidates`` (one lowering per level on the
    jax backends, one vectorized group-by per subset on host).  The
    entity universe is the workspace's (entities complete over S, §4.3
    (a)), shared with G.FSP, so efsp <-> gfsp parity is exact by
    construction.

    Passing a pre-built ``subgraphs_dict`` (property subset ->
    ``[(object_tuple, support), ...]``) runs the legacy gSpan-counted
    scan instead -- the paper-literal Algorithm 1 over an externally
    mined pattern space.
    """

    name = "efsp"

    def __init__(self, min_support: int = 1) -> None:
        # only consulted by the legacy subgraphs_dict path (gSpan mining
        # threshold); the lattice engine evaluates every subset exactly
        self.min_support = min_support

    def detect(self, store, class_id, *, backend=None, props=None,
               subgraphs_dict=None):
        t0 = time.perf_counter()
        s_all, n_s, am = _class_setup(store, class_id, props)
        if subgraphs_dict is None and self.min_support > 1:
            # a mining threshold only exists in the gSpan pattern space;
            # keep the legacy thresholded semantics rather than silently
            # evaluating every subset exactly
            subgraphs_dict, _, _ = build_subgraphs_dict(
                store, class_id, min_support=self.min_support)
        if subgraphs_dict is not None:
            return self._detect_from_patterns(store, class_id, s_all, n_s,
                                              am, subgraphs_dict, t0)
        backend = backend if backend is not None else HostBackend()
        best: StarSweepResult | None = None
        iterations = evaluations = 0
        ws = None
        if n_s >= 2:
            ws = backend.workspace(store, class_id,
                                   tuple(int(p) for p in s_all), n_s, am)
        s_list = [int(p) for p in s_all]
        for subset_card in range(n_s, 1, -1):
            iterations += 1
            # stream the level in engine-sized slabs: memory stays
            # O(MAX_SWEEP_CANDIDATES x n_s) even when C(n, j) explodes,
            # and every slab is one lowering on the batched backends
            combo_iter = itertools.combinations(range(n_s), subset_card)
            while True:
                chunk = list(itertools.islice(combo_iter,
                                              MAX_SWEEP_CANDIDATES))
                if not chunk:
                    break
                m = len(chunk)
                cols = np.fromiter(
                    itertools.chain.from_iterable(chunk), dtype=np.int64,
                    count=m * subset_card).reshape(m, subset_card)
                masks = np.zeros((m, n_s), np.int32)
                masks[np.arange(m)[:, None], cols] = 1
                # the whole slab in one candidate batch: AMI + Def. 4.8
                # edges for every size-j subset from one engine call
                edges, amis = ws.sweep_candidates(masks)
                evaluations += m
                j = int(np.argmin(edges))   # first min = paper tie-break
                if best is None or int(edges[j]) < best.edges:
                    best = StarSweepResult(
                        props=tuple(sorted(s_list[i] for i in chunk[j])),
                        ami=int(amis[j]), am=am, n_total_props=n_s,
                        edges=int(edges[j]))
        if best is None:
            best = StarSweepResult(props=(), ami=0, am=am,
                                   n_total_props=n_s, edges=0)
        return _result(store, class_id, best, am, iterations,
                       evaluations, t0)

    def _detect_from_patterns(self, store, class_id, s_all, n_s, am,
                              subgraphs_dict, t0):
        """Legacy Algorithm 1 over a pre-mined gSpan pattern space."""
        best: StarSweepResult | None = None
        iterations = evaluations = 0
        s_list = [int(p) for p in s_all]
        for subset_card in range(n_s, 1, -1):
            iterations += 1
            for combo in itertools.combinations(s_list, subset_card):
                subgraphs = subgraphs_dict.get(frozenset(combo), [])
                evaluations += 1
                # countEdges(subgraphs): factorized edge count of Def. 4.8
                a = len(subgraphs)
                total = num_edges(a, am, subset_card, n_s)
                if best is None or total < best.edges:
                    best = StarSweepResult(
                        props=tuple(sorted(combo)), ami=a, am=am,
                        n_total_props=n_s, edges=total)
        if best is None:
            best = StarSweepResult(props=(), ami=0, am=am,
                                   n_total_props=n_s, edges=0)
        return _result(store, class_id, best, am, iterations,
                       evaluations, t0)


class GSpanBaseline:
    """Score only the property subsets gSpan actually mined.

    The candidate space is exactly the mined pattern space: one evaluation
    per distinct property subset appearing in ``subgraphsDict`` (>= 2
    properties), rather than E.FSP's full ``2^n`` combination scan.  Under
    the paper's complete-molecule assumption every subset of S is mined,
    so the detected SP coincides with E.FSP/G.FSP; the detector exists as
    the honest gSpan-cost baseline (enumeration time dominates).
    """

    name = "gspan"

    def __init__(self, min_support: int = 1,
                 max_edges: int | None = None) -> None:
        self.min_support = min_support
        self.max_edges = max_edges

    def detect(self, store, class_id, *, backend=None, props=None):
        t0 = time.perf_counter()
        s_all, n_s, am = _class_setup(store, class_id, props)
        allowed = {int(p) for p in s_all}
        subgraphs_dict, _, _ = build_subgraphs_dict(
            store, class_id, min_support=self.min_support,
            max_edges=self.max_edges)
        best: StarSweepResult | None = None
        evaluations = 0
        for key in sorted(subgraphs_dict, key=lambda k: (-len(k),
                                                         tuple(sorted(k)))):
            if len(key) < 2 or not key.issubset(allowed):
                continue
            evaluations += 1
            a = len(subgraphs_dict[key])
            total = num_edges(a, am, len(key), n_s)
            if best is None or total < best.edges:
                best = StarSweepResult(props=tuple(sorted(key)), ami=a,
                                       am=am, n_total_props=n_s, edges=total)
        if best is None:       # nothing mined: keep the full set unscored
            if n_s:
                best = (backend or HostBackend()).evaluate(
                    store, class_id, tuple(int(p) for p in s_all), n_s, am)
                evaluations += 1
            else:
                best = StarSweepResult(props=(), ami=0, am=am,
                                       n_total_props=n_s, edges=0)
        return _result(store, class_id, best, am, 1, evaluations, t0)


DETECTORS = Registry("detector")
DETECTORS.register("gfsp", GreedyDetector)
DETECTORS.register("efsp", ExhaustiveDetector)
DETECTORS.register("gspan", GSpanBaseline)


def register_detector(name: str, cls) -> None:
    DETECTORS.register(name, cls)


def get_detector(spec, **opts) -> Detector:
    """Resolve a detector: registered name (instantiated with ``opts``) or
    an already-constructed detector instance."""
    if isinstance(spec, str):
        return DETECTORS.get(spec)(**opts)
    if isinstance(spec, Detector):
        return spec
    raise TypeError(f"not a detector: {spec!r}")


__all__ = ["Detector", "GreedyDetector", "ExhaustiveDetector",
           "GSpanBaseline", "DETECTORS", "register_detector", "get_detector",
           "get_backend"]

"""The unified compaction pipeline: plan -> execute -> absorb updates.

``Compactor`` is the stable public surface over the paper's three
algorithms (detect-FSP -> factorize -> verify lossless):

    comp = Compactor(detector="gfsp", backend="device")
    report = comp.run(store)          # auto-plans every class, factorizes
    report.graph                      # G' (original store untouched)
    comp.update(new_triples)          # streaming inserts, no recomputation

As of the online-compaction refactor the class is a thin facade: all
graph state lives in an immutable :class:`~repro.api.snapshot.
GraphSnapshot` and every transform (plan, execute, update, delete,
redetect) is implemented by :class:`~repro.api.snapshot.
CompactionPlanner`, which builds a *successor* snapshot instead of
mutating anything.  The facade holds exactly one reference
(``self._snapshot``) and commits each transform by swapping it -- a
single atomic attribute assignment, so concurrent readers holding
``comp.snapshot`` (or the fgraph inside it) never observe torn state.
The long-running service in ``repro.online`` drives the same planner
against its own snapshot reference; this class keeps the one-shot
ergonomics.

* **Planning** ranks every class of the store by predicted ``#Edges``
  savings (Def. 4.8); classes whose predicted savings fall below
  ``min_predicted_savings`` are skipped -- the paper's Fig. 7
  factorization-overhead case never executes.
* **Execution** is transactional via ``core.factorize.factorize_classes``:
  the input store is never mutated, and the snapshot swaps in only after
  every class factorized successfully.
* **Incremental update / deletes** absorb streaming edits on the
  factorized form (surrogate reuse, continuing ordinals, payoff-sweep
  decompaction) with losslessness (Def. 4.10/4.11) preserved at every
  step -- each batch is one snapshot swap.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.fgraph import FactorizedGraph
from repro.core.gfsp import FSPResult
from repro.core.triples import TripleStore

from .backends import ExecutionBackend
from .detectors import Detector
# Plan/report dataclasses live with the planner now; re-exported here so
# ``from repro.api.compactor import CompactionPlan`` keeps working.
from .snapshot import (ClassPlan, CompactionPlan, CompactionPlanner,  # noqa: F401
                       CompactionReport, DeleteReport, GraphSnapshot,
                       RedetectReport, UpdateReport)


class Compactor:
    """Configurable detect -> plan -> factorize pipeline (Algorithms 1-3).

    ``detector``/``backend`` accept registered names ("gfsp"/"efsp"/
    "gspan", "host"/"device"/"sharded") or constructed strategy instances;
    ``detector_opts``/``backend_opts`` are forwarded when a name is given
    (e.g. ``backend="sharded", backend_opts={"mesh": mesh}``).

    Facade over :class:`CompactionPlanner` + one :class:`GraphSnapshot`:
    every mutating call builds a successor snapshot and commits it with
    one atomic reference swap.
    """

    def __init__(self, detector: str | Detector = "gfsp",
                 backend: str | ExecutionBackend = "host", *,
                 min_predicted_savings: int = 1,
                 surrogate_prefix: str = "repro:sg",
                 detector_opts: dict | None = None,
                 backend_opts: dict | None = None) -> None:
        self.planner = CompactionPlanner(
            detector, backend,
            min_predicted_savings=min_predicted_savings,
            surrogate_prefix=surrogate_prefix,
            detector_opts=detector_opts, backend_opts=backend_opts)
        self._snapshot: GraphSnapshot | None = None

    # -- planner configuration passthrough ---------------------------------
    @property
    def detector(self) -> Detector:
        return self.planner.detector

    @property
    def backend(self) -> ExecutionBackend:
        return self.planner.backend

    @property
    def min_predicted_savings(self) -> int:
        return self.planner.min_predicted_savings

    @property
    def surrogate_prefix(self) -> str:
        return self.planner.surrogate_prefix

    # -- detection / planning ----------------------------------------------
    def detect(self, store: TripleStore, class_id: int,
               props: Sequence[int] | None = None) -> FSPResult:
        """Run the configured detector on one class."""
        return self.planner.detect(store, class_id, props=props)

    def plan(self, store: TripleStore,
             classes: Iterable[int] | None = None, *,
             stream: bool = False) -> CompactionPlan:
        """Rank all (or the given) classes by predicted #Edges savings.
        ``stream=True`` drops the store's transient decode caches
        between classes (see :meth:`CompactionPlanner.plan`)."""
        return self.planner.plan(store, classes, stream=stream)

    # -- execution ---------------------------------------------------------
    def execute(self, store: TripleStore,
                plan: CompactionPlan) -> CompactionReport:
        """Factorize every planned class transactionally.

        The input store is never mutated; the snapshot (for ``update``/
        ``delete``) swaps in only after all classes succeed.
        """
        snap, report = self.planner.execute(store, plan)
        self._snapshot = snap
        return report

    def run(self, store: TripleStore,
            classes: Iterable[int] | None = None, *,
            stream: bool = False) -> CompactionReport:
        """plan + execute in one call (the common entry point)."""
        return self.execute(store, self.plan(store, classes, stream=stream))

    # -- snapshot state ----------------------------------------------------
    @property
    def snapshot(self) -> GraphSnapshot:
        """The committed immutable snapshot (fgraph + epoch)."""
        if self._snapshot is None:
            raise RuntimeError("Compactor.run()/execute() before .snapshot")
        return self._snapshot

    @property
    def fgraph(self) -> FactorizedGraph:
        """The committed factorized graph (molecule tables + CSR)."""
        if self._snapshot is None:
            raise RuntimeError("Compactor.run()/execute() before .fgraph")
        return self._snapshot.fgraph

    @property
    def graph(self) -> TripleStore:
        return self.fgraph.store

    # -- incremental path --------------------------------------------------
    def update(self, new_triples) -> UpdateReport:
        """Absorb streaming inserts into the factorized graph.

        ``new_triples``: an (n, 3) id array (shared dictionary) or an
        iterable of (subject, property, object) term triples.  New
        entities of factorized classes whose object tuple matches an
        existing star pattern are linked to its surrogate; novel tuples
        mint fresh surrogates (continuing per-class ordinals); incomplete
        molecules and unplanned classes stay raw.  No full recomputation.
        The successor snapshot commits atomically at the end.
        """
        snap, report = self.planner.apply_update(self.snapshot, new_triples)
        self._snapshot = snap
        return report

    def delete(self, triples=None, entities=None) -> DeleteReport:
        """Remove semantic triples and/or entities from the factorized
        graph transactionally.

        ``triples``: an (n, 3) id array or an iterable of term triples;
        ``entities``: an id array or an iterable of entity terms.  Both
        route through :class:`~repro.core.fgraph.FactorizedGraph` delete
        support -- molecule-covered triples dissolve memberships, and
        molecules whose support drops below payoff decompact in place.
        The successor snapshot commits only if every step succeeds.
        """
        snap, report = self.planner.apply_delete(
            self.snapshot, triples=triples, entities=entities)
        self._snapshot = snap
        return report

    def redetect(self, class_ids: Iterable[int]) -> RedetectReport:
        """Re-detect and re-factorize only the given (drifted) classes;
        see :meth:`CompactionPlanner.redetect`."""
        snap, report = self.planner.redetect(self.snapshot, class_ids)
        self._snapshot = snap
        return report

"""The unified compaction pipeline: plan -> execute -> absorb updates.

``Compactor`` is the stable public surface over the paper's three
algorithms (detect-FSP -> factorize -> verify lossless):

    comp = Compactor(detector="gfsp", backend="device")
    report = comp.run(store)          # auto-plans every class, factorizes
    report.graph                      # G' (original store untouched)
    comp.update(new_triples)          # streaming inserts, no recomputation

* **Planning** ranks every class of the store by predicted ``#Edges``
  savings (Def. 4.8): the unfactorized class representation costs
  ``AM_G(C) * |S|`` property edges (= ``#Edges(empty SP)``), the detected
  subset costs ``#Edges(SP*)``; classes whose predicted savings fall
  below ``min_predicted_savings`` are skipped -- the paper's Fig. 7
  factorization-overhead case never executes.
* **Execution** is transactional via ``core.factorize.factorize_classes``:
  the input store is never mutated, and the compactor commits its
  internal state (factorized graph + per-class surrogate signature maps)
  only after every class factorized successfully.
* **Execution commits a ``FactorizedGraph``** (``core.fgraph``): G' is
  not a bare triple array but a first-class structure -- molecule
  tables (surrogate -> object-tuple rows per class), the ``instanceOf``
  CSR, Def. 4.8 accounting, lossless ``expand()`` -- which is what the
  ``repro.query`` star-query engine evaluates against.  ``Compactor.
  graph`` remains the plain ``TripleStore`` view; ``Compactor.fgraph``
  is the structured one.
* **Incremental update** absorbs streaming inserts: new entities whose
  object tuple matches an existing star pattern link to its surrogate
  (one ``instanceOf`` edge); novel tuples mint new surrogates with
  continuing ordinals; incomplete molecules stay raw until later batches
  complete them.  Losslessness (Def. 4.10/4.11) is preserved at every
  step -- the axiom closure of the updated G' equals the closure of
  G + inserts (tested in tests/test_api.py).
* **Deletes** route through ``FactorizedGraph.delete_triples`` /
  ``delete_entities`` transactionally: triples covered by molecules
  dissolve memberships, and molecules whose support falls below payoff
  decompact in place -- the structure never misrepresents the graph.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Sequence

import numpy as np

from repro.core.factorize import (FactorizationResult, apply_molecule_map,
                                  factorize_classes)
from repro.core.fgraph import DeleteStats, FactorizedGraph, MoleculeTable
from repro.core.gfsp import FSPResult
from repro.core.index import in_sorted
from repro.core.star import row_groups
from repro.core.triples import TripleStore

from .backends import ExecutionBackend, get_backend
from .detectors import Detector, get_detector


@dataclasses.dataclass(frozen=True)
class ClassPlan:
    """One planned (class, SP) factorization with its predicted payoff.

    The predictions are filled by the auto-planner; explicit plans carry
    ``None`` (the caller already decided, so no evaluation is spent).
    """

    class_id: int
    props: tuple[int, ...]
    predicted_edges: int | None = None   # #Edges(SP, C, G) -- Def. 4.8
    baseline_edges: int | None = None    # #Edges(emptyset) = AM_G(C) * |S|
    detection: FSPResult | None = None

    @property
    def predicted_savings(self) -> int | None:
        if self.predicted_edges is None or self.baseline_edges is None:
            return None
        return self.baseline_edges - self.predicted_edges

    @property
    def pct_predicted_savings(self) -> float:
        savings = self.predicted_savings
        if not self.baseline_edges or savings is None:
            return 0.0
        return 100.0 * savings / self.baseline_edges


@dataclasses.dataclass
class CompactionPlan:
    """Ranked multi-class factorization plan (highest predicted savings
    first for auto-plans; given order for explicit plans)."""

    entries: list[ClassPlan]
    detector: str = "explicit"
    backend: str = "host"

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def __bool__(self) -> bool:
        return bool(self.entries)

    @classmethod
    def explicit(cls, pairs: Sequence[tuple[int, Sequence[int]]]
                 ) -> "CompactionPlan":
        """Plan from caller-chosen (class_id, props) pairs, applied in the
        given order (no ranking, no savings filter, no detection cost --
        predictions stay ``None``)."""
        entries = [ClassPlan(class_id=int(cid),
                             props=tuple(sorted(int(p) for p in props)))
                   for cid, props in pairs]
        return cls(entries=entries, detector="explicit", backend="host")


@dataclasses.dataclass
class CompactionReport:
    """Outcome of one transactional multi-class compaction."""

    graph: TripleStore
    plan: CompactionPlan
    factorizations: list[FactorizationResult]
    n_triples_before: int
    n_triples_after: int
    exec_time_ms: float
    fgraph: FactorizedGraph | None = None   # the structured G' (queryable)

    @property
    def pct_savings_triples(self) -> float:
        if self.n_triples_before == 0:
            return 0.0
        return 100.0 * (self.n_triples_before - self.n_triples_after) \
            / self.n_triples_before

    @property
    def detections(self) -> dict[int, FSPResult]:
        return {e.class_id: e.detection for e in self.plan
                if e.detection is not None}

    def factorization_for(self, class_id: int) -> FactorizationResult:
        for f in self.factorizations:
            if f.class_id == class_id:
                return f
        raise KeyError(class_id)


@dataclasses.dataclass
class UpdateReport:
    """Outcome of one incremental ``Compactor.update`` batch."""

    graph: TripleStore
    n_new_triples: int
    n_entities_absorbed: int
    n_new_surrogates: int
    n_surrogates_reused: int
    exec_time_ms: float


@dataclasses.dataclass
class DeleteReport:
    """Outcome of one transactional ``Compactor.delete`` batch."""

    graph: TripleStore
    stats: DeleteStats
    exec_time_ms: float


class Compactor:
    """Configurable detect -> plan -> factorize pipeline (Algorithms 1-3).

    ``detector``/``backend`` accept registered names ("gfsp"/"efsp"/
    "gspan", "host"/"device"/"sharded") or constructed strategy instances;
    ``detector_opts``/``backend_opts`` are forwarded when a name is given
    (e.g. ``backend="sharded", backend_opts={"mesh": mesh}``).
    """

    def __init__(self, detector: str | Detector = "gfsp",
                 backend: str | ExecutionBackend = "host", *,
                 min_predicted_savings: int = 1,
                 surrogate_prefix: str = "repro:sg",
                 detector_opts: dict | None = None,
                 backend_opts: dict | None = None) -> None:
        self.detector = get_detector(detector, **(detector_opts or {}))
        self.backend = get_backend(backend, **(backend_opts or {}))
        self.min_predicted_savings = min_predicted_savings
        self.surrogate_prefix = surrogate_prefix
        self._fg: FactorizedGraph | None = None

    # -- detection ---------------------------------------------------------
    def detect(self, store: TripleStore, class_id: int,
               props: Sequence[int] | None = None) -> FSPResult:
        """Run the configured detector on one class."""
        return self.detector.detect(store, int(class_id),
                                    backend=self.backend, props=props)

    # -- planning ----------------------------------------------------------
    def plan(self, store: TripleStore,
             classes: Iterable[int] | None = None) -> CompactionPlan:
        """Rank all (or the given) classes by predicted #Edges savings."""
        cids = ([int(c) for c in classes] if classes is not None
                else [int(c) for c in store.classes()])
        entries = []
        for cid in cids:
            stats = store.class_stats(cid)
            n_s = int(stats.properties.shape[0])
            am = stats.n_instances
            if n_s < 2 or am == 0:
                continue                      # nothing star-shaped to share
            res = self.detect(store, cid)
            if len(res.props) < 2:
                continue
            entry = ClassPlan(class_id=cid, props=tuple(sorted(res.props)),
                              predicted_edges=res.edges,
                              baseline_edges=am * n_s, detection=res)
            if entry.predicted_savings >= self.min_predicted_savings:
                entries.append(entry)
        entries.sort(key=lambda e: -e.predicted_savings)
        return CompactionPlan(entries=entries, detector=self.detector.name,
                              backend=self.backend.name)

    # -- execution ---------------------------------------------------------
    def execute(self, store: TripleStore,
                plan: CompactionPlan) -> CompactionReport:
        """Factorize every planned class transactionally.

        The input store is never mutated; compactor state (for
        ``update``) commits only after all classes succeed.
        """
        t0 = time.perf_counter()
        pairs = [(e.class_id, e.props) for e in plan]
        graph, results = factorize_classes(
            store, pairs, surrogate_prefix=self.surrogate_prefix)
        # star_objects rows are aligned with surrogates and ordered over
        # sorted props -- the molecule tables build with no rescan of G'
        self._fg = FactorizedGraph.from_compaction(graph, results)
        return CompactionReport(
            graph=graph, plan=plan, factorizations=results,
            n_triples_before=store.n_triples, n_triples_after=graph.n_triples,
            exec_time_ms=(time.perf_counter() - t0) * 1e3,
            fgraph=self._fg)

    def run(self, store: TripleStore,
            classes: Iterable[int] | None = None) -> CompactionReport:
        """plan + execute in one call (the common entry point)."""
        return self.execute(store, self.plan(store, classes))

    # -- incremental path --------------------------------------------------
    @property
    def fgraph(self) -> FactorizedGraph:
        """The committed factorized graph (molecule tables + CSR)."""
        if self._fg is None:
            raise RuntimeError("Compactor.run()/execute() before .fgraph")
        return self._fg

    @property
    def graph(self) -> TripleStore:
        return self.fgraph.store

    def update(self, new_triples) -> UpdateReport:
        """Absorb streaming inserts into the factorized graph.

        ``new_triples``: an (n, 3) id array (shared dictionary) or an
        iterable of (subject, property, object) term triples.  New
        entities of factorized classes whose object tuple matches an
        existing star pattern are linked to its surrogate; novel tuples
        mint fresh surrogates (continuing per-class ordinals); incomplete
        molecules and unplanned classes stay raw.  No full recomputation.
        The molecule tables gain the fresh rows and the whole
        ``FactorizedGraph`` commits atomically at the end.
        """
        fg = self.fgraph
        t0 = time.perf_counter()
        g = fg.store
        if isinstance(new_triples, np.ndarray):
            rows = np.asarray(new_triples, np.int32).reshape(-1, 3)
        else:
            trips = list(new_triples)
            if trips:
                flat = [t for spo in trips for t in spo]
                rows = g.dict.ids(flat).reshape(-1, 3)
            else:
                rows = np.empty((0, 3), np.int32)
        # merge-on-append: the (usually small) batch merges into the
        # sorted triple array and the live GraphIndex in O(n + m log n);
        # the factorized graph is never re-sorted or re-indexed wholesale
        combined = g.copy()
        combined.add_ids(rows)
        n_absorbed = n_new_sg = n_reused = 0
        # classes are processed sequentially against the running graph so
        # overlapping-class entities keep the same semantics as a full
        # factorize_classes pass; the surrogate id set is loop-invariant
        # (ids minted below are never entities of another planned class)
        sg_arr = fg.surrogate_ids.astype(np.int64)
        new_tables: dict[int, MoleculeTable] = {}
        for cid, table in fg.tables.items():
            sig = dict(table.sig)          # working copy: commit-at-end
            next_ordinal = table.next_ordinal
            props_arr = np.asarray(table.props, np.int32)
            fresh_rows: list[tuple[int, ...]] = []
            new_tables[cid] = table
            ents, objmat = combined.object_matrix(cid, props_arr)
            if ents.size == 0:
                continue
            raw = ~in_sorted(ents, sg_arr)    # never re-factorize surrogates
            if not raw.any():
                continue
            r_ents, r_mat = ents[raw], objmat[raw]
            inv, counts, rep = row_groups(r_mat)
            sg_of_group = np.empty((counts.shape[0],), np.int64)
            fresh: list[tuple[int, tuple[int, ...]]] = []
            for gi in range(counts.shape[0]):
                key = tuple(int(x) for x in r_mat[rep[gi]])
                sg = sig.get(key)
                if sg is None:
                    fresh.append((gi, key))
                else:
                    sg_of_group[gi] = sg
            if fresh:
                cname = combined.dict.term(cid)
                names = [f"{self.surrogate_prefix}/{cname}/"
                         f"{next_ordinal + j}" for j in range(len(fresh))]
                new_ids = combined.dict.ids(names)
                next_ordinal += len(fresh)
                for (gi, key), sid in zip(fresh, new_ids.tolist()):
                    sg_of_group[gi] = sid
                    sig[key] = int(sid)
                    fresh_rows.append(key)
                new_tables[cid] = table.with_rows(
                    new_ids, np.asarray(fresh_rows, np.int32),
                    next_ordinal)
            n_new_sg += len(fresh)
            n_reused += int(counts.shape[0]) - len(fresh)
            n_absorbed += int(r_ents.shape[0])
            # rewrite only the absorbed entities' own rows; the rest of
            # the (possibly huge) factorized graph passes through as a
            # presorted slice and the rewritten rows merge back in.  The
            # live index follows the same remove-then-merge path (a row
            # subset of a sorted index stays sorted), so no class of this
            # loop ever triggers a full O(|G| log |G|) re-index.
            spo = combined.spo
            touched = in_sorted(spo[:, 0], r_ents)
            rewritten = apply_molecule_map(
                spo[touched], r_ents, sg_of_group[inv].astype(np.int32),
                props_arr, cid, combined.TYPE, combined.INSTANCE_OF)
            idx = combined.index
            kept_index = idx.filtered(~in_sorted(idx.rows[:, 0], r_ents))
            combined = TripleStore.from_ids(combined.dict, spo[~touched],
                                            presorted=True)
            combined.add_ids(rewritten)
            combined._index = kept_index.merged(rewritten)
        self._fg = FactorizedGraph(
            combined, new_tables,
            payoff_min_support=fg.payoff_min_support)
        return UpdateReport(
            graph=combined, n_new_triples=int(rows.shape[0]),
            n_entities_absorbed=n_absorbed, n_new_surrogates=n_new_sg,
            n_surrogates_reused=n_reused,
            exec_time_ms=(time.perf_counter() - t0) * 1e3)

    def delete(self, triples=None, entities=None) -> DeleteReport:
        """Remove semantic triples and/or entities from the factorized
        graph transactionally.

        ``triples``: an (n, 3) id array or an iterable of term triples;
        ``entities``: an id array or an iterable of entity terms.  Both
        route through :class:`~repro.core.fgraph.FactorizedGraph` delete
        support -- molecule-covered triples dissolve memberships, and
        molecules whose support drops below payoff decompact in place.
        The new graph commits only if every step succeeds.
        """
        fg = self.fgraph
        t0 = time.perf_counter()
        stats = DeleteStats()
        if triples is not None:
            if isinstance(triples, np.ndarray):
                rows = np.asarray(triples, np.int32).reshape(-1, 3)
            else:
                # lookup, never id(): a term the graph has never seen
                # cannot name an existing triple, and a no-op delete must
                # not grow the shared dictionary as a side effect
                d = fg.store.dict
                rows_list = []
                n_unknown = 0
                for s, p, o in triples:
                    ids3 = (d.lookup(s), d.lookup(p), d.lookup(o))
                    if None in ids3:
                        n_unknown += 1
                        continue
                    rows_list.append(ids3)
                stats.n_requested += n_unknown     # counted, trivially absent
                rows = np.asarray(rows_list, np.int32).reshape(-1, 3)
            fg, st = fg.delete_triples(rows)
            for f in dataclasses.fields(st):
                setattr(stats, f.name,
                        getattr(stats, f.name) + getattr(st, f.name))
        if entities is not None:
            if isinstance(entities, np.ndarray):
                ids = np.asarray(entities, np.int64).reshape(-1)
            else:
                d = fg.store.dict
                looked = [d.lookup(e) for e in entities]
                stats.n_requested += sum(1 for x in looked if x is None)
                ids = np.asarray([x for x in looked if x is not None],
                                 np.int64)
            fg, st = fg.delete_entities(ids)
            for f in dataclasses.fields(st):
                setattr(stats, f.name,
                        getattr(stats, f.name) + getattr(st, f.name))
        self._fg = fg
        return DeleteReport(graph=fg.store, stats=stats,
                            exec_time_ms=(time.perf_counter() - t0) * 1e3)

"""Stable compaction API: pluggable detectors x execution backends,
multi-class auto-planning, transactional factorization, incremental
updates.

The paper's pipeline (detect-FSP -> factorize -> verify lossless,
Algorithms 1-3) is exposed as strategies instead of free functions with
boolean toggles:

    from repro.api import Compactor

    comp = Compactor(detector="gfsp", backend="device")
    report = comp.run(store)           # rank classes, factorize the winners
    comp.update(new_triples)           # absorb streaming inserts

Extension points (see the ``Registry`` helpers):

* detectors -- ``gfsp`` (greedy, Alg. 2), ``efsp`` (exhaustive, Alg. 1),
  ``gspan`` (mined-pattern-space baseline); ``register_detector`` adds
  more.
* execution backends -- ``host`` (numpy), ``device`` (batched jax /
  Pallas), ``sharded`` (mesh-sharded via the ``repro.dist`` planner);
  ``register_backend`` adds more.

The old free functions (``core.gfsp.gfsp``, ``core.efsp.efsp``,
``core.factorize.factorize``) remain as deprecated shims over this API.
"""
from .backends import (BACKENDS, DeviceBackend, ExecutionBackend,  # noqa: F401
                       HostBackend, Registry, ShardedBackend, get_backend,
                       register_backend)
from .detectors import (DETECTORS, Detector, ExhaustiveDetector,  # noqa: F401
                        GreedyDetector, GSpanBaseline, get_detector,
                        register_detector)
from .snapshot import (ClassPlan, CompactionPlan, CompactionPlanner,  # noqa: F401
                       CompactionReport, DeleteReport, GraphSnapshot,
                       RedetectReport, UpdateReport)
from .compactor import Compactor  # noqa: F401

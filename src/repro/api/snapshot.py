"""Snapshot-swapped compaction substrate: immutable versioned snapshots
plus the planner that builds new ones.

The transactional one-shot surface (``Compactor.run`` -> mutate-in-place
``update``/``delete``) is the wrong substrate for a *service*: readers
must never observe a half-committed graph, and recompaction must be able
to run while queries are being served.  This module splits the old
``Compactor`` internals into two pieces:

* :class:`GraphSnapshot` -- an immutable, epoch-versioned view of the
  compact form: one :class:`~repro.core.fgraph.FactorizedGraph` (which
  carries its own ``GraphIndex`` and instanceOf CSR) plus an ``epoch``
  id.  Snapshots are never mutated; every change produces a *successor*
  snapshot (``epoch + 1``) and the owner swaps a single reference -- an
  atomic pointer flip, so a reader holding the old snapshot keeps a
  fully-consistent (tables <-> CSR <-> index) world view for as long as
  it wants.

* :class:`CompactionPlanner` -- the pure compaction brain, operating on
  snapshots: ``plan``/``execute`` (the paper's Algorithms 1-3 over a
  plain store), ``apply_update``/``apply_delete`` (the incremental paths
  reimplemented as build-new-snapshot transforms), and ``redetect`` --
  targeted re-detection of *drifted* classes only: the dirty classes are
  decompacted in place, re-detected through the existing candidate-
  batched sweep engine, and re-factorized, while every clean class's
  molecule table and surrogate triples pass through untouched.  Sweep
  work (``core.sweep.EXEC_STATS`` descents) is therefore proportional to
  the dirty-class set, never to the whole graph.

``repro.api.Compactor`` remains as a thin facade (hold one snapshot,
delegate to a planner, swap on mutation); ``repro.online`` drives the
same planner from its write-ahead ingest queue.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Iterable, Sequence

import numpy as np

from repro.core import sweep as core_sweep
from repro.core.factorize import (FactorizationResult, apply_molecule_map,
                                  factorize_classes)
from repro.core.fgraph import DeleteStats, FactorizedGraph, MoleculeTable
from repro.core.gfsp import FSPResult
from repro.core.index import GraphIndex, in_sorted
from repro.core.star import row_groups
from repro.core.triples import TripleStore

from .backends import ExecutionBackend, get_backend
from .detectors import Detector, get_detector


# ---------------------------------------------------------------------------
# plan / report dataclasses (moved verbatim from api.compactor)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassPlan:
    """One planned (class, SP) factorization with its predicted payoff.

    The predictions are filled by the auto-planner; explicit plans carry
    ``None`` (the caller already decided, so no evaluation is spent).
    """

    class_id: int
    props: tuple[int, ...]
    predicted_edges: int | None = None   # #Edges(SP, C, G) -- Def. 4.8
    baseline_edges: int | None = None    # #Edges(emptyset) = AM_G(C) * |S|
    detection: FSPResult | None = None

    @property
    def predicted_savings(self) -> int | None:
        if self.predicted_edges is None or self.baseline_edges is None:
            return None
        return self.baseline_edges - self.predicted_edges

    @property
    def pct_predicted_savings(self) -> float:
        savings = self.predicted_savings
        if not self.baseline_edges or savings is None:
            return 0.0
        return 100.0 * savings / self.baseline_edges


@dataclasses.dataclass
class CompactionPlan:
    """Ranked multi-class factorization plan (highest predicted savings
    first for auto-plans; given order for explicit plans)."""

    entries: list[ClassPlan]
    detector: str = "explicit"
    backend: str = "host"

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def __bool__(self) -> bool:
        return bool(self.entries)

    @classmethod
    def explicit(cls, pairs: Sequence[tuple[int, Sequence[int]]]
                 ) -> "CompactionPlan":
        """Plan from caller-chosen (class_id, props) pairs, applied in the
        given order (no ranking, no savings filter, no detection cost --
        predictions stay ``None``)."""
        entries = [ClassPlan(class_id=int(cid),
                             props=tuple(sorted(int(p) for p in props)))
                   for cid, props in pairs]
        return cls(entries=entries, detector="explicit", backend="host")


@dataclasses.dataclass
class CompactionReport:
    """Outcome of one transactional multi-class compaction."""

    graph: TripleStore
    plan: CompactionPlan
    factorizations: list[FactorizationResult]
    n_triples_before: int
    n_triples_after: int
    exec_time_ms: float
    fgraph: FactorizedGraph | None = None   # the structured G' (queryable)

    @property
    def pct_savings_triples(self) -> float:
        if self.n_triples_before == 0:
            return 0.0
        return 100.0 * (self.n_triples_before - self.n_triples_after) \
            / self.n_triples_before

    @property
    def detections(self) -> dict[int, FSPResult]:
        return {e.class_id: e.detection for e in self.plan
                if e.detection is not None}

    def factorization_for(self, class_id: int) -> FactorizationResult:
        for f in self.factorizations:
            if f.class_id == class_id:
                return f
        raise KeyError(class_id)


@dataclasses.dataclass
class UpdateReport:
    """Outcome of one incremental update batch."""

    graph: TripleStore
    n_new_triples: int
    n_entities_absorbed: int
    n_new_surrogates: int
    n_surrogates_reused: int
    exec_time_ms: float
    # per-class deltas for drift tracking: class id -> {"absorbed",
    # "new_surrogates", "reused"}; classes only *touched* (a type row
    # landed but nothing absorbed -- incomplete molecules, brand-new
    # classes) appear in ``touched_classes`` with no delta entry
    per_class: dict[int, dict[str, int]] = dataclasses.field(
        default_factory=dict)
    touched_classes: tuple[int, ...] = ()


@dataclasses.dataclass
class DeleteReport:
    """Outcome of one transactional delete batch."""

    graph: TripleStore
    stats: DeleteStats
    exec_time_ms: float


@dataclasses.dataclass
class RedetectReport:
    """Outcome of one targeted (dirty-classes-only) re-detection pass."""

    considered: tuple[int, ...]      # classes re-evaluated
    refactorized: tuple[int, ...]    # classes the plan kept (payoff >= min)
    plan: CompactionPlan
    exec_time_ms: float
    epoch: int                       # epoch of the snapshot it produced
    descents: int = 0                # EXEC_STATS delta: sweep work spent
    lowerings: int = 0
    per_class_savings: dict[int, int] = dataclasses.field(
        default_factory=dict)       # class id -> predicted Def. 4.8 savings
    rejected: bool = False           # realized-edges guard kept the old form
    edges_before: int = 0            # snapshot triple count going in
    edges_after: int = 0             # ... and of the snapshot returned


# ---------------------------------------------------------------------------
# the snapshot
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class GraphSnapshot:
    """Immutable, versioned view of the compact form.

    Holds one :class:`FactorizedGraph` (tables + instanceOf CSR + the
    store's ``GraphIndex``) and an ``epoch``.  All mutation in this
    codebase is build-new-snapshot-then-swap: a reader that grabbed a
    snapshot can never observe torn state (tables from one version, CSR
    from another), because nothing it references is ever written again.
    """

    fgraph: FactorizedGraph
    epoch: int = 0
    # one-slot memo for ``digest()`` -- a mutable cell so the frozen
    # dataclass can fill it lazily; a swap creates a NEW snapshot object,
    # so invalidation is automatic (never carried across epochs)
    _digest_cache: list = dataclasses.field(
        default_factory=list, init=False, repr=False, compare=False)

    @property
    def store(self) -> TripleStore:
        return self.fgraph.store

    @property
    def index(self) -> GraphIndex:
        return self.fgraph.store.index

    @property
    def n_triples(self) -> int:
        return self.fgraph.n_triples

    def next(self, fgraph: FactorizedGraph) -> "GraphSnapshot":
        """Successor snapshot: new factorized graph, epoch + 1."""
        return GraphSnapshot(fgraph=fgraph, epoch=self.epoch + 1)

    def digest(self) -> str:
        """sha1 of the *semantic* graph (``expand()``, canonical row
        order) -- two snapshots with equal digests represent the same RDF
        graph regardless of how it is factorized.  Cached per snapshot:
        the snapshot is immutable, so the first expansion's hash stays
        valid for its whole lifetime (the online soak's parity checks
        call this in a loop; at 1M triples re-expanding would dominate
        wall clock)."""
        if not self._digest_cache:
            self._digest_cache.append(hashlib.sha1(
                np.ascontiguousarray(self.fgraph.expand().spo).tobytes()
            ).hexdigest()[:16])
        return self._digest_cache[0]

    def to_state(self) -> tuple[dict[str, np.ndarray], dict]:
        """Decompose into (named arrays, JSON-safe meta) for durable
        checkpoints (``repro.online.recovery``).

        The dictionary is deliberately NOT here -- it is shared,
        append-only state owned by the service, checkpointed as a term-
        list prefix alongside.  The instanceOf CSR, surrogate locator
        and ``GraphIndex`` are all rebuilt from ``spo`` + tables by the
        :class:`FactorizedGraph` constructor, so they need no bytes on
        disk.  Everything referenced is immutable, so serialization may
        run on a background thread while the service keeps swapping."""
        fg = self.fgraph
        arrays: dict[str, np.ndarray] = {"spo": fg.store.spo}
        meta = {"epoch": int(self.epoch),
                "payoff_min_support": int(fg.payoff_min_support),
                "tables": []}
        for cid in sorted(fg.tables):
            t = fg.tables[cid]
            arrays[f"table_{cid}_surrogates"] = t.surrogates
            arrays[f"table_{cid}_objects"] = t.objects
            meta["tables"].append({"class_id": int(cid),
                                   "props": [int(p) for p in t.props],
                                   "next_ordinal": int(t.next_ordinal)})
        return arrays, meta

    @classmethod
    def from_state(cls, dictionary, arrays: dict[str, np.ndarray],
                   meta: dict) -> "GraphSnapshot":
        """Inverse of :meth:`to_state` over a restored dictionary."""
        store = TripleStore.from_ids(dictionary, arrays["spo"],
                                     presorted=True)
        tables = {}
        for ent in meta["tables"]:
            cid = int(ent["class_id"])
            tables[cid] = MoleculeTable(
                class_id=cid, props=tuple(ent["props"]),
                surrogates=arrays[f"table_{cid}_surrogates"],
                objects=arrays[f"table_{cid}_objects"],
                next_ordinal=int(ent["next_ordinal"]), presorted=True)
        fg = FactorizedGraph(
            store, tables,
            payoff_min_support=int(meta["payoff_min_support"]))
        return cls(fgraph=fg, epoch=int(meta["epoch"]))

    def __repr__(self) -> str:  # pragma: no cover
        return (f"GraphSnapshot(epoch={self.epoch}, "
                f"n_triples={self.n_triples}, "
                f"classes={len(self.fgraph.tables)})")


def _merge_delete_stats(acc: DeleteStats, st: DeleteStats) -> None:
    """Field-wise accumulate ``st`` into ``acc`` (ints add, the
    ``per_class`` dicts merge key-wise)."""
    for f in dataclasses.fields(st):
        if f.name == "per_class":
            for cid, deltas in st.per_class.items():
                d = acc.per_class.setdefault(cid, {})
                for k, v in deltas.items():
                    d[k] = d.get(k, 0) + v
        else:
            setattr(acc, f.name,
                    getattr(acc, f.name) + getattr(st, f.name))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

class CompactionPlanner:
    """Pure detect/plan/factorize/update/delete/redetect over snapshots.

    Every method either reads a plain store (``plan``/``execute``) or a
    :class:`GraphSnapshot` and -- when it changes anything -- returns a
    *new* snapshot, leaving its input untouched.  The planner holds only
    configuration (detector, backend, thresholds); all graph state lives
    in the snapshots, which is what makes the owner's commit an atomic
    reference swap.
    """

    def __init__(self, detector: str | Detector = "gfsp",
                 backend: str | ExecutionBackend = "host", *,
                 min_predicted_savings: int = 1,
                 surrogate_prefix: str = "repro:sg",
                 detector_opts: dict | None = None,
                 backend_opts: dict | None = None) -> None:
        self.detector = get_detector(detector, **(detector_opts or {}))
        self.backend = get_backend(backend, **(backend_opts or {}))
        self.min_predicted_savings = min_predicted_savings
        self.surrogate_prefix = surrogate_prefix

    # -- detection ---------------------------------------------------------
    def detect(self, store: TripleStore, class_id: int,
               props: Sequence[int] | None = None) -> FSPResult:
        """Run the configured detector on one class."""
        return self.detector.detect(store, int(class_id),
                                    backend=self.backend, props=props)

    def _shard_planner(self, sid: int) -> "CompactionPlanner":
        """Per-shard clone: same detector/backend instances, a shard-
        suffixed surrogate prefix so parallel shards minting into the
        shared dictionary can never collide on a surrogate name."""
        return CompactionPlanner(
            self.detector, self.backend,
            min_predicted_savings=self.min_predicted_savings,
            surrogate_prefix=f"{self.surrogate_prefix}/s{int(sid)}")

    # -- planning ----------------------------------------------------------
    def plan(self, store: TripleStore | None = None,
             classes: Iterable[int] | None = None, *,
             stream: bool = False,
             sharded_graph=None) -> CompactionPlan | dict:
        """Rank all (or the given) classes by predicted #Edges savings.

        With ``sharded_graph=`` (a
        :class:`~repro.dist.graph.ShardedFactorizedGraph`) the ranking
        runs shard-local over each shard's semantic sub-store and a
        ``{shard_id: CompactionPlan}`` dict comes back -- the detection
        itself never leaves the shard.

        ``stream=True`` releases the store's transient decode caches
        between classes (compressed tier: resident CSR partitions,
        per-class entity vectors, sorted-object caches), so detection
        over an out-of-core-scale graph holds at most one class's
        working set uncompressed at a time -- peak RSS is bounded by the
        largest class bucket, not the graph."""
        if sharded_graph is not None:
            out = {}
            for sid, snap in enumerate(sharded_graph.snapshots):
                sub = (snap.fgraph.store if not snap.fgraph.tables
                       else snap.fgraph.expand())
                out[sid] = self._shard_planner(sid).plan(
                    sub, classes, stream=stream)
            return out
        if store is None:
            raise ValueError("plan() needs a store or a sharded_graph")
        cids = ([int(c) for c in classes] if classes is not None
                else [int(c) for c in store.classes()])
        release = getattr(store, "release_transients", None) \
            if stream else None
        entries = []
        for cid in cids:
            stats = store.class_stats(cid)
            n_s = int(stats.properties.shape[0])
            am = stats.n_instances
            if n_s < 2 or am == 0:
                continue                      # nothing star-shaped to share
            res = self.detect(store, cid)
            if len(res.props) < 2:
                if release is not None:
                    release()
                continue
            entry = ClassPlan(class_id=cid, props=tuple(sorted(res.props)),
                              predicted_edges=res.edges,
                              baseline_edges=am * n_s, detection=res)
            if entry.predicted_savings >= self.min_predicted_savings:
                entries.append(entry)
            if release is not None:
                release()
        entries.sort(key=lambda e: -e.predicted_savings)
        return CompactionPlan(entries=entries, detector=self.detector.name,
                              backend=self.backend.name)

    # -- execution ---------------------------------------------------------
    def execute(self, store: TripleStore, plan: CompactionPlan, *,
                epoch: int = 0) -> tuple[GraphSnapshot, CompactionReport]:
        """Factorize every planned class transactionally into a fresh
        snapshot.  The input store is never mutated."""
        t0 = time.perf_counter()
        pairs = [(e.class_id, e.props) for e in plan]
        graph, results = factorize_classes(
            store, pairs, surrogate_prefix=self.surrogate_prefix)
        # star_objects rows are aligned with surrogates and ordered over
        # sorted props -- the molecule tables build with no rescan of G'
        fg = FactorizedGraph.from_compaction(graph, results)
        snap = GraphSnapshot(fgraph=fg, epoch=epoch)
        report = CompactionReport(
            graph=graph, plan=plan, factorizations=results,
            n_triples_before=store.n_triples, n_triples_after=graph.n_triples,
            exec_time_ms=(time.perf_counter() - t0) * 1e3,
            fgraph=fg)
        return snap, report

    def run(self, store: TripleStore,
            classes: Iterable[int] | None = None
            ) -> tuple[GraphSnapshot, CompactionReport]:
        """plan + execute in one call (the common entry point)."""
        return self.execute(store, self.plan(store, classes))

    # -- incremental update ------------------------------------------------
    def apply_update(self, snapshot: GraphSnapshot,
                     new_triples) -> tuple[GraphSnapshot, UpdateReport]:
        """Absorb streaming inserts into a new snapshot.

        ``new_triples``: an (n, 3) id array (shared dictionary) or an
        iterable of (subject, property, object) term triples.  New
        entities of factorized classes whose object tuple matches an
        existing star pattern are linked to its surrogate; novel tuples
        mint fresh surrogates (continuing per-class ordinals); incomplete
        molecules and unplanned classes stay raw.  No full recomputation,
        no mutation of ``snapshot``.
        """
        fg = snapshot.fgraph
        t0 = time.perf_counter()
        g = fg.store
        if isinstance(new_triples, np.ndarray):
            rows = np.asarray(new_triples, np.int32).reshape(-1, 3)
        else:
            trips = list(new_triples)
            if trips:
                flat = [t for spo in trips for t in spo]
                rows = g.dict.ids(flat).reshape(-1, 3)
            else:
                rows = np.empty((0, 3), np.int32)
        # merge-on-append: the (usually small) batch merges into the
        # sorted triple array and the live GraphIndex in O(n + m log n);
        # the factorized graph is never re-sorted or re-indexed wholesale.
        # A compressed-tier store migrates to the plain tier here (one
        # decode) instead of repacking per batch -- the online service's
        # background recompression re-packs it off the hot path.
        if getattr(g, "is_compressed", False):
            combined = TripleStore.from_ids(g.dict, g.spo, presorted=True)
        else:
            combined = g.copy()
        combined.add_ids(rows)
        n_absorbed = n_new_sg = n_reused = 0
        per_class: dict[int, dict[str, int]] = {}
        # classes are processed sequentially against the running graph so
        # overlapping-class entities keep the same semantics as a full
        # factorize_classes pass; the surrogate id set is loop-invariant
        # (ids minted below are never entities of another planned class)
        sg_arr = fg.surrogate_ids.astype(np.int64)
        new_tables: dict[int, MoleculeTable] = {}
        for cid, table in fg.tables.items():
            sig = table.sig            # read-only probe; commit-at-end
            next_ordinal = table.next_ordinal
            props_arr = np.asarray(table.props, np.int32)
            new_tables[cid] = table
            ents, objmat = combined.object_matrix(cid, props_arr)
            if ents.size == 0:
                continue
            raw = ~in_sorted(ents, sg_arr)    # never re-factorize surrogates
            if not raw.any():
                continue
            r_ents, r_mat = ents[raw], objmat[raw]
            inv, counts, rep = row_groups(r_mat)
            sg_of_group = np.empty((counts.shape[0],), np.int64)
            fresh: list[tuple[int, tuple[int, ...]]] = []
            for gi in range(counts.shape[0]):
                key = tuple(int(x) for x in r_mat[rep[gi]])
                sg = sig.get(key)
                if sg is None:
                    fresh.append((gi, key))
                else:
                    sg_of_group[gi] = sg
            if fresh:
                cname = combined.dict.term(cid)
                names = [f"{self.surrogate_prefix}/{cname}/"
                         f"{next_ordinal + j}" for j in range(len(fresh))]
                new_ids = combined.dict.ids(names)
                next_ordinal += len(fresh)
                fresh_rows = np.asarray([key for _, key in fresh], np.int32)
                for (gi, _), sid in zip(fresh, new_ids.tolist()):
                    sg_of_group[gi] = sid
                # amortized append: fresh ids are minted in ascending
                # order past every existing surrogate, so the hot loop
                # extends the table's capacity buffer instead of paying
                # an O(M) copy per small batch
                new_tables[cid] = table.with_rows(
                    new_ids, fresh_rows, next_ordinal)
            n_new_sg += len(fresh)
            n_reused += int(counts.shape[0]) - len(fresh)
            n_absorbed += int(r_ents.shape[0])
            per_class[int(cid)] = {
                "absorbed": int(r_ents.shape[0]),
                "new_surrogates": len(fresh),
                "reused": int(counts.shape[0]) - len(fresh)}
            # rewrite only the absorbed entities' own rows; the rest of
            # the (possibly huge) factorized graph passes through as a
            # presorted slice and the rewritten rows merge back in.  The
            # live index follows the same remove-then-merge path (a row
            # subset of a sorted index stays sorted), so no class of this
            # loop ever triggers a full O(|G| log |G|) re-index.
            spo = combined.spo
            touched = in_sorted(spo[:, 0], r_ents)
            rewritten = apply_molecule_map(
                spo[touched], r_ents, sg_of_group[inv].astype(np.int32),
                props_arr, cid, combined.TYPE, combined.INSTANCE_OF)
            idx = combined.index
            kept_index = idx.filtered(~in_sorted(idx.rows[:, 0], r_ents))
            combined = TripleStore.from_ids(combined.dict, spo[~touched],
                                            presorted=True)
            combined.add_ids(rewritten)
            combined._index = kept_index.merged(rewritten)
        # classes touched by the batch (for drift tracking): any class a
        # type row landed in, plus every class that absorbed something
        touched_cids = set(per_class)
        if rows.shape[0]:
            type_rows = rows[rows[:, 1] == g.TYPE, 2]
            touched_cids.update(int(c) for c in np.unique(type_rows)
                                if not fg.is_surrogate(
                                    np.asarray([c]))[0])
        new_fg = FactorizedGraph(
            combined, new_tables,
            payoff_min_support=fg.payoff_min_support)
        report = UpdateReport(
            graph=combined, n_new_triples=int(rows.shape[0]),
            n_entities_absorbed=n_absorbed, n_new_surrogates=n_new_sg,
            n_surrogates_reused=n_reused,
            exec_time_ms=(time.perf_counter() - t0) * 1e3,
            per_class=per_class,
            touched_classes=tuple(sorted(touched_cids)))
        return snapshot.next(new_fg), report

    # -- deletes -----------------------------------------------------------
    def apply_delete(self, snapshot: GraphSnapshot, triples=None,
                     entities=None) -> tuple[GraphSnapshot, DeleteReport]:
        """Remove semantic triples and/or entities into a new snapshot.

        ``triples``: an (n, 3) id array or an iterable of term triples;
        ``entities``: an id array or an iterable of entity terms.  Both
        route through :class:`FactorizedGraph` delete support --
        molecule-covered triples dissolve memberships, and molecules
        whose support drops below payoff decompact in place.
        """
        fg = snapshot.fgraph
        t0 = time.perf_counter()
        stats = DeleteStats()
        if triples is not None:
            if isinstance(triples, np.ndarray):
                rows = np.asarray(triples, np.int32).reshape(-1, 3)
            else:
                # lookup, never id(): a term the graph has never seen
                # cannot name an existing triple, and a no-op delete must
                # not grow the shared dictionary as a side effect
                d = fg.store.dict
                rows_list = []
                n_unknown = 0
                for s, p, o in triples:
                    ids3 = (d.lookup(s), d.lookup(p), d.lookup(o))
                    if None in ids3:
                        n_unknown += 1
                        continue
                    rows_list.append(ids3)
                stats.n_requested += n_unknown     # counted, trivially absent
                rows = np.asarray(rows_list, np.int32).reshape(-1, 3)
            fg, st = fg.delete_triples(rows)
            _merge_delete_stats(stats, st)
        if entities is not None:
            if isinstance(entities, np.ndarray):
                ids = np.asarray(entities, np.int64).reshape(-1)
            else:
                d = fg.store.dict
                looked = [d.lookup(e) for e in entities]
                stats.n_requested += sum(1 for x in looked if x is None)
                ids = np.asarray([x for x in looked if x is not None],
                                 np.int64)
            fg, st = fg.delete_entities(ids)
            _merge_delete_stats(stats, st)
        report = DeleteReport(graph=fg.store, stats=stats,
                              exec_time_ms=(time.perf_counter() - t0) * 1e3)
        return snapshot.next(fg), report

    # -- targeted re-detection ---------------------------------------------
    def redetect(self, snapshot: GraphSnapshot | None,
                 class_ids: Iterable[int], *,
                 sharded_graph=None
                 ) -> tuple[GraphSnapshot, RedetectReport] | tuple:
        """Re-detect and re-factorize ONLY the given (drifted) classes.

        With ``sharded_graph=`` the pass runs shard-local (``snapshot``
        is ignored): every shard holding a dirty class builds its own
        successor through a per-shard-prefixed planner, and the whole
        snapshot tuple swaps atomically ONCE at the end -- a reader
        holding the old tuple keeps a consistent world view, exactly
        the replicated epoch discipline.  Returns ``(sharded_graph,
        {shard_id: RedetectReport})``.

        The dirty classes are decompacted in place (their members take
        their arms back as raw triples; every clean class's surrogate
        triples and molecule table survive untouched), the detector runs
        per dirty class through the candidate-batched sweep engine, and
        classes whose predicted savings still clear the planner threshold
        re-factorize.  A class whose payoff evaporated stays raw -- the
        paper's Fig. 7 overhead case handled *live*.  Sweep work is
        proportional to the dirty-class set: ``EXEC_STATS`` descent and
        lowering deltas are recorded on the report so callers (and the
        bench gates) can assert it.

        The pass is guarded on REALIZED edges: predicted Def. 4.8
        savings are computed on the candidate population (complete
        functional molecules, §4.3), so a re-plan can look profitable
        yet cost more actual triples once incomplete entities fall back
        to raw form.  If the rebuilt graph carries more triples than the
        current one, the pass is rejected -- the old snapshot stays live
        (``report.rejected``) and the service re-baselines, so an online
        re-detection can only ever improve or hold the realized edge
        count, never regress it.
        """
        if sharded_graph is not None:
            cids = sorted({int(c) for c in class_ids})
            snaps = list(sharded_graph.snapshots)
            reports = {}
            for sid, snap in enumerate(snaps):
                local = [c for c in cids
                         if sid in sharded_graph.plan.shards_for_class(c)
                         or c in snap.fgraph.tables]
                if not local:
                    continue
                new_snap, rep = self._shard_planner(sid).redetect(
                    snap, local)
                snaps[sid] = new_snap
                reports[sid] = rep
            sharded_graph.swap(snaps)     # one atomic tuple store
            return sharded_graph, reports
        t0 = time.perf_counter()
        fg = snapshot.fgraph
        cids = sorted({int(c) for c in class_ids})
        exec_before = dict(core_sweep.EXEC_STATS)
        base = fg.decompact_classes(cids)
        plan = self.plan(base.store, classes=cids)
        pairs = [(e.class_id, e.props) for e in plan]
        graph, results = factorize_classes(
            base.store, pairs, surrogate_prefix=self.surrogate_prefix)
        tables = dict(base.tables)
        for res in results:
            tables[int(res.class_id)] = MoleculeTable(
                class_id=int(res.class_id),
                props=tuple(sorted(int(p) for p in res.props)),
                surrogates=res.surrogates, objects=res.star_objects,
                next_ordinal=int(res.surrogates.shape[0]))
        new_fg = FactorizedGraph(graph, tables,
                                 payoff_min_support=fg.payoff_min_support)
        rejected = new_fg.n_triples > fg.n_triples
        new_snap = snapshot if rejected else snapshot.next(new_fg)
        report = RedetectReport(
            considered=tuple(cids),
            refactorized=() if rejected
            else tuple(int(e.class_id) for e in plan),
            plan=plan,
            exec_time_ms=(time.perf_counter() - t0) * 1e3,
            epoch=new_snap.epoch,
            descents=core_sweep.EXEC_STATS["descents"]
            - exec_before["descents"],
            lowerings=core_sweep.EXEC_STATS["lowerings"]
            - exec_before["lowerings"],
            per_class_savings={int(e.class_id): int(e.predicted_savings)
                               for e in plan
                               if e.predicted_savings is not None},
            rejected=rejected,
            edges_before=fg.n_triples,
            edges_after=new_snap.fgraph.n_triples)
        return new_snap, report


__all__ = ["ClassPlan", "CompactionPlan", "CompactionReport",
           "UpdateReport", "DeleteReport", "RedetectReport",
           "GraphSnapshot", "CompactionPlanner"]

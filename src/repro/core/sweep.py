"""Shape-bucketed sweep workspaces: the greedy descent's hot loop.

The seed executed every G.FSP descent step by re-extracting the class's
object matrix from the store (full-graph ``np.isin`` scans per candidate)
and -- on the jax backends -- re-tracing the drop-one sweep at a fresh
``(n, k)`` shape for every (class, candidate-size) pair.  That made the
"accelerated" paths ~2 orders of magnitude slower than the numpy loop
(BENCH_fsp.json: 3089 ms device vs 32 ms host detect).

A :class:`SweepWorkspace` fixes both costs structurally:

* **one extraction per class**: the object matrix over the *full*
  property set S is pulled through the ``GraphIndex`` joins once, at
  descent start.  Every candidate evaluation -- on every backend,
  including host -- is a column view of that parent matrix; the store is
  never touched again.  (Consequence: all backends share the same
  §4.3-(a) entity universe -- entities complete over S -- which the seed's
  host loop re-decided per subset while the device path did not.)
* **one upload per class**: the device workspaces ship the matrix to
  device once; descent steps drop columns *on device* by masking them to
  a constant, so child matrices never round-trip through the host.
* **one compile per bucket shape**: ``(n, k)`` is padded up to a
  power-of-two bucket (rows carry a validity mask, columns a drop mask),
  so the jitted sweep traces once per bucket and is cache-hit for every
  subsequent class, descent level, and ``Compactor`` instance.  Masking a
  column to zero is AMI-exact: the column contributes the same constant
  to every row's signature, so the distinct-row count equals the count
  over the surviving columns.

``TRACE_COUNTS`` records one entry per traced bucket shape -- the
benchmark snapshot and the regression tests assert the trace count stays
bounded by the number of distinct buckets, not the number of sweeps.
"""
from __future__ import annotations

import functools
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from .star import StarSweepResult, ami, num_edges
from .triples import TripleStore

# -- bucket ladder -----------------------------------------------------------

BUCKET_MIN_ROWS = 64    # floor: tiny classes share one compiled shape
BUCKET_MIN_COLS = 2     # star patterns need >= 2 properties


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def bucket_rows(n: int, multiple: int = 1) -> int:
    """Row bucket: next power of two >= max(n, floor), rounded up to the
    sharding ``multiple`` (DP degree) so shards stay equal-sized."""
    nb = max(_next_pow2(n), BUCKET_MIN_ROWS)
    if multiple > 1:
        nb += (-nb) % multiple
    return nb


def bucket_cols(k: int) -> int:
    return max(_next_pow2(k), BUCKET_MIN_COLS)


# -- jit trace accounting ----------------------------------------------------

TRACE_COUNTS: dict[tuple, int] = {}


def _note_trace(kind: str, shape: tuple) -> None:
    # executed at trace time only: jit cache hits never reach the body
    key = (kind,) + tuple(int(x) for x in shape)
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1


def reset_trace_stats() -> None:
    TRACE_COUNTS.clear()


def clear_compile_cache() -> None:
    """Drop the compiled sweep functions AND the trace counters -- gives
    tests a deterministic cold start regardless of process history."""
    _bucket_sweep_fn.cache_clear()
    _sharded_ami_fn.cache_clear()
    TRACE_COUNTS.clear()


def trace_count() -> int:
    """Total sweep traces since the last reset (cache misses only)."""
    return sum(TRACE_COUNTS.values())


def distinct_bucket_shapes() -> int:
    return len(TRACE_COUNTS)


# -- the compiled bucket sweep ----------------------------------------------

@functools.lru_cache(maxsize=None)
def _jax():
    import jax
    import jax.numpy as jnp
    return jax, jnp


@functools.lru_cache(maxsize=None)
def _bucket_sweep_fn(use_kernel: bool):
    """Build (once) the jitted drop-one sweep over a padded bucket.

    All data-dependent quantities -- ``am``, the child cardinality, the
    total property count -- enter as traced scalars, so the jit cache is
    keyed ONLY by the bucket shape ``(n_b, k_b)``.
    """
    jax, jnp = _jax()
    from .star import ami_device

    def sweep(objmat, valid, col_masks, am, n_sp_child, n_s):
        _note_trace("sweep", objmat.shape + (col_masks.shape[0],))

        def one(mask):
            return ami_device(objmat * mask[None, :], valid=valid,
                              use_kernel=use_kernel)

        amis = jax.vmap(one)(col_masks)
        edges = amis * (n_sp_child + 1) + am * (n_s - n_sp_child)
        return edges, amis

    return jax.jit(sweep)


@functools.lru_cache(maxsize=None)
def _sharded_ami_fn(mesh, dp_axes: tuple, use_kernel: bool):
    """Jitted masked-candidate AMI through the explicit hash-bucket
    collective schedule (``core.distributed.ami_bucketed``): the only
    distinct-count lowering that is exact on real multi-axis meshes."""
    jax, jnp = _jax()
    from .distributed import ami_bucketed

    def one(objmat, valid, col_mask):
        _note_trace("sharded", objmat.shape)
        return ami_bucketed(objmat * col_mask[None, :], valid, mesh,
                            dp_axes=dp_axes, use_kernel=use_kernel)

    return jax.jit(one)


# -- selection rule ----------------------------------------------------------

def pick_child(current: StarSweepResult, edges: np.ndarray,
               amis: np.ndarray, n_s: int, am: int
               ) -> tuple[StarSweepResult, int]:
    """Shared selection rule: first AMI == 1 candidate (paper Alg. 2
    lines 14-18), else minimum #Edges, first index breaking ties.
    Returns the child result and the dropped position ``j``."""
    single = np.where(amis == 1)[0]
    j = int(single[0]) if single.size else int(np.argmin(edges))
    child_props = tuple(p for i, p in enumerate(current.props) if i != j)
    child = StarSweepResult(props=child_props, ami=int(amis[j]), am=am,
                            n_total_props=n_s, edges=int(edges[j]))
    return child, j


# -- workspaces --------------------------------------------------------------

@runtime_checkable
class SweepWorkspace(Protocol):
    """Per-(class, descent) state: extract once, sweep many.

    ``props`` is the *current* property subset (shrinks as the descent
    drops columns); ``sweep()`` returns ``(edges, amis)`` aligned with it
    (entry ``j`` = subset with ``props[j]`` removed); ``descend(j)``
    commits the drop.
    """

    n_s: int
    am: int

    @property
    def props(self) -> tuple[int, ...]: ...

    def evaluate_current(self) -> StarSweepResult: ...

    def sweep(self) -> tuple[np.ndarray, np.ndarray]: ...

    def descend(self, j: int) -> None: ...


class _WorkspaceBase:
    """Shared extraction + bookkeeping: one index-join per descent."""

    def __init__(self, store: TripleStore, class_id: int,
                 props: Sequence[int], n_s: int, am: int) -> None:
        self.class_id = int(class_id)
        self.n_s = int(n_s)
        self.am = int(am)
        self._all_props = tuple(int(p) for p in props)
        self.entities, self.matrix = store.object_matrix(
            class_id, self._all_props)
        self._active = list(range(len(self._all_props)))

    @property
    def props(self) -> tuple[int, ...]:
        return tuple(self._all_props[i] for i in self._active)

    @property
    def k(self) -> int:
        return len(self._active)

    def evaluate_current(self) -> StarSweepResult:
        # exact host arithmetic over the already-extracted parent matrix
        a = ami(self.matrix[:, self._active]) if self._active else 0
        return StarSweepResult(
            props=self.props, ami=a, am=self.am, n_total_props=self.n_s,
            edges=num_edges(a, self.am, self.k, self.n_s))

    def descend(self, j: int) -> None:
        # pure bookkeeping: device buffers are untouched (the dropped
        # column is simply masked out of every subsequent sweep)
        del self._active[j]


class HostSweepWorkspace(_WorkspaceBase):
    """Sequential numpy sweep over column views of the parent matrix."""

    def sweep(self) -> tuple[np.ndarray, np.ndarray]:
        k = self.k
        edges = np.empty((k,), np.int64)
        amis = np.empty((k,), np.int64)
        for j in range(k):
            cols = self._active[:j] + self._active[j + 1:]
            a = ami(self.matrix[:, cols])
            amis[j] = a
            edges[j] = num_edges(a, self.am, k - 1, self.n_s)
        return edges, amis


class DeviceSweepWorkspace(_WorkspaceBase):
    """Batched jax sweep over a bucket-padded on-device parent buffer.

    Upload happens once, in the constructor; each ``sweep()`` ships only
    a ``(k_b, k_b)`` drop-mask stack.  Already-descended columns stay in
    the buffer, permanently masked -- dropping a column is a host-side
    bookkeeping update, not a transfer.
    """

    def __init__(self, store, class_id, props, n_s, am, *,
                 use_kernel: bool = True) -> None:
        super().__init__(store, class_id, props, n_s, am)
        self.use_kernel = bool(use_kernel)
        self._dev = None            # uploaded lazily, on the first sweep
        self._valid = None

    def _placement(self, n_rows: int):
        """(row_multiple, (matrix, mask) shardings | None) -- overridden
        by the mesh-sharded workspace."""
        return 1, None

    def _ensure_uploaded(self) -> None:
        """Bucket-pad and ship the parent matrix to device ONCE, on first
        use: classes whose descent never sweeps (|SP| <= 2, or a single
        pattern at full S) stay entirely on host."""
        if self._dev is not None:
            return
        jax, jnp = _jax()
        n, k = self.matrix.shape
        row_multiple, sharding = self._placement(n)
        self.n_bucket = bucket_rows(n, row_multiple)
        self.k_bucket = bucket_cols(k)
        buf = np.zeros((self.n_bucket, self.k_bucket), np.int32)
        buf[:n, :k] = self.matrix
        valid = np.arange(self.n_bucket) < n
        if sharding is not None:
            self._dev = jax.device_put(buf, sharding[0])
            self._valid = jax.device_put(valid, sharding[1])
        else:
            self._dev = jnp.asarray(buf)
            self._valid = jnp.asarray(valid)

    def _col_masks(self) -> np.ndarray:
        """(k_b, k_b) int32: row j = active columns with column j dropped.

        The stack always spans the FULL bucket width -- rows for inactive
        or padding columns are no-op candidates (mask == current active
        set) whose results the host discards -- so the compiled sweep
        shape is invariant across descent levels: one trace per bucket,
        not per (bucket, |SP|) pair.
        """
        base = np.zeros((self.k_bucket,), np.int32)
        base[self._active] = 1
        masks = np.repeat(base[None, :], self.k_bucket, axis=0)
        np.fill_diagonal(masks, 0)
        return masks

    def sweep(self) -> tuple[np.ndarray, np.ndarray]:
        _, jnp = _jax()
        self._ensure_uploaded()
        edges, amis = _bucket_sweep_fn(self.use_kernel)(
            self._dev, self._valid, jnp.asarray(self._col_masks()),
            self.am, self.k - 1, self.n_s)
        act = np.asarray(self._active)
        return np.asarray(edges)[act].astype(np.int64), \
            np.asarray(amis)[act].astype(np.int64)


class ShardedSweepWorkspace(DeviceSweepWorkspace):
    """Device workspace with rows sharded over the mesh's DP axes.

    With ``mesh=None`` this *is* the single-device bucketed sweep (same
    jit cache, same bucket ladder).  On a real mesh each candidate's AMI
    runs through the explicit ``ami_bucketed`` collective schedule; the
    column-drop multiply happens under GSPMD with row sharding preserved,
    so the buffer still uploads exactly once per descent.
    """

    def __init__(self, store, class_id, props, n_s, am, *, mesh=None,
                 plan=None, use_kernel: bool = True) -> None:
        self.mesh = mesh
        self.plan = plan
        self.dp_axes: tuple = ()
        super().__init__(store, class_id, props, n_s, am,
                         use_kernel=use_kernel)

    def _placement(self, n_rows: int):
        if self.mesh is None:
            return 1, None
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist.sharding import batch_axes_for
        if self.plan is not None:
            # prefer the planner's rung for this (padded) row count
            axes = tuple(batch_axes_for(self.plan, bucket_rows(n_rows))
                         or self.plan.dp_axes)
        else:
            axes = tuple(a for a in self.mesh.axis_names if a != "model")
        self.dp_axes = axes
        row_multiple = int(np.prod(
            [s for a, s in zip(self.mesh.axis_names,
                               self.mesh.devices.shape) if a in axes],
            initial=1))
        return row_multiple, (NamedSharding(self.mesh, P(axes, None)),
                              NamedSharding(self.mesh, P(axes)))

    def sweep(self) -> tuple[np.ndarray, np.ndarray]:
        if self.mesh is None:
            return super().sweep()
        _, jnp = _jax()
        self._ensure_uploaded()      # also resolves dp_axes placement
        fn = _sharded_ami_fn(self.mesh, self.dp_axes, self.use_kernel)
        masks = self._col_masks()
        k = self.k
        amis = np.empty((k,), np.int64)
        for j, col in enumerate(self._active):
            amis[j] = int(fn(self._dev, self._valid,
                             jnp.asarray(masks[col])))
        edges = np.asarray([num_edges(int(a), self.am, k - 1, self.n_s)
                            for a in amis], np.int64)
        return edges, amis

"""Shape-bucketed, candidate-batched sweep workspaces: the detectors'
hot loop.

The seed executed every G.FSP descent step by re-extracting the class's
object matrix from the store (full-graph ``np.isin`` scans per candidate)
and -- on the jax backends -- re-tracing the drop-one sweep at a fresh
``(n, k)`` shape for every (class, candidate-size) pair.  That made the
"accelerated" paths ~2 orders of magnitude slower than the numpy loop
(BENCH_fsp.json: 3089 ms device vs 32 ms host detect).

A :class:`SweepWorkspace` fixes both costs structurally:

* **one extraction per class**: the object matrix over the *full*
  property set S is pulled through the ``GraphIndex`` joins once, at
  descent start.  Every candidate evaluation -- on every backend,
  including host -- is a column view of that parent matrix; the store is
  never touched again.  (Consequence: all backends share the same
  §4.3-(a) entity universe -- entities complete over S -- which the seed's
  host loop re-decided per subset while the device path did not.)
* **one upload per class**: the device workspaces ship the matrix to
  device once; descent steps drop columns *on device* by masking them to
  a constant, so child matrices never round-trip through the host.
* **one lowering per candidate batch**: ``sweep_candidates`` evaluates an
  ARBITRARY stack of C column-mask candidates in a single jitted call --
  the drop-one sweep is the C = |SP| special case, and E.FSP's
  breadth-first lattice scan feeds each whole subset level through it.
  On the sharded workspace the candidate axis rides one ``shard_map``
  lowering (``distributed.ami_bucketed_batch``) instead of one collective
  schedule per candidate.
* **one compile per bucket shape**: ``(n, k, c)`` pads up to a
  power-of-two bucket (rows carry a validity mask, columns a zero mask,
  padding candidates are all-zero no-ops), so the jitted sweep traces
  once per ``(n_b, k_b, c_b)`` bucket and is cache-hit for every
  subsequent class, descent level, lattice level, and ``Compactor``
  instance.  Masking a column to zero is AMI-exact: the column
  contributes the same constant to every row's signature, so the
  distinct-row count equals the count over the surviving columns.

``TRACE_COUNTS`` records one entry per traced bucket shape, and
``EXEC_STATS`` counts executed lowerings vs logical descents (one
``sweep``/``sweep_candidates`` call = one descent) -- the benchmark
snapshot asserts one lowering per warm descent on the batched paths.
"""
from __future__ import annotations

import functools
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from .star import StarSweepResult, ami, num_edges, num_edges_batch
from .triples import TripleStore

# -- bucket ladder -----------------------------------------------------------

BUCKET_MIN_ROWS = 64    # floor: tiny classes share one compiled shape
BUCKET_MIN_COLS = 2     # star patterns need >= 2 properties
BUCKET_MIN_CANDS = 2    # candidate-axis floor (mirrors the column floor)

# one lowering evaluates at most this many candidates; larger stacks are
# chunked so the masked (c_b, n_b, k_b) intermediate stays VMEM/HBM-sane
MAX_SWEEP_CANDIDATES = 256


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def bucket_rows(n: int, multiple: int = 1) -> int:
    """Row bucket: next power of two >= max(n, floor), rounded up to the
    sharding ``multiple`` (DP degree) so shards stay equal-sized."""
    nb = max(_next_pow2(n), BUCKET_MIN_ROWS)
    if multiple > 1:
        nb += (-nb) % multiple
    return nb


def bucket_cols(k: int) -> int:
    return max(_next_pow2(k), BUCKET_MIN_COLS)


def bucket_candidates(c: int) -> int:
    """Candidate-axis bucket: next power of two, floored at 2, capped by
    chunking at ``MAX_SWEEP_CANDIDATES`` (callers slice larger stacks)."""
    return max(_next_pow2(min(c, MAX_SWEEP_CANDIDATES)), BUCKET_MIN_CANDS)


# -- jit trace / execution accounting ----------------------------------------

TRACE_COUNTS: dict[tuple, int] = {}

# executed-lowering accounting (every invocation, cache hits included):
# ``descents`` counts logical sweep calls, ``lowerings`` compiled-sweep
# dispatches -- the batched engine keeps their ratio at 1 for any
# candidate stack that fits one chunk
EXEC_STATS = {"lowerings": 0, "descents": 0}


def _note_trace(kind: str, shape: tuple) -> None:
    # executed at trace time only: jit cache hits never reach the body
    key = (kind,) + tuple(int(x) for x in shape)
    TRACE_COUNTS[key] = TRACE_COUNTS.get(key, 0) + 1


# auxiliary per-module counter resets (e.g. repro.query.batch's
# QUERY_EXEC) hook in here so one reset_trace_stats() call clears EVERY
# accounting surface -- a bench cell can never bleed counters into the
# next because a caller forgot a module-specific reset
_EXTRA_STAT_RESETS: list = []


def register_stats_reset(fn) -> None:
    """Register an extra zero-the-counters callback invoked by
    :func:`reset_trace_stats` (idempotent per function)."""
    if fn not in _EXTRA_STAT_RESETS:
        _EXTRA_STAT_RESETS.append(fn)


def reset_trace_stats() -> None:
    TRACE_COUNTS.clear()
    EXEC_STATS["lowerings"] = 0
    EXEC_STATS["descents"] = 0
    for fn in _EXTRA_STAT_RESETS:
        fn()


def clear_compile_cache() -> None:
    """Drop the compiled sweep functions AND the trace counters -- gives
    tests a deterministic cold start regardless of process history."""
    _bucket_sweep_fn.cache_clear()
    _sharded_sweep_fn.cache_clear()
    reset_trace_stats()


def trace_count() -> int:
    """Total sweep traces since the last reset (cache misses only)."""
    return sum(TRACE_COUNTS.values())


def distinct_bucket_shapes() -> int:
    return len(TRACE_COUNTS)


def lowerings_per_descent() -> float:
    """Executed compiled-sweep calls per logical sweep since the last
    reset (0.0 on the host path, which lowers nothing)."""
    d = EXEC_STATS["descents"]
    return EXEC_STATS["lowerings"] / d if d else 0.0


# -- the compiled bucket sweep ----------------------------------------------

@functools.lru_cache(maxsize=None)
def _jax():
    import jax
    import jax.numpy as jnp
    return jax, jnp


@functools.lru_cache(maxsize=None)
def _bucket_sweep_fn(use_kernel: bool):
    """Build (once) the jitted candidate-batch sweep over a padded bucket.

    All data-dependent quantities -- ``am``, the per-candidate subset
    sizes, the total property count -- enter as traced values, so the jit
    cache is keyed ONLY by the bucket shape ``(n_b, k_b, c_b)``.
    """
    jax, jnp = _jax()
    from .star import ami_device_batch

    def sweep(objmat, valid, col_masks, am, n_sp, n_s):
        _note_trace("sweep", objmat.shape + (col_masks.shape[0],))
        masked = objmat[None, :, :] * col_masks[:, None, :]  # (c, n, k)
        amis = ami_device_batch(masked, valid=valid, use_kernel=use_kernel)
        edges = amis * (n_sp + 1) + am * (n_s - n_sp)
        return edges, amis

    return jax.jit(sweep)


@functools.lru_cache(maxsize=None)
def _sharded_sweep_fn(mesh, dp_axes: tuple, use_kernel: bool):
    """Jitted candidate-batch AMI through the explicit hash-bucket
    collective schedule (``core.distributed.ami_bucketed_batch``): the
    only distinct-count lowering that is exact on real multi-axis meshes,
    now carrying the whole candidate stack through ONE all_to_all."""
    jax, jnp = _jax()
    from .distributed import ami_bucketed_batch

    def batch(objmat, valid, col_masks):
        _note_trace("sharded", objmat.shape + (col_masks.shape[0],))
        return ami_bucketed_batch(objmat, valid, col_masks, mesh,
                                  dp_axes=dp_axes, use_kernel=use_kernel)

    return jax.jit(batch)


# -- selection rule ----------------------------------------------------------

def pick_child(current: StarSweepResult, edges: np.ndarray,
               amis: np.ndarray, n_s: int, am: int
               ) -> tuple[StarSweepResult, int]:
    """Shared selection rule: first AMI == 1 candidate (paper Alg. 2
    lines 14-18), else minimum #Edges, first index breaking ties.
    Returns the child result and the dropped position ``j``."""
    single = np.where(amis == 1)[0]
    j = int(single[0]) if single.size else int(np.argmin(edges))
    child_props = tuple(p for i, p in enumerate(current.props) if i != j)
    child = StarSweepResult(props=child_props, ami=int(amis[j]), am=am,
                            n_total_props=n_s, edges=int(edges[j]))
    return child, j


# -- workspaces --------------------------------------------------------------

@runtime_checkable
class SweepWorkspace(Protocol):
    """Per-(class, descent) state: extract once, sweep many.

    ``props`` is the *current* property subset (shrinks as the descent
    drops columns); ``sweep()`` returns ``(edges, amis)`` aligned with it
    (entry ``j`` = subset with ``props[j]`` removed); ``descend(j)``
    commits the drop.  ``sweep_candidates(col_masks)`` evaluates an
    arbitrary ``(C, |S|)`` 0/1 stack of column selections over the FULL
    extracted property list -- E.FSP feeds whole lattice levels through
    it -- and returns ``(edges, amis)`` aligned with the stack.
    """

    n_s: int
    am: int

    @property
    def props(self) -> tuple[int, ...]: ...

    def evaluate_current(self) -> StarSweepResult: ...

    def sweep(self) -> tuple[np.ndarray, np.ndarray]: ...

    def sweep_candidates(self, col_masks) -> tuple[np.ndarray, np.ndarray]:
        ...

    def descend(self, j: int) -> None: ...


class _WorkspaceBase:
    """Shared extraction + bookkeeping: one index-join per descent."""

    def __init__(self, store: TripleStore, class_id: int,
                 props: Sequence[int], n_s: int, am: int) -> None:
        self.class_id = int(class_id)
        self.n_s = int(n_s)
        self.am = int(am)
        self._all_props = tuple(int(p) for p in props)
        self.entities, self.matrix = store.object_matrix(
            class_id, self._all_props)
        self._active = list(range(len(self._all_props)))

    @property
    def props(self) -> tuple[int, ...]:
        return tuple(self._all_props[i] for i in self._active)

    @property
    def k(self) -> int:
        return len(self._active)

    def evaluate_current(self) -> StarSweepResult:
        # exact host arithmetic over the already-extracted parent matrix
        a = ami(self.matrix[:, self._active]) if self._active else 0
        return StarSweepResult(
            props=self.props, ami=a, am=self.am, n_total_props=self.n_s,
            edges=num_edges(a, self.am, self.k, self.n_s))

    def descend(self, j: int) -> None:
        # pure bookkeeping: device buffers are untouched (the dropped
        # column is simply masked out of every subsequent sweep)
        del self._active[j]

    def _normalize_masks(self, col_masks) -> np.ndarray:
        masks = np.asarray(col_masks)
        if masks.ndim != 2 or masks.shape[1] != len(self._all_props):
            raise ValueError(
                f"col_masks must be (C, {len(self._all_props)}), "
                f"got {masks.shape}")
        # canonicalize to 0/1: the device paths MULTIPLY by the mask, so
        # any other truthy value would silently skew ids (and parity)
        return np.ascontiguousarray((masks != 0).astype(np.int32))

    def _drop_one_stack(self, n_rows: int) -> np.ndarray:
        """(n_rows, k_all) 0/1 drop-one stack: row j = active columns
        with column j dropped (a no-op candidate when j is inactive or
        beyond ``k_all`` -- callers discard those rows)."""
        k_all = len(self._all_props)
        base = np.zeros((k_all,), np.int32)
        base[self._active] = 1
        masks = np.repeat(base[None, :], n_rows, axis=0)
        idx = np.arange(min(n_rows, k_all))
        masks[idx, idx] = 0
        return masks


class HostSweepWorkspace(_WorkspaceBase):
    """Sequential numpy evaluation over column views of the parent matrix."""

    def sweep(self) -> tuple[np.ndarray, np.ndarray]:
        # no shape bucket to keep invariant on host: only the active
        # rows of the drop-one stack are evaluated
        masks = self._drop_one_stack(len(self._all_props))
        return self.sweep_candidates(masks[np.asarray(self._active)])

    def sweep_candidates(self, col_masks) -> tuple[np.ndarray, np.ndarray]:
        masks = self._normalize_masks(col_masks)
        EXEC_STATS["descents"] += 1
        n = self.matrix.shape[0]
        amis = np.empty((masks.shape[0],), np.int64)
        for i in range(masks.shape[0]):
            cols = np.flatnonzero(masks[i])
            # zero surviving columns: every row is the same empty tuple
            amis[i] = ami(self.matrix[:, cols]) if cols.size \
                else (1 if n else 0)
        n_sp = (masks != 0).sum(axis=1)
        edges = num_edges_batch(amis, self.am, n_sp, self.n_s)
        return edges, amis


class DeviceSweepWorkspace(_WorkspaceBase):
    """Batched jax sweep over a bucket-padded on-device parent buffer.

    Upload happens once, in the constructor; each candidate batch ships
    only a ``(c_b, k_b)`` mask stack.  Already-descended columns stay in
    the buffer, permanently masked -- dropping a column is a host-side
    bookkeeping update, not a transfer.
    """

    def __init__(self, store, class_id, props, n_s, am, *,
                 use_kernel: bool = True) -> None:
        super().__init__(store, class_id, props, n_s, am)
        self.use_kernel = bool(use_kernel)
        self._dev = None            # uploaded lazily, on the first sweep
        self._valid = None

    def _placement(self, n_rows: int):
        """(row_multiple, (matrix, mask) shardings | None) -- overridden
        by the mesh-sharded workspace."""
        return 1, None

    def _ensure_uploaded(self) -> None:
        """Bucket-pad and ship the parent matrix to device ONCE, on first
        use: classes whose descent never sweeps (|SP| <= 2, or a single
        pattern at full S) stay entirely on host."""
        if self._dev is not None:
            return
        jax, jnp = _jax()
        n, k = self.matrix.shape
        row_multiple, sharding = self._placement(n)
        self.n_bucket = bucket_rows(n, row_multiple)
        self.k_bucket = bucket_cols(k)
        buf = np.zeros((self.n_bucket, self.k_bucket), np.int32)
        buf[:n, :k] = self.matrix
        valid = np.arange(self.n_bucket) < n
        if sharding is not None:
            self._dev = jax.device_put(buf, sharding[0])
            self._valid = jax.device_put(valid, sharding[1])
        else:
            self._dev = jnp.asarray(buf)
            self._valid = jnp.asarray(valid)

    def sweep(self) -> tuple[np.ndarray, np.ndarray]:
        # the drop-one stack spans FULL bucket height so the compiled
        # sweep shape is invariant across descent levels (one trace per
        # bucket, not per (bucket, |SP|) pair); no-op rows are discarded
        self._ensure_uploaded()
        edges, amis = self.sweep_candidates(
            self._drop_one_stack(self.k_bucket))
        act = np.asarray(self._active)
        return edges[act], amis[act]

    def _run_batch(self, stack: np.ndarray, n_sp: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """One lowering over a (c_b, k_b) padded stack."""
        _, jnp = _jax()
        EXEC_STATS["lowerings"] += 1
        edges, amis = _bucket_sweep_fn(self.use_kernel)(
            self._dev, self._valid, jnp.asarray(stack), self.am,
            jnp.asarray(n_sp), self.n_s)
        return np.asarray(edges), np.asarray(amis)

    def sweep_candidates(self, col_masks) -> tuple[np.ndarray, np.ndarray]:
        masks = self._normalize_masks(col_masks)
        EXEC_STATS["descents"] += 1
        self._ensure_uploaded()
        n_cand, k_all = masks.shape
        edges_out = np.empty((n_cand,), np.int64)
        amis_out = np.empty((n_cand,), np.int64)
        for lo in range(0, n_cand, MAX_SWEEP_CANDIDATES):
            chunk = masks[lo:lo + MAX_SWEEP_CANDIDATES]
            c_b = bucket_candidates(chunk.shape[0])
            stack = np.zeros((c_b, self.k_bucket), np.int32)
            stack[:chunk.shape[0], :k_all] = chunk
            n_sp = stack.sum(axis=1, dtype=np.int32)
            edges, amis = self._run_batch(stack, n_sp)
            m = chunk.shape[0]
            edges_out[lo:lo + m] = edges[:m].astype(np.int64)
            amis_out[lo:lo + m] = amis[:m].astype(np.int64)
        return edges_out, amis_out


class ShardedSweepWorkspace(DeviceSweepWorkspace):
    """Device workspace with rows sharded over the mesh's DP axes.

    With ``mesh=None`` this *is* the single-device bucketed sweep (same
    jit cache, same bucket ladder).  On a real mesh the WHOLE candidate
    stack runs through one ``ami_bucketed_batch`` collective schedule per
    chunk -- one shard_map lowering per descent, not one per candidate;
    the column-drop multiply happens inside the shard_map body with row
    sharding preserved, so the buffer still uploads exactly once per
    descent.
    """

    def __init__(self, store, class_id, props, n_s, am, *, mesh=None,
                 plan=None, use_kernel: bool = True) -> None:
        self.mesh = mesh
        self.plan = plan
        self.dp_axes: tuple = ()
        super().__init__(store, class_id, props, n_s, am,
                         use_kernel=use_kernel)

    def _placement(self, n_rows: int):
        if self.mesh is None:
            return 1, None
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist.sharding import batch_axes_for
        if self.plan is not None:
            # prefer the planner's rung for this (padded) row count
            axes = tuple(batch_axes_for(self.plan, bucket_rows(n_rows))
                         or self.plan.dp_axes)
        else:
            axes = tuple(a for a in self.mesh.axis_names if a != "model")
        self.dp_axes = axes
        row_multiple = int(np.prod(
            [s for a, s in zip(self.mesh.axis_names,
                               self.mesh.devices.shape) if a in axes],
            initial=1))
        return row_multiple, (NamedSharding(self.mesh, P(axes, None)),
                              NamedSharding(self.mesh, P(axes)))

    def _run_batch(self, stack: np.ndarray, n_sp: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        if self.mesh is None:
            return super()._run_batch(stack, n_sp)
        _, jnp = _jax()
        EXEC_STATS["lowerings"] += 1
        fn = _sharded_sweep_fn(self.mesh, self.dp_axes, self.use_kernel)
        amis = np.asarray(fn(self._dev, self._valid, jnp.asarray(stack)))
        edges = num_edges_batch(amis, self.am, n_sp, self.n_s)
        return edges, amis

"""Def. 4.11 -- the ``instanceOf`` axioms, expansion, and query rewriting.

Axiom 1:  (s instanceOf sg) & (sg type C)   =>  (s type C)
Axiom 2:  (s instanceOf sg) & (sg p o)      =>  (s p o)     [p != type]

These make factorization lossless: the original graph is contained in the
axiom closure of the factorized graph, *without* a decompression pass.  The
same axioms drive query rewriting: a star query over the original graph is
answered over G' by allowing each (p, o) condition to be satisfied either
directly or through one ``instanceOf`` hop -- no customized engine needed.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .triples import TripleStore


def expand(store: TripleStore) -> TripleStore:
    """Materialize the axiom closure of a (possibly factorized) graph.

    One pass suffices: surrogates are never themselves instances of other
    surrogates (Algorithm 3 mints fresh entities).
    """
    spo = store.spo
    inst = spo[spo[:, 1] == store.INSTANCE_OF]          # (s, instanceOf, sg)
    if not len(inst):
        return store.copy()
    # join: inst(s, sg) |x| spo(sg, p, o)
    sg_rows = spo[spo[:, 1] != store.INSTANCE_OF]
    order = np.argsort(sg_rows[:, 0], kind="stable")
    sg_rows = sg_rows[order]
    starts = np.searchsorted(sg_rows[:, 0], inst[:, 2], side="left")
    ends = np.searchsorted(sg_rows[:, 0], inst[:, 2], side="right")
    counts = ends - starts
    total = int(counts.sum())
    if total:
        # gather indices for each (s, sg) pair
        rep_s = np.repeat(inst[:, 0], counts)
        idx = np.concatenate([np.arange(a, b) for a, b in zip(starts, ends)
                              if b > a]) if total else np.empty(0, np.int64)
        joined = sg_rows[idx]
        derived = np.stack([rep_s, joined[:, 1], joined[:, 2]], axis=1)
    else:
        derived = np.empty((0, 3), np.int32)
    out = TripleStore.from_ids(store.dict,
                               np.concatenate([spo, derived], axis=0))
    return out


def semantic_triples(store: TripleStore) -> np.ndarray:
    """The graph's *entity-level* content: axiom closure restricted to
    non-surrogate structure (drop instanceOf edges and surrogate subjects).

    Two graphs are information-equivalent iff these sets match -- this is
    the losslessness criterion tested against Def. 4.10.
    """
    closed = expand(store)
    spo = closed.spo
    surr = np.unique(spo[spo[:, 1] == store.INSTANCE_OF, 2])
    keep = (spo[:, 1] != store.INSTANCE_OF) & ~np.isin(spo[:, 0], surr)
    return np.unique(spo[keep], axis=0)


def match_star(store: TripleStore, conditions: Sequence[tuple[int, int]],
               rewrite: bool = True) -> np.ndarray:
    """Entities matching a star query ``AND_k (?s p_k o_k)``.

    ``rewrite=False`` evaluates the query literally (what a stock engine
    does on the original graph).  ``rewrite=True`` applies the Def. 4.11
    rewriting: each condition may also be satisfied via
    ``(?s instanceOf ?g) AND (?g p_k o_k)`` -- correct on factorized graphs.
    """
    spo = store.spo
    inst = spo[spo[:, 1] == store.INSTANCE_OF]
    result: np.ndarray | None = None
    for (p, o) in conditions:
        rows = spo[(spo[:, 1] == p) & (spo[:, 2] == o)]
        subjects = rows[:, 0]
        if rewrite and len(inst):
            # surrogates satisfying the condition -> their instances
            via = inst[np.isin(inst[:, 2], subjects), 0]
            subjects = np.union1d(subjects, via)
        else:
            subjects = np.unique(subjects)
        result = subjects if result is None else np.intersect1d(result, subjects)
        if result.size == 0:
            break
    if result is None:
        return np.empty((0,), np.int32)
    # exclude surrogate entities themselves from answers (they are storage
    # artifacts, not domain entities)
    if len(inst):
        result = np.setdiff1d(result, np.unique(inst[:, 2]))
    return result

"""gSpan (Yan & Han 2002) -- DFS-code frequent subgraph mining.

The paper's baseline E.FSP "resorts to the gSpan enumeration of frequent
patterns"; we implement gSpan itself rather than stubbing it, for directed,
vertex- and edge-labeled graphs (RDF molecules are such graphs).

A pattern is a DFS code: a sequence of tuples

    (i, j, l_i, l_e, d, l_j)

with DFS discovery ids ``i, j``, vertex labels ``l_i, l_j``, edge label
``l_e`` and direction bit ``d`` (1 if the RDF edge points i->j, else 0).
Codes are compared lexicographically; a pattern is generated only from its
*minimal* DFS code (canonical form), which removes isomorphic duplicates.
Growth follows the rightmost-path extension rule: backward edges from the
rightmost vertex only, forward edges from rightmost-path vertices only.

Support = number of database graphs containing at least one embedding.

This implementation favors clarity over constant factors -- it is the
*intentionally expensive* baseline whose enumeration E.FSP consumes; the
paper's headline result is that G.FSP avoids this cost by >= 3 orders of
magnitude.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

# ---------------------------------------------------------------------------
# database graphs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DBGraph:
    """A small directed labeled graph (one per RDF molecule)."""

    vlabels: list[int]
    # adjacency: adj[u] = list of (v, elabel, direction) where direction=1
    # means the underlying edge is u->v, 0 means v->u.  Both endpoints carry
    # the entry so DFS can traverse edges in either direction.
    adj: list[list[tuple[int, int, int]]]
    edges: list[tuple[int, int, int]]  # (u, v, elabel) with u->v

    @classmethod
    def from_edges(cls, vlabels: Sequence[int],
                   edges: Iterable[tuple[int, int, int]]) -> "DBGraph":
        vlabels = list(vlabels)
        adj: list[list[tuple[int, int, int]]] = [[] for _ in vlabels]
        es = []
        for u, v, le in edges:
            adj[u].append((v, le, 1))
            adj[v].append((u, le, 0))
            es.append((u, v, le))
        return cls(vlabels, adj, es)


Code = tuple[tuple[int, int, int, int, int, int], ...]


def _tuple_key(t) -> tuple:
    """gSpan DFS-code linear order on extension tuples.

    NOT plain lexicographic: backward edges precede forward edges, and among
    forward edges a deeper origin (larger i) is smaller (DFS discipline).
    For e1=(i1,j1), e2=(i2,j2) (Yan & Han, DFS lexicographic order):
      * both forward:  e1 < e2 iff j1 < j2 or (j1 == j2 and i1 > i2)
      * both backward: e1 < e2 iff i1 < i2 or (i1 == i2 and j1 < j2)
      * backward (i1,_) < forward (_,j2) iff i1 < j2  (always true for
        same-prefix extensions, where j2 = rightmost+1 > i1)
    ties broken by labels (l_i, l_e, d, l_j).
    """
    i, j, li, le, d, lj = t
    if i < j:   # forward
        return (1, j, -i, li, le, d, lj)
    return (0, i, j, li, le, d, lj)      # backward


def _code_key(code) -> tuple:
    return tuple(_tuple_key(t) for t in code)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Embedding:
    gid: int
    vmap: tuple[int, ...]          # dfs id -> graph vertex
    used: frozenset[tuple[int, int, int]]  # used (u, v, elabel) graph edges


def _edge_key(u: int, v: int, le: int, d: int) -> tuple[int, int, int]:
    return (u, v, le) if d == 1 else (v, u, le)


def _rightmost_path(code: Code) -> list[int]:
    """DFS ids on the rightmost path, rightmost vertex first."""
    if not code:
        return []
    # forward edges only
    path = []
    rightmost = max(max(t[0], t[1]) for t in code)
    cur = rightmost
    path.append(cur)
    while cur != 0:
        for t in reversed(code):
            i, j = t[0], t[1]
            if j == cur and i < j:     # forward edge discovering cur
                cur = i
                path.append(cur)
                break
        else:  # pragma: no cover - malformed code
            break
    return path


def _code_graph(code: Code) -> DBGraph:
    """Materialize the pattern graph described by a DFS code."""
    n = 1 + max(max(t[0], t[1]) for t in code)
    vlabels = [-1] * n
    edges = []
    for (i, j, li, le, d, lj) in code:
        vlabels[i] = li
        vlabels[j] = lj
        if d == 1:
            edges.append((i, j, le))
        else:
            edges.append((j, i, le))
    return DBGraph.from_edges(vlabels, edges)


def _min_code(g: DBGraph) -> Code:
    """Minimal DFS code of a (small) pattern graph, by exhaustive DFS."""
    best: list[Code | None] = [None]
    n_edges = len(g.edges)

    def extend(code: list, vmap: dict, rev: dict, used: set) -> None:
        if best[0] is not None and _code_key(code) > _code_key(best[0])[:len(code)]:
            return
        if len(code) == n_edges:
            c = tuple(code)
            if best[0] is None or _code_key(c) < _code_key(best[0]):
                best[0] = c
            return
        # candidate extensions, gSpan order: backward from rightmost vertex
        # (smallest target id first), then forward from rightmost path
        # (deepest origin first, i.e. rightmost vertex outward).
        rm_path = _rightmost_path(tuple(code)) if code else []
        cands = []
        if code:
            rm = rm_path[0]
            u = vmap[rm]
            for (v, le, d) in g.adj[u]:
                k = _edge_key(u, v, le, d)
                if k in used or v not in rev:
                    continue
                j = rev[v]
                if j == rm:
                    continue
                # backward edge rm -> j (only to rightmost-path vertices)
                if j in rm_path:
                    cands.append((rm, j, g.vlabels[u], le, d, g.vlabels[v]))
            for origin in rm_path:
                u = vmap[origin]
                nxt = max(vmap.keys()) + 1
                for (v, le, d) in g.adj[u]:
                    k = _edge_key(u, v, le, d)
                    if k in used or v in rev:
                        continue
                    cands.append((origin, nxt, g.vlabels[u], le, d,
                                  g.vlabels[v], v))
        else:
            for (u, v, le) in g.edges:
                cands.append((0, 1, g.vlabels[u], le, 1, g.vlabels[v], v, u))
        if not cands:
            return
        cands.sort(key=lambda t: _tuple_key(t[:6]))
        best_tuple = cands[0][:6]
        for t in cands:
            if t[:6] != best_tuple:
                break  # only minimal extension is canonical
            if len(t) == 8:  # initial edge: t = (0,1,li,le,1,lj, v, u)
                u, v = t[7], t[6]
                code.append(t[:6])
                used.add(_edge_key(u, v, t[3], 1))
                extend(code, {0: u, 1: v}, {u: 0, v: 1}, used)
                used.discard(_edge_key(u, v, t[3], 1))
                code.pop()
            elif len(t) == 7:  # forward
                origin, nxt, li, le, d, lj, v = t
                u = vmap[origin]
                k = _edge_key(u, v, le, d)
                code.append(t[:6])
                vmap[nxt] = v
                rev[v] = nxt
                used.add(k)
                extend(code, vmap, rev, used)
                used.discard(k)
                del rev[v]
                del vmap[nxt]
                code.pop()
            else:  # backward
                i, j, li, le, d, lj = t
                u = vmap[i]
                v = vmap[j]
                k = _edge_key(u, v, le, d)
                code.append(t)
                used.add(k)
                extend(code, vmap, rev, used)
                used.discard(k)
                code.pop()

    extend([], {}, {}, set())
    assert best[0] is not None
    return best[0]


def is_min(code: Code) -> bool:
    return _min_code(_code_graph(code)) == code


# ---------------------------------------------------------------------------
# mining
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Pattern:
    code: Code
    support: int
    embeddings: list[Embedding]

    @property
    def n_edges(self) -> int:
        return len(self.code)


def mine(graphs: Sequence[DBGraph], min_support: int,
         max_edges: int | None = None) -> list[Pattern]:
    """Enumerate all frequent patterns (minimal DFS codes) in ``graphs``."""
    results: list[Pattern] = []

    # frequent initial edges
    initial: dict[tuple, list[Embedding]] = {}
    for gid, g in enumerate(graphs):
        for (u, v, le) in g.edges:
            t = (0, 1, g.vlabels[u], le, 1, g.vlabels[v])
            initial.setdefault(t, []).append(
                Embedding(gid, (u, v), frozenset([(u, v, le)])))

    def support_of(embs: list[Embedding]) -> int:
        return len({e.gid for e in embs})

    def grow(code: Code, embs: list[Embedding]) -> None:
        if not is_min(code):
            return
        results.append(Pattern(code, support_of(embs), embs))
        if max_edges is not None and len(code) >= max_edges:
            return
        rm_path = _rightmost_path(code)
        rm = rm_path[0]
        nxt = 1 + max(max(t[0], t[1]) for t in code)
        # gather candidate extensions over all embeddings
        ext: dict[tuple, list[Embedding]] = {}
        for emb in embs:
            g = graphs[emb.gid]
            # backward from rightmost vertex
            u = emb.vmap[rm]
            pos = {gv: i for i, gv in enumerate(emb.vmap)}
            for (v, le, d) in g.adj[u]:
                k = _edge_key(u, v, le, d)
                if k in emb.used:
                    continue
                j = pos.get(v)
                if j is not None and j in rm_path and j != rm:
                    t = (rm, j, g.vlabels[u], le, d, g.vlabels[v])
                    ext.setdefault(t, []).append(
                        Embedding(emb.gid, emb.vmap, emb.used | {k}))
            # forward from rightmost path
            for origin in rm_path:
                u = emb.vmap[origin]
                for (v, le, d) in g.adj[u]:
                    k = _edge_key(u, v, le, d)
                    if k in emb.used or v in pos:
                        continue
                    t = (origin, nxt, g.vlabels[u], le, d, g.vlabels[v])
                    ext.setdefault(t, []).append(
                        Embedding(emb.gid, emb.vmap + (v,), emb.used | {k}))
        for t in sorted(ext.keys(), key=_tuple_key):
            child_embs = ext[t]
            if support_of(child_embs) >= min_support:
                grow(code + (t,), child_embs)

    for t in sorted(initial.keys(), key=_tuple_key):
        embs = initial[t]
        if support_of(embs) >= min_support:
            grow((t,), embs)
    return results


# ---------------------------------------------------------------------------
# RDF molecules -> database graphs (for E.FSP)
# ---------------------------------------------------------------------------

def molecules_of_class(store, class_id: int):
    """One DBGraph per entity of C: a star of its (property, object) edges.

    Vertex 0 is the subject, labeled with the class id; object vertices are
    labeled with their object id (gSpan mines constant patterns -- paper §3.3:
    'only patterns with constants are considered').
    Returns (entities, graphs).
    """
    import numpy as np
    ents = store.entities_of_class(class_id)
    props = store.class_properties(class_id)
    sel = np.isin(store.spo[:, 0], ents) & np.isin(store.spo[:, 1], props)
    spo = store.spo[sel]
    order = np.argsort(spo[:, 0], kind="stable")
    spo = spo[order]
    graphs = []
    bounds = np.searchsorted(spo[:, 0], ents)
    bounds = np.concatenate([bounds, [spo.shape[0]]])
    for i in range(ents.shape[0]):
        rows = spo[bounds[i]:bounds[i + 1]]
        vlabels = [int(class_id)] + [int(o) for o in rows[:, 2]]
        edges = [(0, 1 + k, int(p)) for k, p in enumerate(rows[:, 1])]
        graphs.append(DBGraph.from_edges(vlabels, edges))
    return ents, graphs

"""First-class factorized RDF graph: G' as a queryable structure.

``Compactor`` used to keep the factorized state as private dicts (the
per-class tuple -> surrogate signature maps) next to a plain
``TripleStore`` -- enough to *measure* size, but the paper's point is
that frequent star patterns hurt both size AND query processing, and a
bag of dicts cannot answer a query.  ``FactorizedGraph`` promotes G' to
a representation with three aligned parts:

* ``store``  -- the factorized triples themselves (a ``TripleStore``:
  residual raw triples, surrogate molecule triples ``(sg p_j o_j)`` /
  ``(sg type C)``, and the ``(s instanceOf sg)`` links);
* ``tables`` -- one :class:`MoleculeTable` per factorized class: the
  surrogate column aligned with an ``(M, K)`` object matrix over the
  class's SP (Def. 4.9's compact molecules in dense form) -- this is
  what star queries match against *without expanding*;
* an ``instanceOf`` CSR -- surrogate -> member entities, rebuilt from
  the store's instanceOf partition, so one matched molecule emits all
  of its entities in a single gather.

The structure is **lossless** (Def. 4.10/4.11): :meth:`expand`
re-materializes the original graph exactly, and Def. 4.8 ``#Edges``
accounting is reproducible from the tables alone
(:meth:`def48_edges`).  It also supports **deletes** -- the one
mutation factorization makes non-trivial: removing a triple covered by
a molecule makes its entity *exit* the molecule (the entity's surviving
arms re-materialize as raw triples), and any molecule whose support
drops below the payoff threshold (``k(m-1) > 1``, i.e. support >= 2)
decompacts in place.  Delete methods are pure: they return a new
``FactorizedGraph`` so ``repro.api.Compactor`` can commit
transactionally.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from .index import SPO_PERM, _key_view, csr_take, in_sorted, sort_unique
from .star import num_edges
from .triples import TripleStore


@dataclasses.dataclass
class MoleculeTable:
    """Per-class molecule table: surrogate -> (SP, object tuple) rows.

    ``surrogates`` is kept ascending with ``objects`` rows aligned; the
    object rows are ordered over the (sorted) ``props``.  ``sig`` maps
    object tuples back to surrogates -- the incremental-update index
    that used to live privately inside ``Compactor``.
    """

    class_id: int
    props: tuple[int, ...]
    surrogates: np.ndarray            # (M,) int32, ascending
    objects: np.ndarray               # (M, K) int32, rows over sorted props
    next_ordinal: int
    # construction fast path: the arrays are already ascending-by-
    # surrogate (amortized append below) -- skip the O(M log M) argsort
    presorted: dataclasses.InitVar[bool] = False

    def __post_init__(self, presorted: bool = False) -> None:
        self.props = tuple(int(p) for p in self.props)
        self.surrogates = np.asarray(self.surrogates, np.int32).reshape(-1)
        self.objects = np.asarray(self.objects, np.int32).reshape(
            self.surrogates.shape[0], len(self.props))
        if not presorted:
            order = np.argsort(self.surrogates, kind="stable")
            if not np.array_equal(order, np.arange(order.shape[0])):
                self.surrogates = self.surrogates[order]
                self.objects = self.objects[order]
        self._sig: dict[tuple[int, ...], int] | None = None
        # geometric append buffer shared along a with_rows chain:
        # (capacity surrogates, capacity objects, [rows used]) -- the
        # used cell is the copy-on-branch guard
        self._append: tuple[np.ndarray, np.ndarray, list[int]] | None = None

    @property
    def n_molecules(self) -> int:
        return int(self.surrogates.shape[0])

    @property
    def k(self) -> int:
        return len(self.props)

    @property
    def sig(self) -> dict[tuple[int, ...], int]:
        """Object tuple -> surrogate id (lazily built, cached)."""
        if self._sig is None:
            self._sig = {tuple(row): int(sg) for row, sg in
                         zip(self.objects.tolist(), self.surrogates.tolist())}
        return self._sig

    def row_of(self, sg: int) -> int:
        i = int(np.searchsorted(self.surrogates, sg))
        if i >= self.n_molecules or self.surrogates[i] != sg:
            raise KeyError(sg)
        return i

    def col_of(self, prop: int) -> int | None:
        try:
            return self.props.index(int(prop))
        except ValueError:
            return None

    def with_rows(self, new_surrogates, new_objects,
                  next_ordinal: int) -> "MoleculeTable":
        """New table with appended molecule rows (update path).

        The ingest hot path appends *freshly minted* surrogate ids --
        strictly ascending past the current tail -- so the append runs
        amortized O(rows added): rows land in a geometrically grown
        capacity buffer shared along the chain of successor tables, and
        each successor is a (presorted) view of its prefix.  Old tables
        stay valid -- their views cover only rows written before the
        append -- and branching two successors off one table falls back
        to a fresh buffer (copy-on-branch, guarded by the used counter).
        Non-ascending appends (surrogate id reuse after a redetect) take
        the plain concatenate-and-resort path.
        """
        new_s = np.asarray(new_surrogates, np.int32).reshape(-1)
        new_o = np.asarray(new_objects, np.int32).reshape(-1, self.k)
        m, n = self.n_molecules, int(new_s.shape[0])
        if n == 0:
            return MoleculeTable(
                class_id=self.class_id, props=self.props,
                surrogates=self.surrogates, objects=self.objects,
                next_ordinal=next_ordinal, presorted=True)
        ascending = bool(np.all(np.diff(new_s) > 0)) and \
            (m == 0 or int(new_s[0]) > int(self.surrogates[-1]))
        if not ascending:
            return MoleculeTable(
                class_id=self.class_id, props=self.props,
                surrogates=np.concatenate([self.surrogates, new_s]),
                objects=np.concatenate([self.objects, new_o]),
                next_ordinal=next_ordinal)
        buf = self._append
        if buf is None or buf[2][0] != m or buf[0].shape[0] < m + n:
            cap = max(2 * (m + n), 16)
            buf_s = np.empty((cap,), np.int32)
            buf_o = np.empty((cap, max(self.k, 1)), np.int32)
            buf_s[:m] = self.surrogates
            if self.k:
                buf_o[:m, :self.k] = self.objects
            buf = (buf_s, buf_o, [m])
        buf[0][m:m + n] = new_s
        if self.k:
            buf[1][m:m + n, :self.k] = new_o
        buf[2][0] = m + n
        out = MoleculeTable(
            class_id=self.class_id, props=self.props,
            surrogates=buf[0][:m + n], objects=buf[1][:m + n, :self.k],
            next_ordinal=next_ordinal, presorted=True)
        out._append = buf
        self._append = None     # successor owns the buffer now
        if self._sig is not None:
            # sig ownership transfer: extending the parent's map costs
            # O(n), rebuilding it on the successor would cost O(m + n)
            sig = self._sig
            self._sig = None    # parent rebuilds lazily if probed again
            for row, sg in zip(new_o.tolist(), new_s.tolist()):
                sig[tuple(row)] = int(sg)
            out._sig = sig
        return out

    def without_rows(self, drop: Sequence[int]) -> "MoleculeTable":
        keep = np.ones((self.n_molecules,), bool)
        keep[list(drop)] = False
        return MoleculeTable(
            class_id=self.class_id, props=self.props,
            surrogates=self.surrogates[keep], objects=self.objects[keep],
            next_ordinal=self.next_ordinal)


@dataclasses.dataclass
class DeleteStats:
    """Outcome of one ``delete_triples`` / ``delete_entities`` pass."""

    n_requested: int = 0
    n_raw_removed: int = 0          # rows removed directly from the store
    n_exits: int = 0                # (entity, molecule) memberships dissolved
    n_decompacted: int = 0          # entities re-materialized as raw triples
    n_molecules_removed: int = 0    # molecules invalidated / below payoff
    # class id -> {"exits" | "decompacted" | "molecules_removed": count};
    # the drift tracker consumes these to attribute support decay to the
    # classes that suffered it (repro.online.drift)
    per_class: dict = dataclasses.field(default_factory=dict)

    def note_class(self, cid: int, key: str, n: int = 1) -> None:
        if n:
            d = self.per_class.setdefault(int(cid), {})
            d[key] = d.get(key, 0) + int(n)


# the support below which a molecule stops paying for itself: a molecule
# of k >= 2 arms and m members saves k(m - 1) - 1 edges, positive iff
# m >= 2 (see Def. 4.8 / Fig. 7's overhead case)
PAYOFF_MIN_SUPPORT = 2


class FactorizedGraph:
    """G' with its molecule tables and instanceOf CSR as one structure."""

    def __init__(self, store: TripleStore,
                 tables: Mapping[int, MoleculeTable], *,
                 payoff_min_support: int = PAYOFF_MIN_SUPPORT) -> None:
        self.store = store
        self.tables: dict[int, MoleculeTable] = {
            int(c): t for c, t in tables.items()}
        self.payoff_min_support = int(payoff_min_support)
        if self.tables:
            self.surrogate_ids = np.sort(np.concatenate(
                [t.surrogates for t in self.tables.values()])).astype(np.int32)
        else:
            self.surrogate_ids = np.empty((0,), np.int32)
        # surrogate locator: sg -> (class, table row), vectorized-friendly
        loc_cid, loc_row = [], []
        for cid, t in self.tables.items():
            loc_cid.append(np.full((t.n_molecules,), cid, np.int64))
            loc_row.append(np.arange(t.n_molecules, dtype=np.int64))
        if self.tables:
            cat_sg = np.concatenate([t.surrogates
                                     for t in self.tables.values()])
            order = np.argsort(cat_sg, kind="stable")
            self._loc_sg = cat_sg[order]
            self._loc_cid = np.concatenate(loc_cid)[order]
            self._loc_row = np.concatenate(loc_row)[order]
        else:
            self._loc_sg = np.empty((0,), np.int32)
            self._loc_cid = np.empty((0,), np.int64)
            self._loc_row = np.empty((0,), np.int64)
        self._build_membership()

    # -- membership CSR ----------------------------------------------------
    def _build_membership(self) -> None:
        """Rebuild the surrogate -> members CSR from the instanceOf
        partition of the store (sorted by (surrogate, entity))."""
        inst = self.store.index.pred_slice(self.store.INSTANCE_OF)
        if inst.shape[0]:
            order = np.lexsort((inst[:, 0], inst[:, 2]))
            pairs = inst[order]
            self._mem_sg, first = np.unique(pairs[:, 2], return_index=True)
            self._mem_off = np.append(first, pairs.shape[0])
            self._mem = np.ascontiguousarray(pairs[:, 0])
        else:
            self._mem_sg = np.empty((0,), np.int32)
            self._mem_off = np.zeros((1,), np.int64)
            self._mem = np.empty((0,), np.int32)

    def members(self, sg: int) -> np.ndarray:
        """Sorted member entities of one surrogate (CSR slice)."""
        i = int(np.searchsorted(self._mem_sg, sg))
        if i >= self._mem_sg.shape[0] or self._mem_sg[i] != sg:
            return self._mem[:0]
        return self._mem[self._mem_off[i]:self._mem_off[i + 1]]

    def members_of(self, sgs: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Members of a surrogate *set* in one vectorized CSR gather.

        Returns ``(entities, source)``: all member entities concatenated
        plus the position into ``sgs`` each came from -- one matched
        molecule answers all of its entities at once.
        """
        sgs = np.asarray(sgs).reshape(-1)
        if self._mem_sg.shape[0] == 0 or sgs.shape[0] == 0:
            return self._mem[:0], np.empty((0,), np.int64)
        idx = np.searchsorted(self._mem_sg, sgs)
        idx_c = np.minimum(idx, max(self._mem_sg.shape[0] - 1, 0))
        present = np.zeros(sgs.shape[0], bool)
        if self._mem_sg.shape[0]:
            present = (idx < self._mem_sg.shape[0]) & \
                (self._mem_sg[idx_c] == sgs)
        starts = np.where(present, self._mem_off[idx_c], 0)
        counts = np.where(present, self._mem_off[idx_c + 1] - starts, 0)
        if int(counts.sum()) == 0:
            return self._mem[:0], np.empty((0,), np.int64)
        ents = self._mem[csr_take(starts, counts)]
        src = np.repeat(np.arange(sgs.shape[0]), counts)
        return ents, src

    def support(self, class_id: int) -> np.ndarray:
        """(M,) member count per molecule of one class."""
        t = self.tables[int(class_id)]
        _, src = self.members_of(t.surrogates)
        return np.bincount(src, minlength=t.n_molecules).astype(np.int64)

    def am(self, class_id: int) -> int:
        """Total absorbed membership of a class (Def. 4.8's AM over the
        factorized population) -- a planner cardinality input."""
        t = self.tables.get(int(class_id))
        if t is None or t.n_molecules == 0:
            return 0
        return int(self.support(int(class_id)).sum())

    def ami(self, class_id: int) -> int:
        """Molecule count of a class (Def. 4.8's AMI): the row count a
        molecule-granularity evaluation touches."""
        t = self.tables.get(int(class_id))
        return int(t.n_molecules) if t is not None else 0

    def molecule_of(self, class_id: int, ents: np.ndarray) -> np.ndarray:
        """Per entity, the surrogate it is absorbed under in this class
        (-1 if not absorbed there).  One searchsorted walk over the
        subject-sorted instanceOf partition -- the entity->molecule side
        of a molecule-level join, O(n log) in the probe set, never in
        AM."""
        ents = np.asarray(ents, np.int64).reshape(-1)
        out = np.full(ents.shape[0], -1, np.int64)
        t = self.tables.get(int(class_id))
        if t is None or t.n_molecules == 0 or ents.shape[0] == 0:
            return out
        inst = self.store.index.pred_slice(self.store.INSTANCE_OF)
        if inst.shape[0] == 0:
            return out
        lo = np.searchsorted(inst[:, 0], ents, side="left")
        hi = np.searchsorted(inst[:, 0], ents, side="right")
        counts = hi - lo
        src = np.repeat(np.arange(ents.shape[0]), counts)
        sgs = inst[csr_take(lo, counts), 2].astype(np.int64)
        keep = in_sorted(sgs, t.surrogates.astype(np.int64))
        out[src[keep]] = sgs[keep]
        return out

    def is_surrogate(self, ids: np.ndarray) -> np.ndarray:
        return in_sorted(np.asarray(ids).reshape(-1), self.surrogate_ids)

    def surrogates_of(self, entity: int) -> np.ndarray:
        """Surrogates the entity is an instance of (possibly several --
        one per factorized class it was absorbed into)."""
        sl = self.store.index.pred_slice(self.store.INSTANCE_OF)
        lo = int(np.searchsorted(sl[:, 0], entity, side="left"))
        hi = int(np.searchsorted(sl[:, 0], entity, side="right"))
        return sl[lo:hi, 2]

    def locate(self, sg: int) -> tuple[int, int]:
        """(class_id, table row) of a surrogate."""
        i = int(np.searchsorted(self._loc_sg, sg))
        if i >= self._loc_sg.shape[0] or self._loc_sg[i] != sg:
            raise KeyError(sg)
        return int(self._loc_cid[i]), int(self._loc_row[i])

    # -- construction ------------------------------------------------------
    @classmethod
    def from_compaction(cls, graph: TripleStore, results: Iterable,
                        **kw) -> "FactorizedGraph":
        """Build from ``factorize_classes`` output (the
        ``FactorizationResult`` list carries aligned surrogate /
        star-object arrays, so no rescan of G' is needed)."""
        tables: dict[int, MoleculeTable] = {}
        for res in results:
            tables[int(res.class_id)] = MoleculeTable(
                class_id=int(res.class_id),
                props=tuple(sorted(int(p) for p in res.props)),
                surrogates=res.surrogates, objects=res.star_objects,
                next_ordinal=int(res.surrogates.shape[0]))
        return cls(graph, tables, **kw)

    def with_store(self, store: TripleStore) -> "FactorizedGraph":
        """Re-host the same tables on a semantically identical store
        (tier migration: the background recompression packs the store
        and swaps it under the unchanged molecule tables)."""
        return FactorizedGraph(store, dict(self.tables),
                               payoff_min_support=self.payoff_min_support)

    # -- size / accounting -------------------------------------------------
    @property
    def n_triples(self) -> int:
        return self.store.n_triples

    def residual_props(self, class_id: int) -> np.ndarray:
        """Sorted non-SP property ids carried (raw) by the class's
        absorbed entities -- the ``|S - SP|`` part of Def. 4.8."""
        t = self.tables[int(class_id)]
        ents, _ = self.members_of(t.surrogates)
        ents = np.unique(ents)
        idx = self.store.index
        sp = set(t.props)
        out = []
        for p in idx.preds.tolist():
            if p in sp or p == idx.type_id or p == idx.instance_of_id:
                continue
            subs = idx.pred_subjects(p)
            if ents.shape[0] and in_sorted(subs, ents).any():
                out.append(p)
        return np.asarray(out, np.int64)

    def def48_edges(self, class_id: int, n_s: int | None = None) -> int:
        """Def. 4.8 ``#Edges(SP, C, G)`` read off the structure:
        ``AMI * (|SP| + 1) + AM * (|S| - |SP|)`` with AMI = molecule
        count, AM = total membership, |S| measured from the residual
        raw properties unless given."""
        t = self.tables[int(class_id)]
        am = int(self.support(class_id).sum())
        if n_s is None:
            n_s = t.k + int(self.residual_props(class_id).shape[0])
        return num_edges(t.n_molecules, am, t.k, int(n_s))

    # -- losslessness ------------------------------------------------------
    def expand(self) -> TripleStore:
        """Materialize the original graph G from G' (Def. 4.10/4.11
        losslessness): every member entity takes back its molecule's
        arms and ``type`` edge; surrogate rows and ``instanceOf`` links
        disappear.  One CSR gather per class -- no per-entity loop."""
        spo = self.store.spo
        keep = (spo[:, 1] != self.store.INSTANCE_OF) & \
            ~in_sorted(spo[:, 0], self.surrogate_ids)
        parts = [spo[keep]]
        for cid, t in self.tables.items():
            ents, src = self.members_of(t.surrogates)
            if ents.shape[0] == 0:
                continue
            k = t.k
            arm_rows = np.empty((ents.shape[0] * k, 3), np.int32)
            arm_rows[:, 0] = np.repeat(ents, k)
            arm_rows[:, 1] = np.tile(np.asarray(t.props, np.int32),
                                     ents.shape[0])
            arm_rows[:, 2] = t.objects[src].ravel()
            type_rows = np.empty((ents.shape[0], 3), np.int32)
            type_rows[:, 0] = ents
            type_rows[:, 1] = self.store.TYPE
            type_rows[:, 2] = cid
            parts.extend([arm_rows, type_rows])
        return TripleStore.from_ids(self.store.dict,
                                    np.concatenate(parts, axis=0))

    def decompact_classes(self, class_ids: Iterable[int]
                          ) -> "FactorizedGraph":
        """Decompact ONLY the given classes: their members take their
        molecule arms and ``type`` edges back as raw triples, their
        surrogate rows and ``instanceOf`` links disappear, and every
        other class's table and triples pass through untouched.  This is
        the targeted-redetection primitive (``CompactionPlanner.
        redetect``): the rebuilt store costs one sort over the result,
        proportional to the dirty classes' footprint plus one pass over
        the store -- never a re-factorization of the clean classes."""
        cids = sorted({int(c) for c in class_ids if int(c) in self.tables})
        if not cids:
            return self
        drop_sgs = np.sort(np.concatenate(
            [self.tables[c].surrogates for c in cids]).astype(np.int64))
        spo = self.store.spo
        keep = ~in_sorted(spo[:, 0].astype(np.int64), drop_sgs) & \
            ~((spo[:, 1] == self.store.INSTANCE_OF) &
              in_sorted(spo[:, 2].astype(np.int64), drop_sgs))
        parts = [spo[keep]]
        for cid in cids:
            t = self.tables[cid]
            ents, src = self.members_of(t.surrogates)
            if ents.shape[0] == 0:
                continue
            k = t.k
            arm_rows = np.empty((ents.shape[0] * k, 3), np.int32)
            arm_rows[:, 0] = np.repeat(ents, k)
            arm_rows[:, 1] = np.tile(np.asarray(t.props, np.int32),
                                     ents.shape[0])
            arm_rows[:, 2] = t.objects[src].ravel()
            type_rows = np.empty((ents.shape[0], 3), np.int32)
            type_rows[:, 0] = ents
            type_rows[:, 1] = self.store.TYPE
            type_rows[:, 2] = cid
            parts.extend([arm_rows, type_rows])
        store = TripleStore.from_ids(self.store.dict,
                                     np.concatenate(parts, axis=0))
        tables = {c: t for c, t in self.tables.items() if c not in cids}
        return FactorizedGraph(store, tables,
                               payoff_min_support=self.payoff_min_support)

    def validate(self) -> None:
        """Assert the tables agree with the store's surrogate triples
        (used by tests; cheap relative to a factorization)."""
        idx = self.store.index
        for cid, t in self.tables.items():
            for r in range(t.n_molecules):
                sg = int(t.surrogates[r])
                lo = np.searchsorted(self.store.spo[:, 0], sg, side="left")
                hi = np.searchsorted(self.store.spo[:, 0], sg, side="right")
                rows = self.store.spo[lo:hi]
                want = {(int(p), int(o))
                        for p, o in zip(t.props, t.objects[r])}
                want.add((self.store.TYPE, cid))
                got = {(int(p), int(o)) for _, p, o in rows}
                assert got == want, (cid, sg, got, want)
        del idx

    # -- deletes -----------------------------------------------------------
    def _check_semantic_rows(self, rows: np.ndarray) -> None:
        if rows.shape[0] == 0:
            return
        if in_sorted(rows[:, 0], self.surrogate_ids).any():
            raise ValueError(
                "cannot delete surrogate-subject triples directly; delete "
                "the entity triples they factorize instead")
        if (rows[:, 1] == self.store.INSTANCE_OF).any():
            raise ValueError(
                "instanceOf links are storage artifacts, not semantic "
                "triples; delete entity triples (or entities) instead")

    def delete_triples(self, rows) -> tuple["FactorizedGraph", DeleteStats]:
        """Delete *semantic* triples from G'.

        A triple present raw in the store is simply removed.  A triple
        covered by a molecule (one of the subject's absorbed arms, or
        its moved ``type`` edge) dissolves that membership: the entity
        exits the molecule and its surviving arms re-materialize as raw
        triples.  Molecules whose support drops below the payoff
        threshold decompact in place.  Absent triples are no-ops.
        """
        rows = sort_unique(np.asarray(rows, np.int32).reshape(-1, 3),
                           SPO_PERM)
        self._check_semantic_rows(rows)
        stats = DeleteStats(n_requested=int(rows.shape[0]))
        store = self.store
        present = in_sorted(_key_view(rows, SPO_PERM),
                            _key_view(store.spo, SPO_PERM)) \
            if store.spo.shape[0] else np.zeros(rows.shape[0], bool)
        raw_del = rows[present]
        stats.n_raw_removed = int(raw_del.shape[0])
        # molecule-covered deletions: (entity, surrogate) -> dissolved arms
        exits: dict[tuple[int, int], tuple[set, bool]] = {}
        for s, p, o in rows[~present].tolist():
            for sg in self.surrogates_of(s).tolist():
                cid, r = self.locate(sg)
                t = self.tables[cid]
                cols, type_del = exits.get((s, sg), (set(), False))
                if p == store.TYPE and o == cid:
                    exits[(s, sg)] = (cols, True)
                else:
                    j = t.col_of(p)
                    if j is not None and int(t.objects[r, j]) == o:
                        cols.add(j)
                        exits[(s, sg)] = (cols, type_del)
        stats.n_exits = len(exits)
        removed = [raw_del]
        added = []
        for (s, sg), (cols, type_del) in exits.items():
            cid, r = self.locate(sg)
            stats.note_class(cid, "exits")
            t = self.tables[cid]
            for j in range(t.k):
                if j not in cols:
                    added.append((s, t.props[j], int(t.objects[r, j])))
            if not type_del:
                added.append((s, store.TYPE, cid))
            removed.append(np.asarray([[s, store.INSTANCE_OF, sg]],
                                      np.int32))
        interim = self._apply_edits(np.concatenate(removed, axis=0)
                                    if removed else None, added)
        fg = FactorizedGraph(interim, self.tables,
                             payoff_min_support=self.payoff_min_support)
        affected = {sg for (_, sg) in exits}
        return fg._payoff_sweep(affected, stats)

    def delete_entities(self, entities) -> tuple["FactorizedGraph",
                                                 DeleteStats]:
        """Delete entities: every triple with the entity as subject OR
        object disappears semantically.  Molecules *referencing* a
        deleted entity in an arm are invalidated outright (their members
        decompact with the surviving arms); memberships of deleted
        entities dissolve and shrink supports, with the same payoff
        sweep as :meth:`delete_triples`.
        """
        ents = np.unique(np.asarray(entities, np.int64).reshape(-1))
        if in_sorted(ents, self.surrogate_ids).any():
            raise ValueError("surrogates are storage artifacts; they "
                             "disappear when their molecules do")
        stats = DeleteStats(n_requested=int(ents.shape[0]))
        store = self.store
        removed = []
        added: list[tuple[int, int, int]] = []
        new_tables = dict(self.tables)
        # 1. molecules with a deleted entity (or class) in an arm/type:
        #    the star pattern no longer exists -- invalidate in place
        for cid, t in self.tables.items():
            class_deleted = bool(in_sorted(
                np.asarray([cid], np.int64), ents)[0])
            arm_hit = in_sorted(t.objects.ravel(), ents).reshape(
                t.objects.shape)
            hit_rows = np.flatnonzero(arm_hit.any(axis=1) | class_deleted)
            if hit_rows.size == 0:
                continue
            for r in hit_rows.tolist():
                sg = int(t.surrogates[r])
                mem = self.members(sg)
                surviving = mem[~in_sorted(mem.astype(np.int64), ents)]
                for m in surviving.tolist():
                    for j in range(t.k):
                        if not arm_hit[r, j]:
                            added.append((m, t.props[j],
                                          int(t.objects[r, j])))
                    if not class_deleted:
                        added.append((m, store.TYPE, cid))
                stats.n_decompacted += int(surviving.shape[0])
                stats.note_class(cid, "decompacted",
                                 int(surviving.shape[0]))
                # surrogate rows + every member's instanceOf link go
                sg_lo = np.searchsorted(store.spo[:, 0], sg, "left")
                sg_hi = np.searchsorted(store.spo[:, 0], sg, "right")
                removed.append(store.spo[sg_lo:sg_hi])
                if mem.shape[0]:
                    inst = np.empty((mem.shape[0], 3), np.int32)
                    inst[:, 0] = mem
                    inst[:, 1] = store.INSTANCE_OF
                    inst[:, 2] = sg
                    removed.append(inst)
            stats.n_molecules_removed += int(hit_rows.size)
            stats.note_class(cid, "molecules_removed", int(hit_rows.size))
            new_tables[cid] = t.without_rows(hit_rows.tolist())
        # 2. raw rows touching a deleted entity (their instanceOf rows
        #    dissolve memberships -> collect affected surrogates)
        spo = store.spo
        touch = in_sorted(spo[:, 0].astype(np.int64), ents) | \
            (in_sorted(spo[:, 2].astype(np.int64), ents) &
             (spo[:, 1] != store.INSTANCE_OF))
        inst_of_deleted = (spo[:, 1] == store.INSTANCE_OF) & \
            in_sorted(spo[:, 0].astype(np.int64), ents)
        affected = set(np.unique(spo[inst_of_deleted, 2]).tolist())
        diss_sg, diss_n = np.unique(spo[inst_of_deleted, 2],
                                    return_counts=True)
        for sg, c in zip(diss_sg.tolist(), diss_n.tolist()):
            try:
                stats.note_class(self.locate(int(sg))[0], "exits", int(c))
            except KeyError:
                pass
        removed.append(spo[touch | inst_of_deleted])
        stats.n_raw_removed = int((touch | inst_of_deleted).sum())
        interim = self._apply_edits(
            np.concatenate(removed, axis=0) if removed else None, added)
        fg = FactorizedGraph(interim, new_tables,
                             payoff_min_support=self.payoff_min_support)
        return fg._payoff_sweep(affected, stats)

    def _apply_edits(self, removed_rows: np.ndarray | None,
                     added: list) -> TripleStore:
        spo = self.store.spo
        if removed_rows is not None and removed_rows.shape[0]:
            dr = sort_unique(removed_rows, SPO_PERM)
            keep = ~in_sorted(_key_view(spo, SPO_PERM),
                              _key_view(dr, SPO_PERM))
            spo = spo[keep]
        out = TripleStore.from_ids(self.store.dict, spo, presorted=True)
        if added:
            out.add_ids(np.asarray(added, np.int32).reshape(-1, 3))
        return out

    def _payoff_sweep(self, affected_sgs: set,
                      stats: DeleteStats) -> tuple["FactorizedGraph",
                                                   DeleteStats]:
        """Decompact molecules among ``affected_sgs`` whose support fell
        below ``payoff_min_support`` (Fig. 7: they now cost more edges
        than the raw representation they replaced)."""
        if not affected_sgs:
            return self, stats
        removed = []
        added: list[tuple[int, int, int]] = []
        new_tables = dict(self.tables)
        store = self.store
        affected_arr = np.asarray(sorted(affected_sgs), np.int64)
        for cid, t in self.tables.items():
            # surrogates are kept ascending: the affected subset of a
            # table is one binary-search join, not a per-molecule probe
            hit = np.flatnonzero(in_sorted(
                t.surrogates.astype(np.int64), affected_arr)).tolist()
            drop = []
            for r in hit:
                sg = int(t.surrogates[r])
                mem = self.members(sg)
                if mem.shape[0] >= self.payoff_min_support:
                    continue
                drop.append(r)
                for m in mem.tolist():
                    for j in range(t.k):
                        added.append((m, t.props[j], int(t.objects[r, j])))
                    added.append((m, store.TYPE, cid))
                stats.n_decompacted += int(mem.shape[0])
                stats.note_class(cid, "decompacted", int(mem.shape[0]))
                sg_lo = np.searchsorted(store.spo[:, 0], sg, "left")
                sg_hi = np.searchsorted(store.spo[:, 0], sg, "right")
                removed.append(store.spo[sg_lo:sg_hi])
                if mem.shape[0]:
                    inst = np.empty((mem.shape[0], 3), np.int32)
                    inst[:, 0] = mem
                    inst[:, 1] = store.INSTANCE_OF
                    inst[:, 2] = sg
                    removed.append(inst)
            if drop:
                stats.n_molecules_removed += len(drop)
                stats.note_class(cid, "molecules_removed", len(drop))
                new_tables[cid] = new_tables[cid].without_rows(drop)
        if not removed and not added:
            return self, stats
        out = self._apply_edits(
            np.concatenate(removed, axis=0) if removed else None, added)
        return FactorizedGraph(
            out, new_tables,
            payoff_min_support=self.payoff_min_support), stats

    def __repr__(self) -> str:  # pragma: no cover
        return (f"FactorizedGraph(n_triples={self.n_triples}, "
                f"classes={len(self.tables)}, "
                f"molecules={int(self.surrogate_ids.shape[0])})")

"""Distributed FSP detection on the production mesh (paper §6 future work).

The FSP inner loop is a group-by-signature + count-distinct over the
(entities x |SP|) object matrix.  On the 512-chip mesh:

* rows (entities) are sharded over the combined DP axes ("pod", "data");
* each device hashes its rows with the Pallas signature kernel
  (``kernels/sig_hash``), giving fixed-width 64-bit keys;
* AMI = number of distinct signatures = global sort + segment-boundary
  count.  The sort runs under GSPMD, which lowers it to a distributed
  sort (all-to-all exchanges) -- the TPU-idiomatic replacement for the
  paper's host hash map;
* G.FSP's per-iteration sweep over all |SP| one-property-removed subsets
  is DATA-PARALLEL across candidates (the paper iterates them
  sequentially): one vmapped lowering evaluates every candidate at once.

``gfsp_distributed`` runs the greedy descent of Algorithm 2 with this
device sweep, and is validated against the host implementation
(tests/test_distributed_fsp.py).  ``benchmarks/bench_fsp_scale.py``
lowers the sweep on the production mesh and reports its roofline terms
(the paper's own workload, deliverable g).
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from .star import ami_device, edges_formula_device
from .triples import TripleStore


def pad_rows(objmat: np.ndarray, multiple: int) -> tuple[np.ndarray, int]:
    """Pad the row count to a multiple of the DP degree (sentinel rows)."""
    n = objmat.shape[0]
    pad = (-n) % multiple
    if pad:
        sentinel = np.full((pad, objmat.shape[1]), -1, objmat.dtype)
        objmat = np.concatenate([objmat, sentinel], axis=0)
    return objmat, n


@functools.partial(jax.jit, static_argnames=("n_s", "use_kernel"))
def sweep_drop_one(objmat, valid, am, n_s: int, use_kernel: bool = True):
    """Evaluate all |SP| one-property-removed candidate subsets at once.

    objmat: (n, k) int32 (row-sharded); valid: (n,) bool (padding mask).
    Returns (edges (k,), amis (k,)) for candidate j = SP minus property j.
    """
    n, k = objmat.shape
    keep = jnp.stack([jnp.delete(jnp.arange(k), j, assume_unique_indices=True)
                      for j in range(k)])              # (k, k-1) static
    stacked = jnp.take(objmat, keep.T, axis=1)         # (n, k-1, k)
    stacked = stacked.transpose(2, 0, 1)               # (k, n, k-1)
    amis = jax.vmap(
        lambda m: ami_device(m, valid=valid, use_kernel=use_kernel))(stacked)
    edges = edges_formula_device(amis, am, k - 1, n_s)
    return edges, amis


@functools.partial(jax.jit, static_argnames=("n_s", "n_sp", "use_kernel"))
def eval_subset_device(objmat, valid, am, n_sp: int, n_s: int,
                       use_kernel: bool = True):
    a = ami_device(objmat, valid=valid, use_kernel=use_kernel)
    return edges_formula_device(a, am, n_sp, n_s), a


def shard_rows(objmat: np.ndarray, mesh) -> jax.Array:
    """Place the object matrix row-sharded over every non-"model" axis."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return jax.device_put(objmat, NamedSharding(mesh, P(dp, None)))


def gfsp_distributed(store: TripleStore, class_id: int, *, mesh=None,
                     use_kernel: bool = True):
    """Algorithm 2 (G.FSP) with the mesh-sharded device sweep.

    Compatibility wrapper over the unified pipeline: equivalent to
    ``repro.api.Compactor(detector="gfsp", backend="sharded",
    backend_opts={"mesh": mesh}).detect(store, class_id)``.  Control flow
    (stop criteria, tie-breaking, evaluation accounting) is the shared
    ``GreedyDetector`` loop, so host / device / sharded results are
    identical by construction (asserted in tests/test_distributed_fsp.py).
    """
    from repro.api import GreedyDetector, ShardedBackend

    backend = ShardedBackend(mesh=mesh, use_kernel=use_kernel)
    return GreedyDetector().detect(store, class_id, backend=backend)


def ami_bucketed_batch(objmat, valid, col_masks, mesh, *, dp_axes=("data",),
                       cap_factor: float = 4.0, use_kernel: bool = True):
    """Candidate-batched distinct-row count via ONE hash-bucket exchange.

    The sort-based AMI is exact but a distributed sort exchanges the data
    over O(log^2 S) merge rounds (bench_fsp_scale baseline: 3035 s of
    collectives at D1D2D3 scale).  Here every signature moves ONCE: each
    shard routes signatures to their hash-owner with one all_to_all
    (static per-destination capacity; uniform murmur hashes make a 4x
    headroom overflow probability ~Poisson-tail negligible, and overflow
    is detected and summed so exactness violations are observable), the
    owner dedups locally, and a psum merges counts.

    The candidate axis rides the same schedule end to end: all C
    column-mask candidates hash in one batched signature launch (Pallas
    grid axis over candidates), route through ONE ``all_to_all`` whose
    buffer carries a candidate dimension, and dedup/psum as (C,) vectors
    -- one shard_map lowering per sweep instead of one per candidate.

    objmat: (n, k) int32 row-sharded over ``dp_axes``; valid: (n,) bool;
    col_masks: (C, k) int32 replicated column masks (1 = keep column).
    Returns (C,) int32 AMI, one per candidate.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ops as kops

    n_shards = 1
    for a, s_ in zip(mesh.axis_names, mesh.devices.shape):
        if a in dp_axes:
            n_shards *= s_

    def body(mat, val, masks):
        nl = mat.shape[0]
        c = masks.shape[0]
        # candidates are column-masked views of the one sharded buffer
        mats = mat[None, :, :] * masks[:, None, :]           # (c, nl, k)
        # mask-aware signature: padding rows get the shared sentinel,
        # independently per candidate
        sig = kops.row_signature(mats, valid=val, use_kernel=use_kernel)
        sentinel = jnp.uint32(kops.SIG_SENTINEL)
        owner = (sig[..., 0] % jnp.uint32(n_shards)).astype(jnp.int32)
        owner = jnp.where(val[None, :], owner, n_shards)  # invalid -> dump
        cap = max(int(cap_factor * nl / n_shards) + 8, 8)
        order = jnp.argsort(owner, axis=1)
        owner_s = jnp.take_along_axis(owner, order, axis=1)
        sig_s = jnp.take_along_axis(sig, order[..., None], axis=1)
        starts = jax.vmap(
            lambda os: jnp.searchsorted(os, jnp.arange(n_shards)))(owner_s)
        pos = jnp.arange(nl)[None, :] - jnp.take_along_axis(
            starts, jnp.minimum(owner_s, n_shards - 1), axis=1)
        keep = (owner_s < n_shards) & (pos < cap)
        dropped = jnp.sum((owner_s < n_shards) & (pos >= cap), axis=1)
        # cap+1: slot ``cap`` is the dump slot for non-kept entries --
        # dumping them at (0, 0) would overwrite a real signature
        buf = jnp.full((n_shards, c, cap + 1, 2), sentinel, jnp.uint32)
        ci = jnp.broadcast_to(jnp.arange(c)[:, None], (c, nl))
        buf = buf.at[jnp.where(keep, owner_s, 0), ci,
                     jnp.where(keep, pos, cap)].set(
            jnp.where(keep[..., None], sig_s, sentinel))
        buf = buf[:, :, :cap]
        # ONE exchange for the whole stack: shard i sends slab j to shard
        # j; the candidate axis tags along inside each slab
        recv = jax.lax.all_to_all(buf, dp_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        flat = recv.transpose(1, 0, 2, 3).reshape(c, -1, 2)
        sig_sorted, _ = kops.sort_signatures(flat)     # per-candidate sort
        _, n_groups = kops.seg_boundaries(sig_sorted,
                                          use_kernel=use_kernel)   # (c,)
        has_sent = jnp.any(jnp.all(sig_sorted == sentinel, axis=-1),
                           axis=-1)                                # (c,)
        local_distinct = n_groups - has_sent.astype(jnp.int32)
        total = jax.lax.psum(local_distinct, dp_axes)
        total = total + jax.lax.psum(dropped, dp_axes)  # upper-bound fix
        return total

    spec_m = P(dp_axes, None)
    spec_v = P(dp_axes)
    # check_vma=False: pallas_call outputs do not carry vma metadata yet
    return shard_map(body, mesh=mesh,
                     in_specs=(spec_m, spec_v, P(None, None)),
                     out_specs=P(None), check_vma=False)(
        objmat, valid, col_masks)


def ami_bucketed(objmat, valid, mesh, *, dp_axes=("data",),
                 cap_factor: float = 4.0, use_kernel: bool = True):
    """Single-candidate distinct-row count: the C = 1 special case of
    :func:`ami_bucketed_batch` with an all-ones column mask (kept as the
    stable entry point for callers outside the sweep engine)."""
    masks = jnp.ones((1, objmat.shape[1]), jnp.int32)
    return ami_bucketed_batch(
        objmat, valid, masks, mesh, dp_axes=dp_axes,
        cap_factor=cap_factor, use_kernel=use_kernel)[0]

"""E.FSP -- Algorithm 1: exhaustive frequent-star-pattern detection.

E.FSP consumes the frequent-pattern space enumerated by gSpan over the RDF
molecules of a class (``subgraphsDict``: property subset -> the star
subgraphs over that subset), then breadth-first scans all property subsets
of cardinality ``|S| .. 2`` keeping the subset whose subgraphs minimize the
Def. 4.8 edge objective.  Complexity is O(2^n) in the number of class
properties -- the pattern space itself is exponential, which is exactly the
cost G.FSP avoids (paper reports >= 3 orders of magnitude).

``subgraphsDict`` construction: gSpan patterns over star molecules are
star-shaped DFS codes rooted at the class vertex; each pattern fixes a set
of properties and one object tuple.  Grouping patterns by their property
set yields the dictionary of Algorithm 1; the number of patterns per subset
is AMI, and countEdges follows Def. 4.8 (see note in ``star.py`` on the
prose/definition discrepancy in the paper's walkthrough).
"""
from __future__ import annotations

import itertools
import time
from typing import Sequence

import numpy as np

from .gfsp import FSPResult
from .gspan import mine, molecules_of_class
from .star import num_edges, star_groups
from .triples import TripleStore


def build_subgraphs_dict(store: TripleStore, class_id: int,
                         min_support: int = 1,
                         max_edges: int | None = None):
    """Enumerate the gSpan pattern space and bucket star patterns by
    property subset.

    Returns ``(subgraphs_dict, n_patterns, entities)`` where
    ``subgraphs_dict[frozenset(props)] = list[(object_tuple, support)]``.
    """
    ents, graphs = molecules_of_class(store, class_id)
    patterns = mine(graphs, min_support=min_support, max_edges=max_edges)
    subgraphs: dict[frozenset, list[tuple[tuple, int]]] = {}
    for pat in patterns:
        # star pattern rooted at the class vertex: every edge is a forward
        # edge (0, k, class, p, 1, o)
        if not all(t[0] == 0 and t[4] == 1 for t in pat.code):
            continue
        props = tuple(sorted(t[3] for t in pat.code))
        if len(set(props)) != len(props):
            continue  # functional-property duplicates are not star patterns
        objs = tuple(o for _, o in sorted((t[3], t[5]) for t in pat.code))
        subgraphs.setdefault(frozenset(props), []).append((objs, pat.support))
    return subgraphs, len(patterns), ents


def efsp(store: TripleStore, class_id: int,
         props: Sequence[int] | None = None,
         min_support: int = 1,
         subgraphs_dict=None) -> FSPResult:
    """Run E.FSP for ``class_id``; returns the same result type as G.FSP."""
    t0 = time.perf_counter()
    stats = store.class_stats(class_id)
    s_all = (np.asarray(list(props), np.int32)
             if props is not None else stats.properties)
    n_s = int(s_all.shape[0])
    am = stats.n_instances

    if subgraphs_dict is None:
        subgraphs_dict, _, _ = build_subgraphs_dict(
            store, class_id, min_support=min_support)

    best_sp: tuple[int, ...] | None = None
    best_edges = 0
    best_ami = 0
    iterations = 0
    evaluations = 0
    subset_card = n_s
    s_list = [int(p) for p in s_all]
    while subset_card >= 2:
        iterations += 1
        for combo in itertools.combinations(s_list, subset_card):
            key = frozenset(combo)
            subgraphs = subgraphs_dict.get(key, [])
            evaluations += 1
            # countEdges(subgraphs): the factorized edge count of Def. 4.8 --
            # one star (|SP|+1 edges) per pattern + untouched properties.
            a = len(subgraphs)
            total_edges = num_edges(a, am, subset_card, n_s)
            if best_sp is None or total_edges < best_edges:
                best_edges = total_edges
                best_sp = tuple(sorted(combo))
                best_ami = a
        subset_card -= 1

    if best_sp is None:
        best_sp, best_ami, best_edges = (), 0, 0
        fsp = []
    else:
        fsp = star_groups(store, class_id, best_sp)
    return FSPResult(
        class_id=class_id, props=best_sp, edges=best_edges, ami=best_ami,
        am=am, iterations=iterations, evaluations=evaluations,
        exec_time_ms=(time.perf_counter() - t0) * 1e3, fsp=fsp)

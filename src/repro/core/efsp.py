"""gSpan pattern-space construction for the E.FSP baseline paths.

The paper's Algorithm 1 consumes the frequent-pattern space enumerated by
gSpan over the RDF molecules of a class (``subgraphsDict``: property
subset -> the star subgraphs over that subset).  Materializing that space
is exponential -- the cost the paper's Table 3 attributes to E.FSP and
that G.FSP avoids (>= 3 orders of magnitude).

The DEFAULT exhaustive detector no longer pays it:
``repro.api.ExhaustiveDetector`` scans the property-subset lattice
level-by-level through the candidate-batched sweep engine
(``core.sweep.SweepWorkspace.sweep_candidates``), computing AMI directly
from the object matrix.  ``build_subgraphs_dict`` remains as (a) the
input of the honest ``gspan`` baseline detector and (b) the legacy
Algorithm-1 path selected by passing ``subgraphs_dict=`` explicitly.

``subgraphsDict`` construction: gSpan patterns over star molecules are
star-shaped DFS codes rooted at the class vertex; each pattern fixes a set
of properties and one object tuple.  Grouping patterns by their property
set yields the dictionary of Algorithm 1; the number of patterns per subset
is AMI, and countEdges follows Def. 4.8 (see note in ``star.py`` on the
prose/definition discrepancy in the paper's walkthrough).
"""
from __future__ import annotations

import warnings
from typing import Sequence

import numpy as np

from .gfsp import FSPResult
from .gspan import mine, molecules_of_class
from .triples import TripleStore


def build_subgraphs_dict(store: TripleStore, class_id: int,
                         min_support: int = 1,
                         max_edges: int | None = None):
    """Enumerate the gSpan pattern space and bucket star patterns by
    property subset.

    Returns ``(subgraphs_dict, n_patterns, entities)`` where
    ``subgraphs_dict[frozenset(props)] = list[(object_tuple, support)]``.
    """
    ents, graphs = molecules_of_class(store, class_id)
    patterns = mine(graphs, min_support=min_support, max_edges=max_edges)
    subgraphs: dict[frozenset, list[tuple[tuple, int]]] = {}
    for pat in patterns:
        # star pattern rooted at the class vertex: every edge is a forward
        # edge (0, k, class, p, 1, o)
        if not all(t[0] == 0 and t[4] == 1 for t in pat.code):
            continue
        props = tuple(sorted(t[3] for t in pat.code))
        if len(set(props)) != len(props):
            continue  # functional-property duplicates are not star patterns
        objs = tuple(o for _, o in sorted((t[3], t[5]) for t in pat.code))
        subgraphs.setdefault(frozenset(props), []).append((objs, pat.support))
    return subgraphs, len(patterns), ents


def efsp(store: TripleStore, class_id: int,
         props: Sequence[int] | None = None,
         min_support: int = 1,
         subgraphs_dict=None) -> FSPResult:
    """Deprecated shim: use ``repro.api.Compactor(detector="efsp")`` /
    ``repro.api.ExhaustiveDetector`` (the breadth-first subset scan moved
    there; this module keeps the gSpan pattern-space construction)."""
    warnings.warn(
        "repro.core.efsp() is deprecated; use repro.api.Compactor("
        "detector='efsp').detect(...)", DeprecationWarning, stacklevel=2)
    from repro.api import ExhaustiveDetector
    return ExhaustiveDetector(min_support=min_support).detect(
        store, class_id, props=props, subgraphs_dict=subgraphs_dict)

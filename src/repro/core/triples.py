"""Dictionary-encoded RDF triple store.

The paper (Karim et al. 2020) operates on RDF graphs ``G = (V, E, L)``
(Def. 4.2).  Like every production RDF engine (HDT, k2-triples, ...), we
dictionary-encode terms at ingest: URIs / literals become dense int32 ids, and
the graph is a single ``(n, 3)`` COO array of ``(subject, property, object)``
ids.  All downstream computation (multiplicity, AMI, #Edges, factorization)
is vectorized over these arrays, which is also the layout we ship to device.

Two ids are reserved with well-known terms:
  * ``rdf:type``           -- the class-membership property (paper: "type")
  * ``repro:instanceOf``   -- the surrogate-link property added by
                              factorization (paper Def. 4.10/4.11)
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

RDF_TYPE = "rdf:type"
INSTANCE_OF = "repro:instanceOf"


class TermDict:
    """Bidirectional term <-> id dictionary (host side)."""

    __slots__ = ("_terms", "_index")

    def __init__(self) -> None:
        self._terms: list[str] = []
        self._index: dict[str, int] = {}

    def id(self, term: str) -> int:
        """Return the id of ``term``, allocating one if unseen."""
        i = self._index.get(term)
        if i is None:
            i = len(self._terms)
            self._index[term] = i
            self._terms.append(term)
        return i

    def ids(self, terms: Sequence[str]) -> np.ndarray:
        """Bulk id allocation: the batched counterpart of :meth:`id`.

        Unseen terms receive a contiguous id block appended in one shot
        (one list ``extend`` + one dict ``update`` instead of per-term
        lookup/append/insert round-trips) -- the surrogate-minting path of
        Algorithm 3 allocates one id per star pattern and dominates
        factorization setup time at scale (benchmarked in
        ``benchmarks/bench_savings.py``).
        """
        index = self._index
        missing = dict.fromkeys(t for t in terms if t not in index)
        if missing:
            base = len(self._terms)
            self._terms.extend(missing)
            index.update(zip(missing, range(base, base + len(missing))))
        return np.fromiter((index[t] for t in terms), np.int64,
                           count=len(terms))

    def lookup(self, term: str) -> int | None:
        return self._index.get(term)

    def term(self, i: int) -> str:
        return self._terms[i]

    def __len__(self) -> int:
        return len(self._terms)

    def __contains__(self, term: str) -> bool:
        return term in self._index


@dataclasses.dataclass
class ClassStats:
    """Per-class statistics used throughout the paper's formulas."""

    class_id: int
    n_instances: int          # AM_G(C) -- Def. 4.8
    properties: np.ndarray    # sorted property ids with domain C (excl. type)


class TripleStore:
    """An RDF graph as dictionary-encoded COO triples.

    ``spo`` is an ``(n, 3)`` int32 array; row ``(s, p, o)`` is the RDF triple
    / labeled edge of Def. 4.1/4.2.  Duplicate triples are removed (an RDF
    graph is a *set* of triples).
    """

    def __init__(self, dictionary: TermDict | None = None,
                 spo: np.ndarray | None = None) -> None:
        self.dict = dictionary if dictionary is not None else TermDict()
        self.TYPE = self.dict.id(RDF_TYPE)
        self.INSTANCE_OF = self.dict.id(INSTANCE_OF)
        if spo is None:
            spo = np.empty((0, 3), dtype=np.int32)
        self.spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
        self._dedup()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_triples(cls, triples: Iterable[tuple[str, str, str]]) -> "TripleStore":
        store = cls()
        d = store.dict
        rows = [(d.id(s), d.id(p), d.id(o)) for s, p, o in triples]
        store.spo = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        store._dedup()
        return store

    @classmethod
    def from_ids(cls, dictionary: TermDict, spo: np.ndarray) -> "TripleStore":
        return cls(dictionary, spo)

    def add_ids(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        self.spo = np.concatenate([self.spo, rows], axis=0)
        self._dedup()

    def _dedup(self) -> None:
        if len(self.spo):
            self.spo = np.unique(self.spo, axis=0)

    def restrict_subjects(self, subjects: np.ndarray) -> "TripleStore":
        """Subgraph of triples whose subject is in ``subjects`` (shared
        dictionary) -- the paper evaluates each observation type as its
        own graph."""
        mask = np.isin(self.spo[:, 0], np.asarray(subjects))
        return TripleStore.from_ids(self.dict, self.spo[mask])

    # -- size metrics (paper §5, "Metrics") --------------------------------
    @property
    def n_triples(self) -> int:
        return int(self.spo.shape[0])

    def nodes(self) -> np.ndarray:
        """Distinct entity/object nodes (NN numerator)."""
        if not len(self.spo):
            return np.empty((0,), np.int32)
        return np.unique(np.concatenate([self.spo[:, 0], self.spo[:, 2]]))

    @property
    def n_nodes(self) -> int:
        return int(self.nodes().shape[0])

    @property
    def size(self) -> int:
        """Graph size = #nodes + #edges (paper §5 'Metrics')."""
        return self.n_nodes + self.n_triples

    # -- class / schema access ---------------------------------------------
    def entities_of_class(self, class_id: int) -> np.ndarray:
        mask = (self.spo[:, 1] == self.TYPE) & (self.spo[:, 2] == class_id)
        return np.unique(self.spo[mask, 0])

    def classes(self) -> np.ndarray:
        return np.unique(self.spo[self.spo[:, 1] == self.TYPE, 2])

    def class_properties(self, class_id: int) -> np.ndarray:
        """Sorted property ids whose domain includes class C (excl. type &
        instanceOf)."""
        ents = self.entities_of_class(class_id)
        mask = np.isin(self.spo[:, 0], ents)
        props = np.unique(self.spo[mask, 1])
        return props[(props != self.TYPE) & (props != self.INSTANCE_OF)]

    def class_stats(self, class_id: int) -> ClassStats:
        ents = self.entities_of_class(class_id)
        return ClassStats(class_id=class_id, n_instances=int(ents.shape[0]),
                          properties=self.class_properties(class_id))

    # -- molecule access -----------------------------------------------------
    def object_matrix(self, class_id: int, props: Sequence[int],
                      strict: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """Entities x objects matrix for a (class, property-set) pair.

        Returns ``(entities, objmat)`` with ``objmat[i, j]`` = object of
        ``props[j]`` on ``entities[i]``.  The paper's algorithms assume RDF
        molecules are *complete* (every entity has a value for every property)
        and properties are *functional* (one value each) -- assumption (a)/(b)
        of §4.3.  We validate: entities violating either assumption are
        excluded from the candidate set (``strict=True`` raises instead).
        """
        props = np.asarray(list(props), dtype=np.int32)
        ents = self.entities_of_class(class_id)
        if ents.size == 0 or props.size == 0:
            return ents[:0], np.empty((0, props.size), np.int32)
        # edges whose subject is an instance of C and property in props
        sel = np.isin(self.spo[:, 0], ents) & np.isin(self.spo[:, 1], props)
        s, p, o = self.spo[sel].T
        ent_idx = np.searchsorted(ents, s)
        order = np.argsort(props, kind="stable")     # props may be unsorted
        prop_pos = order[np.searchsorted(props[order], p)]
        # count (entity, property) pairs to detect non-functional properties
        flat = ent_idx.astype(np.int64) * props.size + prop_pos
        objmat = np.full((ents.size, props.size), -1, dtype=np.int32)
        counts = np.bincount(flat, minlength=ents.size * props.size)
        ok_pairs = counts.reshape(ents.size, props.size) == 1
        complete = ok_pairs.all(axis=1)
        if strict and not complete.all():
            bad = ents[~complete]
            raise ValueError(
                f"{bad.size} entities of class {class_id} violate the "
                "complete-molecule/functional-property assumption")
        objmat[ent_idx, prop_pos] = o
        return ents[complete], objmat[complete]

    def labeled_edge_count(self, class_id: int,
                           props: Sequence[int] | None = None) -> int:
        """NLE: labeled edges annotated with class properties (paper §5)."""
        ents = self.entities_of_class(class_id)
        mask = np.isin(self.spo[:, 0], ents)
        if props is not None:
            mask &= np.isin(self.spo[:, 1], np.asarray(list(props), np.int32))
        else:
            mask &= self.spo[:, 1] != self.TYPE
        return int(mask.sum())

    # -- convenience ---------------------------------------------------------
    def triples_as_terms(self) -> list[tuple[str, str, str]]:
        t = self.dict.term
        return [(t(s), t(p), t(o)) for s, p, o in self.spo.tolist()]

    def copy(self) -> "TripleStore":
        new = TripleStore.__new__(TripleStore)
        new.dict = self.dict          # term dict is shared (append-only)
        new.TYPE = self.TYPE
        new.INSTANCE_OF = self.INSTANCE_OF
        new.spo = self.spo.copy()
        return new

    def __repr__(self) -> str:  # pragma: no cover
        return f"TripleStore(n_triples={self.n_triples}, n_nodes={self.n_nodes})"

"""Dictionary-encoded RDF triple store.

The paper (Karim et al. 2020) operates on RDF graphs ``G = (V, E, L)``
(Def. 4.2).  Like every production RDF engine (HDT, k2-triples, ...), we
dictionary-encode terms at ingest: URIs / literals become dense int32 ids, and
the graph is a single ``(n, 3)`` COO array of ``(subject, property, object)``
ids.  All downstream computation (multiplicity, AMI, #Edges, factorization)
is vectorized over these arrays, which is also the layout we ship to device.

Access paths are served by a lazily-built :class:`repro.core.index.GraphIndex`
(per-predicate CSR slices over a (p, s, o)-sorted copy): class membership,
class schema, object-matrix extraction and edge counting are index joins,
not full-graph scans.  The index survives ``copy()`` and is *merged* --
not rebuilt -- on ``add_ids``, so streaming appends (``Compactor.update``)
never re-sort the whole graph.

Two ids are reserved with well-known terms:
  * ``rdf:type``           -- the class-membership property (paper: "type")
  * ``repro:instanceOf``   -- the surrogate-link property added by
                              factorization (paper Def. 4.10/4.11)
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .index import (GraphIndex, SPO_PERM, in_sorted, merge_disjoint,
                    setdiff_rows, sort_unique)

RDF_TYPE = "rdf:type"
INSTANCE_OF = "repro:instanceOf"


class TermDict:
    """Bidirectional term <-> id dictionary (host side)."""

    __slots__ = ("_terms", "_index")

    def __init__(self) -> None:
        self._terms: list[str] = []
        self._index: dict[str, int] = {}

    def id(self, term: str) -> int:
        """Return the id of ``term``, allocating one if unseen."""
        i = self._index.get(term)
        if i is None:
            i = len(self._terms)
            self._index[term] = i
            self._terms.append(term)
        return i

    def ids(self, terms: Sequence[str]) -> np.ndarray:
        """Bulk id allocation: the batched counterpart of :meth:`id`.

        Unseen terms receive a contiguous id block appended in one shot
        (one list ``extend`` + one dict ``update`` instead of per-term
        lookup/append/insert round-trips) -- the surrogate-minting path of
        Algorithm 3 allocates one id per star pattern and dominates
        factorization setup time at scale (benchmarked in
        ``benchmarks/bench_savings.py``).

        Returns int32, matching ``TripleStore.spo``: minted ids flow
        straight into triple rows (``from_ids`` / ``add_ids``) and a wider
        dtype would silently upcast every downstream concatenation.
        """
        index = self._index
        missing = dict.fromkeys(t for t in terms if t not in index)
        if missing:
            base = len(self._terms)
            self._terms.extend(missing)
            index.update(zip(missing, range(base, base + len(missing))))
        return np.fromiter((index[t] for t in terms), np.int32,
                           count=len(terms))

    @classmethod
    def from_terms(cls, terms: Iterable[str]) -> "TermDict":
        """Rebuild a dictionary from its term list, ids = positions.

        The recovery path (``repro.online.recovery``) checkpoints the
        dictionary as the ordered term list alone -- ids are implied by
        allocation order, so restoring the exact list restores the
        exact id assignment."""
        d = cls()
        d._terms = list(terms)
        d._index = {t: i for i, t in enumerate(d._terms)}
        if len(d._index) != len(d._terms):
            raise ValueError("duplicate terms in from_terms input")
        return d

    def lookup(self, term: str) -> int | None:
        return self._index.get(term)

    def term(self, i: int) -> str:
        return self._terms[i]

    def __len__(self) -> int:
        return len(self._terms)

    def __contains__(self, term: str) -> bool:
        return term in self._index

    def nbytes(self) -> int:
        """Approximate resident bytes of the term storage: per-string
        UTF-8 payload plus CPython object + dict-slot overhead.  The
        uncompressed-tier denominator for the dictionary share of
        ``substrate_nbytes``."""
        # ~49 bytes str object header + ~104 bytes amortized dict entry
        # (key slot in _index + list slot in _terms), measured on CPython
        # 3.11 via sys.getsizeof over the bench dictionaries
        payload = sum(len(t.encode("utf-8")) for t in self._terms)
        return payload + 153 * len(self._terms)


@dataclasses.dataclass
class ClassStats:
    """Per-class statistics used throughout the paper's formulas."""

    class_id: int
    n_instances: int          # AM_G(C) -- Def. 4.8
    properties: np.ndarray    # sorted property ids with domain C (excl. type)


class TripleStore:
    """An RDF graph as dictionary-encoded COO triples.

    ``spo`` is an ``(n, 3)`` int32 array; row ``(s, p, o)`` is the RDF triple
    / labeled edge of Def. 4.1/4.2.  Duplicate triples are removed (an RDF
    graph is a *set* of triples) and rows are kept sorted by (s, p, o) --
    the invariant that lets appends merge instead of re-sort.
    """

    def __init__(self, dictionary: TermDict | None = None,
                 spo: np.ndarray | None = None, *,
                 presorted: bool = False) -> None:
        self._index: GraphIndex | None = None
        self.dict = dictionary if dictionary is not None else TermDict()
        self.TYPE = self.dict.id(RDF_TYPE)
        self.INSTANCE_OF = self.dict.id(INSTANCE_OF)
        if spo is None:
            spo = np.empty((0, 3), dtype=np.int32)
        spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
        # ``presorted=True``: caller guarantees sorted-unique (s, p, o)
        # rows (e.g. a row-subset of another store) -- skip the dedup sort
        self._spo = spo if presorted else sort_unique(spo, SPO_PERM)

    # -- storage invariants ------------------------------------------------
    @property
    def spo(self) -> np.ndarray:
        return self._spo

    @spo.setter
    def spo(self, rows: np.ndarray) -> None:
        # rebinding the triple array invalidates the index (callers that
        # append should prefer ``add_ids``, which merges instead)
        self._spo = sort_unique(np.asarray(rows, np.int32).reshape(-1, 3),
                                SPO_PERM)
        self._index = None

    @property
    def index(self) -> GraphIndex:
        """The lazily-built per-predicate CSR index over ``spo``."""
        if self._index is None:
            self._index = GraphIndex(self._spo, self.TYPE, self.INSTANCE_OF)
        return self._index

    # -- construction ------------------------------------------------------
    @classmethod
    def from_triples(cls, triples: Iterable[tuple[str, str, str]]) -> "TripleStore":
        store = cls()
        d = store.dict
        rows = [(d.id(s), d.id(p), d.id(o)) for s, p, o in triples]
        store.spo = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        return store

    @classmethod
    def from_ids(cls, dictionary: TermDict, spo: np.ndarray, *,
                 presorted: bool = False) -> "TripleStore":
        return cls(dictionary, spo, presorted=presorted)

    def add_ids(self, rows: np.ndarray) -> None:
        """Append triples, preserving the sorted-unique invariant by
        *merging*: the incoming block is locally sorted/deduped, rows
        already present are dropped with a binary-search pass, and the
        disjoint remainder merges in O(n + m log n) -- no ``np.unique``
        over the combined graph.  A live index is merged incrementally."""
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, 3)
        if rows.shape[0] == 0:
            return
        if self._spo.shape[0] == 0:
            self._spo = sort_unique(rows, SPO_PERM)
            self._index = None
            return
        fresh = setdiff_rows(sort_unique(rows, SPO_PERM), self._spo, SPO_PERM)
        if fresh.shape[0] == 0:
            return
        self._spo = merge_disjoint(self._spo, fresh, SPO_PERM)
        if self._index is not None:
            self._index = self._index.merged(fresh)

    def restrict_subjects(self, subjects: np.ndarray) -> "TripleStore":
        """Subgraph of triples whose subject is in ``subjects`` (shared
        dictionary) -- the paper evaluates each observation type as its
        own graph.  A row-subset of a sorted-unique array stays
        sorted-unique, so the result skips the dedup pass entirely."""
        subjects = np.unique(np.asarray(subjects).ravel())
        mask = in_sorted(self._spo[:, 0], subjects)
        return TripleStore.from_ids(self.dict, self._spo[mask],
                                    presorted=True)

    # -- size metrics (paper §5, "Metrics") --------------------------------
    def substrate_nbytes(self, include_dict: bool = True) -> int:
        """Deterministic resident-bytes accounting of the serving
        substrate: triple rows + CSR index (built if absent) + term
        dictionary.  The bytes-per-triple bench column compares this
        across tiers -- unlike RSS it is allocator- and GC-independent."""
        total = int(self._spo.nbytes) + self.index.nbytes()
        if include_dict:
            total += self.dict.nbytes()
        return total

    def compressed(self, *, max_resident: int = 8,
                   compact_dict: bool = True) -> "TripleStore":
        """This graph re-hosted on the compressed tier (bit-packed
        delta-encoded CSR partitions + front-coded dictionary) behind
        the same accessor surface.  Ids are preserved, so detect/query
        results and digests are identical."""
        from .compress import compress_store
        return compress_store(self, max_resident=max_resident,
                              compact_dict=compact_dict)

    @property
    def is_compressed(self) -> bool:
        """Tier predicate: ``True`` on the compressed tier (overridden
        there) -- mutation paths use it to migrate instead of repacking
        per batch."""
        return False

    @property
    def n_triples(self) -> int:
        return int(self._spo.shape[0])

    def nodes(self) -> np.ndarray:
        """Distinct entity/object nodes (NN numerator)."""
        if not len(self._spo):
            return np.empty((0,), np.int32)
        return np.unique(np.concatenate([self._spo[:, 0], self._spo[:, 2]]))

    @property
    def n_nodes(self) -> int:
        return int(self.nodes().shape[0])

    @property
    def size(self) -> int:
        """Graph size = #nodes + #edges (paper §5 'Metrics')."""
        return self.n_nodes + self.n_triples

    # -- class / schema access ---------------------------------------------
    def entities_of_class(self, class_id: int) -> np.ndarray:
        return self.index.entities_of_class(int(class_id))

    def classes(self) -> np.ndarray:
        return self.index.classes()

    def class_properties(self, class_id: int) -> np.ndarray:
        """Sorted property ids whose domain includes class C (excl. type &
        instanceOf)."""
        return self.index.class_properties(int(class_id))

    def class_stats(self, class_id: int) -> ClassStats:
        ents = self.entities_of_class(class_id)
        return ClassStats(class_id=class_id, n_instances=int(ents.shape[0]),
                          properties=self.class_properties(class_id))

    # -- molecule access -----------------------------------------------------
    def object_matrix(self, class_id: int, props: Sequence[int],
                      strict: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """Entities x objects matrix for a (class, property-set) pair.

        Returns ``(entities, objmat)`` with ``objmat[i, j]`` = object of
        ``props[j]`` on ``entities[i]``.  The paper's algorithms assume RDF
        molecules are *complete* (every entity has a value for every property)
        and properties are *functional* (one value each) -- assumption (a)/(b)
        of §4.3.  We validate: entities violating either assumption are
        excluded from the candidate set (``strict=True`` raises instead).
        Served by per-predicate index joins (see ``core.index``).
        """
        return self.index.object_matrix(int(class_id), props, strict=strict)

    def labeled_edge_count(self, class_id: int,
                           props: Sequence[int] | None = None) -> int:
        """NLE: labeled edges annotated with class properties (paper §5)."""
        return self.index.labeled_edge_count(int(class_id), props)

    # -- convenience ---------------------------------------------------------
    def triples_as_terms(self) -> list[tuple[str, str, str]]:
        t = self.dict.term
        return [(t(s), t(p), t(o)) for s, p, o in self._spo.tolist()]

    def copy(self) -> "TripleStore":
        new = TripleStore.__new__(TripleStore)
        new.dict = self.dict          # term dict is shared (append-only)
        new.TYPE = self.TYPE
        new.INSTANCE_OF = self.INSTANCE_OF
        new._spo = self._spo.copy()
        new._index = self._index      # immutable: valid for equal rows
        return new

    def __repr__(self) -> str:  # pragma: no cover
        return f"TripleStore(n_triples={self.n_triples}, n_nodes={self.n_nodes})"

"""G.FSP result type and the deprecated free-function entry point.

The greedy descent itself (Algorithm 2) lives in
``repro.api.detectors.GreedyDetector``; candidate-subset execution is a
pluggable ``repro.api.backends.ExecutionBackend`` ("host" numpy loop /
"device" batched jax sweep / "sharded" mesh sweep), which replaced the
``device_sweep=`` boolean this module used to carry.

Evaluation accounting note (fixed with the API move): the seed's host
loop charged one evaluation per actually-evaluated child and broke early
on an AMI == 1 candidate, while the device sweep always charged
``len(SP)`` -- so ``FSPResult.evaluations`` disagreed between backends.
Backends now charge identically: ``len(SP)`` per executed sweep, 0 when
children would be sub-star (``|SP'| < 2``), making the counter
backend-invariant (asserted in tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import numpy as np

from .triples import TripleStore


@dataclasses.dataclass
class FSPResult:
    """Outcome of an FSP detection run (any detector)."""

    class_id: int
    props: tuple[int, ...]          # best SP
    edges: int                      # #Edges(SP, C, G)
    ami: int                        # number of frequent star patterns
    am: int                         # AM_G(C)
    iterations: int                 # property-set iterations (PSIterations)
    evaluations: int                # subset evaluations performed
    exec_time_ms: float
    fsp: list[tuple[np.ndarray, np.ndarray]]  # star patterns: (entities, objects)

    @property
    def n_fsp(self) -> int:
        return len(self.fsp)


def gfsp(store: TripleStore, class_id: int,
         props: Sequence[int] | None = None,
         device_sweep: bool = False) -> FSPResult:
    """Deprecated shim: use ``repro.api.Compactor(detector="gfsp",
    backend=...)`` / ``repro.api.GreedyDetector``.

    ``device_sweep=True`` maps to the "device" execution backend.
    """
    warnings.warn(
        "repro.core.gfsp() is deprecated; use repro.api.Compactor("
        "detector='gfsp', backend='device' or 'host').detect(...)",
        DeprecationWarning, stacklevel=2)
    from repro.api import GreedyDetector, get_backend
    backend = get_backend("device" if device_sweep else "host")
    return GreedyDetector().detect(store, class_id, backend=backend,
                                   props=props)

"""G.FSP -- Algorithm 2: greedy frequent-star-pattern detection.

Starting from ``SP = S`` (all properties of class C), each sweep evaluates
every one-property-removed subset ``SP' = SP - {p}`` and keeps the subset
with the lowest ``#Edges(SP', C, G)``.  The descent stops when

  * no subset improves on the current ``#Edges(SP, C, G)``  (Theorem 4.1
    guarantees no deeper subset can improve either), or
  * ``AMI_G(SP|C) == 1``  (a single star pattern -- cannot get more frequent), or
  * ``|SP| < 2``          (star patterns need >= 2 properties).

The published pseudocode initializes the per-sweep best value ``fValue'`` to
0 and tests ``value < fValue'``, which as written never admits a candidate;
we implement the evidently intended semantics (per-sweep best = min over
candidates, accept iff it strictly improves).  Ties are broken by first
candidate encountered -- assumption (c) of §4.3.

Worst case: ``sum_{i=0..n} (n - i) = n(n+1)/2`` subset evaluations (paper
§4.3), each a single group-by -- vs E.FSP's 2^n.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from .star import StarSweepResult, evaluate_subset, star_groups
from .triples import TripleStore


@dataclasses.dataclass
class FSPResult:
    """Outcome of an FSP detection run (either algorithm)."""

    class_id: int
    props: tuple[int, ...]          # best SP
    edges: int                      # #Edges(SP, C, G)
    ami: int                        # number of frequent star patterns
    am: int                         # AM_G(C)
    iterations: int                 # property-set iterations (PSIterations)
    evaluations: int                # subset evaluations performed
    exec_time_ms: float
    fsp: list[tuple[np.ndarray, np.ndarray]]  # star patterns: (entities, objects)

    @property
    def n_fsp(self) -> int:
        return len(self.fsp)


def gfsp(store: TripleStore, class_id: int,
         props: Sequence[int] | None = None,
         device_sweep: bool = False) -> FSPResult:
    """Run G.FSP for ``class_id``.

    ``props``: optional explicit S (defaults to all class properties).
    ``device_sweep``: evaluate each sweep's candidate subsets as one batched
    jax computation (TPU path) instead of the paper's sequential host loop.
    """
    t0 = time.perf_counter()
    stats = store.class_stats(class_id)
    s_all = (np.asarray(list(props), np.int32)
             if props is not None else stats.properties)
    n_s = int(s_all.shape[0])
    am = stats.n_instances

    sp = tuple(int(p) for p in s_all)
    iterations = 0
    evaluations = 0

    def _finish(best: StarSweepResult) -> FSPResult:
        fsp = star_groups(store, class_id, best.props)
        return FSPResult(
            class_id=class_id, props=best.props, edges=best.edges,
            ami=best.ami, am=am, iterations=iterations,
            evaluations=evaluations,
            exec_time_ms=(time.perf_counter() - t0) * 1e3, fsp=fsp)

    if n_s == 0 or am == 0:
        empty = StarSweepResult(props=(), ami=0, am=am,
                                n_total_props=n_s, edges=0)
        return _finish(empty)

    current = evaluate_subset(store, class_id, sp, n_s, am)
    evaluations += 1
    while True:
        iterations += 1
        if len(current.props) < 2 or current.is_single_pattern:
            return _finish(current)
        best_child: StarSweepResult | None = None
        if device_sweep and len(current.props) >= 3:
            best_child = _device_sweep(store, class_id, current, n_s, am)
            evaluations += len(current.props)
        else:
            for p in current.props:
                child_props = tuple(q for q in current.props if q != p)
                if len(child_props) < 2:
                    continue
                child = evaluate_subset(store, class_id, child_props, n_s, am)
                evaluations += 1
                if child.is_single_pattern:
                    best_child = child
                    break
                if best_child is None or child.edges < best_child.edges:
                    best_child = child
        if best_child is None or best_child.edges >= current.edges:
            # no strict improvement -> Theorem 4.1 prunes everything deeper
            if best_child is not None and best_child.is_single_pattern \
                    and best_child.edges < current.edges:
                current = best_child
            return _finish(current)
        current = best_child


def _device_sweep(store: TripleStore, class_id: int,
                  current: StarSweepResult, n_s: int, am: int
                  ) -> StarSweepResult:
    """Batched one-sweep candidate evaluation on device (beyond-paper path)."""
    import jax.numpy as jnp  # noqa: F401  (device path)
    from .star import sweep_drop_one_device

    props = np.asarray(current.props, np.int32)
    _, objmat = store.object_matrix(class_id, props)
    edges, amis = sweep_drop_one_device(jnp.asarray(objmat), am, n_s)
    edges = np.asarray(edges)
    amis = np.asarray(amis)
    # prefer an AMI==1 candidate (paper line 14-18), else the min-edges one
    single = np.where(amis == 1)[0]
    j = int(single[0]) if single.size else int(np.argmin(edges))
    child_props = tuple(int(p) for i, p in enumerate(current.props) if i != j)
    return StarSweepResult(props=child_props, ami=int(amis[j]), am=am,
                           n_total_props=n_s, edges=int(edges[j]))

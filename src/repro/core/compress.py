"""Compressed graph substrate: bit-packed delta CSRs + front-coded terms.

The uncompressed tier holds every triple three times as int32 -- once in
``TripleStore.spo`` (s, p, o order), once in the ``GraphIndex`` copy
(p, s, o order), plus a Python ``list``/``dict`` pair for the term
dictionary -- ~24 bytes of array per triple before the dictionary even
starts counting.  That is fine at the paper's 36k-triple bench scale and
hopeless at the 10M+ scale where Def. 4.8 savings become megabytes.
k2-triples and HDT (Alvarez-Garcia et al., PAPERS.md) hold billion-edge
RDF graphs in RAM with exactly two moves, both reproduced here:

* **bit-packed, delta-encoded vertical partitions** -- inside one
  predicate's CSR extent the subject column is non-decreasing, so it is
  stored as block-anchored deltas; the object column is stored at the
  partition's own bit width.  The id columns of a 1M-triple graph need
  ~20 bits, deltas usually < 8 -- 4-7 bytes/triple instead of 24.
* **front-coded term storage** -- terms are sorted once and stored as
  (shared-prefix-length, suffix) runs in bucketed blocks; ``lookup`` is
  a binary search over bucket heads, ``term(id)`` decodes one bucket.
  No Python ``str`` objects are retained for the base vocabulary.

Everything decodes **on slice**: :class:`CompressedGraphIndex` answers
the exact accessor surface the sweep engine and the query engines
already consume (``entities_of_class`` / ``object_matrix`` /
``pred_objects_sorted`` / ``pred_slice`` / ...), materializing one
predicate partition at a time through a small LRU of resident decodes
(``max_resident``), so detection streams classes through the bucket
ladder with peak transient memory bounded by the largest class's
partitions + its object matrix -- never by the graph.

Mutation migrates tiers: ``filtered``/``merged``/``add_ids`` decode,
apply the plain-tier transform, and re-compress (or hand back a plain
structure where the caller immediately rebuilds).  The compressed tier
is the *read-mostly serving substrate*; writers recompress at snapshot
boundaries.

``DECODE_STATS`` counts partitions/values decoded and the peak resident
decoded bytes -- the scale bench records it as evidence that streamed
detection never holds the whole graph uncompressed.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Sequence

import numpy as np

from .index import (GraphIndex, PSO_PERM, SPO_PERM, _key_view, csr_take,
                    in_sorted, sort_unique)
from .triples import TermDict, TripleStore

# -- decode accounting --------------------------------------------------------

DECODE_STATS = {
    "partitions": 0,          # partition decodes (LRU misses)
    "values": 0,              # total values decoded
    "resident_bytes": 0,      # currently resident decoded bytes (LRU)
    "peak_resident_bytes": 0,  # high-water mark of the above
}


def reset_decode_stats() -> None:
    DECODE_STATS["partitions"] = 0
    DECODE_STATS["values"] = 0
    DECODE_STATS["resident_bytes"] = 0
    DECODE_STATS["peak_resident_bytes"] = 0


def _note_decode(n_values: int) -> None:
    DECODE_STATS["partitions"] += 1
    DECODE_STATS["values"] += int(n_values)


def _note_resident(delta_bytes: int) -> None:
    DECODE_STATS["resident_bytes"] += int(delta_bytes)
    if DECODE_STATS["resident_bytes"] > DECODE_STATS["peak_resident_bytes"]:
        DECODE_STATS["peak_resident_bytes"] = DECODE_STATS["resident_bytes"]


# -- fixed-width bit packing --------------------------------------------------

def bit_width(max_value: int) -> int:
    """Bits needed for values in [0, max_value] (>= 1 so empty/zero
    columns stay addressable)."""
    return max(int(max_value).bit_length(), 1)


class PackedInts:
    """Fixed-width bit-packed non-negative integers.

    Value ``i`` occupies bits ``[i*bits, (i+1)*bits)`` of ``data``
    (MSB-first within each value) -- the flat layout every HDT-family
    engine uses.  ``slice_()`` decodes a contiguous range,
    ``take()`` gathers arbitrary indices; both touch only the bytes the
    requested values span.
    """

    __slots__ = ("data", "bits", "n")

    def __init__(self, data: np.ndarray, bits: int, n: int) -> None:
        self.data = data              # uint8 byte stream
        self.bits = int(bits)
        self.n = int(n)

    @classmethod
    def pack(cls, values: np.ndarray, bits: int | None = None
             ) -> "PackedInts":
        values = np.asarray(values, np.int64).reshape(-1)
        if values.size and values.min() < 0:
            raise ValueError("PackedInts stores non-negative values only")
        if bits is None:
            bits = bit_width(int(values.max()) if values.size else 0)
        shifts = np.arange(bits - 1, -1, -1, dtype=np.uint64)
        bitmat = ((values.astype(np.uint64)[:, None] >> shifts) & 1
                  ).astype(np.uint8)
        return cls(np.packbits(bitmat.ravel()), bits, values.size)

    @property
    def nbytes(self) -> int:
        return int(self.data.nbytes)

    def __len__(self) -> int:
        return self.n

    def slice_(self, start: int = 0, stop: int | None = None) -> np.ndarray:
        """Decode values [start, stop) as int64."""
        stop = self.n if stop is None else min(int(stop), self.n)
        start = int(start)
        count = max(stop - start, 0)
        if count == 0:
            return np.empty((0,), np.int64)
        b = self.bits
        bit_lo, bit_hi = start * b, stop * b
        byte_lo, byte_hi = bit_lo // 8, (bit_hi + 7) // 8
        bits = np.unpackbits(self.data[byte_lo:byte_hi])
        off = bit_lo - 8 * byte_lo
        bits = bits[off:off + count * b].reshape(count, b)
        weights = (np.int64(1) << np.arange(b - 1, -1, -1)).astype(np.int64)
        return bits.astype(np.int64) @ weights

    def take(self, idx: np.ndarray) -> np.ndarray:
        """Gather arbitrary indices (int64 out) -- the compressed
        counterpart of ``rows[idx]`` fancy indexing / ``csr_take``
        gathers, touching only the spanned bytes of each value."""
        idx = np.asarray(idx, np.int64).reshape(-1)
        if idx.size == 0:
            return np.empty((0,), np.int64)
        b = self.bits
        # each value spans <= ceil(b/8) + 1 bytes; accumulate that many
        # bytes into one uint64 window, then shift the value out
        span = b // 8 + 2
        bit_lo = idx * b
        byte_lo = bit_lo // 8
        window = np.zeros(idx.shape, np.uint64)
        nbytes_total = self.data.shape[0]
        for j in range(span):
            bj = byte_lo + j
            valid = bj < nbytes_total
            byte = np.where(valid, self.data[np.minimum(bj,
                                                        nbytes_total - 1)], 0)
            window = (window << np.uint64(8)) | byte.astype(np.uint64)
        # value sits ``tail`` bits above the window's low end
        tail = (np.uint64(8) * np.uint64(span)
                - (bit_lo - byte_lo * 8).astype(np.uint64)
                - np.uint64(b))
        mask = np.uint64((1 << b) - 1) if b < 64 else ~np.uint64(0)
        return ((window >> tail) & mask).astype(np.int64)


class DeltaPacked:
    """Non-decreasing int column as block-anchored bit-packed deltas.

    Every ``block`` values an absolute anchor is stored (int64), between
    anchors only the successive differences at their maximal bit width.
    ``slice_`` decodes from the nearest anchor -- O(block + count) work
    regardless of position.
    """

    __slots__ = ("anchors", "deltas", "block", "n")

    def __init__(self, anchors, deltas, block, n) -> None:
        self.anchors = anchors
        self.deltas = deltas
        self.block = int(block)
        self.n = int(n)

    @classmethod
    def pack(cls, values: np.ndarray, block: int = 1024) -> "DeltaPacked":
        values = np.asarray(values, np.int64).reshape(-1)
        n = values.size
        if n == 0:
            return cls(np.empty((0,), np.int64),
                       PackedInts.pack(np.empty((0,), np.int64)), block, 0)
        diffs = np.diff(values)
        if diffs.size and diffs.min() < 0:
            raise ValueError("DeltaPacked requires a non-decreasing column")
        anchors = values[::block].copy()
        # anchor positions restart each block: zero the crossing diffs
        dd = diffs.copy()
        dd[block - 1::block] = 0
        return cls(anchors, PackedInts.pack(dd), block, n)

    @property
    def nbytes(self) -> int:
        return int(self.anchors.nbytes) + self.deltas.nbytes

    def __len__(self) -> int:
        return self.n

    def slice_(self, start: int = 0, stop: int | None = None) -> np.ndarray:
        stop = self.n if stop is None else min(int(stop), self.n)
        start = int(start)
        if stop <= start:
            return np.empty((0,), np.int64)
        b0, b1 = start // self.block, (stop - 1) // self.block
        lo = b0 * self.block
        # decode whole blocks [lo, stop): anchor + cumsum of in-block diffs
        out = np.empty((stop - lo,), np.int64)
        d = self.deltas.slice_(lo, stop - 1) if stop - 1 > lo \
            else np.empty((0,), np.int64)
        for bi in range(b0, b1 + 1):
            blo = bi * self.block
            bhi = min(blo + self.block, stop)
            seg = out[blo - lo:bhi - lo]
            seg[0] = self.anchors[bi]
            if bhi - blo > 1:
                seg[1:] = self.anchors[bi] + np.cumsum(
                    d[blo - lo:bhi - 1 - lo])
        return out[start - lo:]


# -- front-coded term storage -------------------------------------------------

class FrontCodedTerms:
    """Sorted, bucketed, front-coded immutable string pool.

    Bucket heads are stored whole; every other term as (lcp, suffix)
    against its predecessor.  ``find`` binary-searches bucket heads and
    walks at most one bucket; ``get`` decodes one bucket prefix chain.
    All storage is one ``bytes`` blob + int32/int64 offset arrays -- no
    per-term Python objects.
    """

    __slots__ = ("blob", "bucket_offsets", "bucket", "n", "_heads")

    def __init__(self, blob: bytes, bucket_offsets: np.ndarray,
                 bucket: int, n: int) -> None:
        self.blob = blob
        self.bucket_offsets = bucket_offsets
        self.bucket = int(bucket)
        self.n = int(n)
        self._heads: list[bytes] | None = None   # lazy head cache

    @staticmethod
    def _varint(x: int) -> bytes:
        out = bytearray()
        while True:
            b = x & 0x7F
            x >>= 7
            if x:
                out.append(b | 0x80)
            else:
                out.append(b)
                return bytes(out)

    @staticmethod
    def _read_varint(blob, pos: int) -> tuple[int, int]:
        shift = x = 0
        while True:
            b = blob[pos]
            pos += 1
            x |= (b & 0x7F) << shift
            if not b & 0x80:
                return x, pos
            shift += 7

    @classmethod
    def encode(cls, sorted_terms: Sequence[str], bucket: int = 16
               ) -> "FrontCodedTerms":
        blob = bytearray()
        offsets = []
        prev = b""
        for i, t in enumerate(sorted_terms):
            enc = t.encode("utf-8")
            if i % bucket == 0:
                offsets.append(len(blob))
                blob += cls._varint(len(enc))
                blob += enc
            else:
                lcp = 0
                m = min(len(prev), len(enc))
                while lcp < m and prev[lcp] == enc[lcp]:
                    lcp += 1
                blob += cls._varint(lcp)
                blob += cls._varint(len(enc) - lcp)
                blob += enc[lcp:]
            prev = enc
        return cls(bytes(blob), np.asarray(offsets, np.int64), bucket,
                   len(sorted_terms))

    @property
    def nbytes(self) -> int:
        return len(self.blob) + int(self.bucket_offsets.nbytes)

    def __len__(self) -> int:
        return self.n

    def _head(self, bi: int) -> bytes:
        if self._heads is None:
            self._heads = [None] * self.bucket_offsets.shape[0]
        h = self._heads[bi]
        if h is None:
            ln, pos = self._read_varint(self.blob, int(
                self.bucket_offsets[bi]))
            h = self.blob[pos:pos + ln]
            self._heads[bi] = h
        return h

    def _walk(self, bi: int):
        """Yield (rank, decoded bytes) over bucket ``bi``."""
        pos = int(self.bucket_offsets[bi])
        ln, pos = self._read_varint(self.blob, pos)
        cur = self.blob[pos:pos + ln]
        pos += ln
        base = bi * self.bucket
        yield base, cur
        hi = min(base + self.bucket, self.n)
        for r in range(base + 1, hi):
            lcp, pos = self._read_varint(self.blob, pos)
            sln, pos = self._read_varint(self.blob, pos)
            cur = cur[:lcp] + self.blob[pos:pos + sln]
            pos += sln
            yield r, cur

    def get(self, rank: int) -> str:
        """Decode the term at sorted position ``rank``."""
        if not 0 <= rank < self.n:
            raise IndexError(rank)
        bi = rank // self.bucket
        for r, cur in self._walk(bi):
            if r == rank:
                return cur.decode("utf-8")
        raise AssertionError("unreachable")

    def find(self, term: str) -> int | None:
        """Sorted position of ``term``, or None."""
        if self.n == 0:
            return None
        enc = term.encode("utf-8")
        lo, hi = 0, self.bucket_offsets.shape[0] - 1
        # rightmost bucket whose head <= enc
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self._head(mid) <= enc:
                lo = mid
            else:
                hi = mid - 1
        if self._head(lo) > enc:
            return None
        for r, cur in self._walk(lo):
            if cur == enc:
                return r
            if cur > enc:
                return None
        return None


class CompactTermDict:
    """Front-coded drop-in for :class:`~repro.core.triples.TermDict`.

    Ids are preserved exactly from the dictionary it compacts (they are
    baked into every triple row), so the sorted front-coded pool carries
    two int32 permutations: ``id -> rank`` and ``rank -> id``.  New
    terms minted after compaction (surrogates, streamed inserts) go to a
    small mutable tail with ordinary list/dict storage -- the base
    vocabulary stays compressed forever.
    """

    __slots__ = ("_pool", "_id2rank", "_rank2id", "_tail_terms",
                 "_tail_index", "_base")

    def __init__(self, pool: FrontCodedTerms, id2rank: np.ndarray,
                 rank2id: np.ndarray) -> None:
        self._pool = pool
        self._id2rank = id2rank
        self._rank2id = rank2id
        self._base = int(id2rank.shape[0])
        self._tail_terms: list[str] = []
        self._tail_index: dict[str, int] = {}

    @classmethod
    def from_dict(cls, d, bucket: int = 16) -> "CompactTermDict":
        terms = [d.term(i) for i in range(len(d))]
        # sort by ENCODED bytes: ``find`` compares UTF-8, and python str
        # order diverges from byte order outside ASCII
        order = sorted(range(len(terms)),
                       key=lambda i: terms[i].encode("utf-8"))
        rank2id = np.asarray(order, np.int32)
        id2rank = np.empty((len(terms),), np.int32)
        id2rank[rank2id] = np.arange(len(terms), dtype=np.int32)
        pool = FrontCodedTerms.encode([terms[i] for i in order], bucket)
        return cls(pool, id2rank, rank2id)

    # -- TermDict surface --------------------------------------------------
    def lookup(self, term: str) -> int | None:
        r = self._pool.find(term)
        if r is not None:
            return int(self._rank2id[r])
        i = self._tail_index.get(term)
        return None if i is None else self._base + i

    def id(self, term: str) -> int:
        i = self.lookup(term)
        if i is None:
            i = self._base + len(self._tail_terms)
            self._tail_index[term] = len(self._tail_terms)
            self._tail_terms.append(term)
        return i

    def ids(self, terms: Sequence[str]) -> np.ndarray:
        return np.fromiter((self.id(t) for t in terms), np.int32,
                           count=len(terms))

    def term(self, i: int) -> str:
        i = int(i)
        if i < self._base:
            return self._pool.get(int(self._id2rank[i]))
        return self._tail_terms[i - self._base]

    def __len__(self) -> int:
        return self._base + len(self._tail_terms)

    def __contains__(self, term: str) -> bool:
        return self.lookup(term) is not None

    def nbytes(self) -> int:
        tail = sum(len(t) for t in self._tail_terms) \
            + 64 * len(self._tail_terms)
        return self._pool.nbytes + int(self._id2rank.nbytes) \
            + int(self._rank2id.nbytes) + tail


# -- compressed columns of a sorted triple array ------------------------------

class _CompressedRows:
    """(n, 3) sorted rows as three packed columns: the leading sort key
    delta-packed (non-decreasing), the others at fixed width."""

    __slots__ = ("lead", "mid", "trail", "perm", "n")

    def __init__(self, rows: np.ndarray, perm) -> None:
        rows = np.asarray(rows, np.int64).reshape(-1, 3)
        self.perm = tuple(perm)
        self.n = int(rows.shape[0])
        a, b, c = (rows[:, j] for j in self.perm)
        self.lead = DeltaPacked.pack(a)
        self.mid = PackedInts.pack(b)
        self.trail = PackedInts.pack(c)

    @property
    def nbytes(self) -> int:
        return self.lead.nbytes + self.mid.nbytes + self.trail.nbytes

    def decode(self) -> np.ndarray:
        out = np.empty((self.n, 3), np.int32)
        out[:, self.perm[0]] = self.lead.slice_()
        out[:, self.perm[1]] = self.mid.slice_()
        out[:, self.perm[2]] = self.trail.slice_()
        _note_decode(3 * self.n)
        return out


# -- the compressed index -----------------------------------------------------

class CompressedGraphIndex(GraphIndex):
    """Per-predicate CSR index with bit-packed delta-encoded columns.

    Same accessor surface and *identical results* as
    :class:`~repro.core.index.GraphIndex` (property-tested), but the
    (p, s, o)-sorted row copy is never materialized: each predicate
    partition stores its subject column as block-anchored deltas and its
    object column at the partition's bit width, decoding on slice
    through an LRU of at most ``max_resident`` resident partitions.

    ``filtered``/``merged`` decode and hand back a *plain*
    ``GraphIndex`` -- mutation migrates to the uncompressed tier, and
    writers recompress at snapshot boundaries (``compress_store``).
    """

    __slots__ = ("_sub_parts", "_obj_parts", "max_resident", "_resident")

    def __init__(self, spo: np.ndarray, type_id: int, instance_of_id: int,
                 *, _presorted: bool = False,
                 max_resident: int | None = 8) -> None:
        rows = np.ascontiguousarray(spo, dtype=np.int32).reshape(-1, 3)
        if not _presorted and rows.shape[0] > 1:
            order = np.argsort(_key_view(rows, PSO_PERM), kind="stable")
            rows = rows[order]
        self.type_id = int(type_id)
        self.instance_of_id = int(instance_of_id)
        if rows.shape[0]:
            self.preds, first = np.unique(rows[:, 1], return_index=True)
            self.starts = np.append(first, rows.shape[0])
        else:
            self.preds = np.empty((0,), np.int32)
            self.starts = np.zeros((1,), np.int64)
        self._sub_parts: list[DeltaPacked] = []
        self._obj_parts: list[PackedInts] = []
        for i in range(self.preds.shape[0]):
            part = rows[self.starts[i]:self.starts[i + 1]]
            self._sub_parts.append(DeltaPacked.pack(part[:, 0]))
            self._obj_parts.append(PackedInts.pack(part[:, 2]))
        self.max_resident = max_resident
        self._resident: OrderedDict[int, tuple[np.ndarray, np.ndarray]] = \
            OrderedDict()
        self._ents_cache = {}
        self._props_cache = {}
        self._classes_cache = None
        self._objsort_cache = {}

    # -- storage accounting ------------------------------------------------
    def nbytes(self) -> int:
        total = int(self.preds.nbytes) + int(self.starts.nbytes)
        for sp, op in zip(self._sub_parts, self._obj_parts):
            total += sp.nbytes + op.nbytes
        return total

    @property
    def n_rows(self) -> int:
        return int(self.starts[-1])

    # -- decode-on-slice ---------------------------------------------------
    def _partition(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """Decoded (subjects, objects) of partition ``i`` through the
        resident LRU."""
        hit = self._resident.get(i)
        if hit is not None:
            self._resident.move_to_end(i)
            return hit
        subs = self._sub_parts[i].slice_()
        objs = self._obj_parts[i].slice_()
        _note_decode(subs.size + objs.size)
        self._resident[i] = (subs, objs)
        _note_resident(subs.nbytes + objs.nbytes)
        if self.max_resident is not None:
            while len(self._resident) > self.max_resident:
                _, (es, eo) = self._resident.popitem(last=False)
                _note_resident(-(es.nbytes + eo.nbytes))
        return subs, objs

    def release_resident(self) -> None:
        """Drop every resident decoded partition (stream boundary)."""
        for subs, objs in self._resident.values():
            _note_resident(-(subs.nbytes + objs.nbytes))
        self._resident.clear()

    def release_transients(self) -> None:
        """Drop resident partitions AND the per-class / per-predicate
        decoded caches (entities, sorted objects).  The streamed
        detection path calls this between classes so accumulated caches
        never grow to O(graph) -- peak RSS stays bounded by the largest
        single class's working set."""
        self.release_resident()
        self._objsort_cache.clear()
        self._ents_cache.clear()

    @property
    def rows(self) -> np.ndarray:
        """Full decoded (p, s, o)-sorted row array -- the plain-tier
        fallback for mutation paths; NOT cached (O(n) per access)."""
        out = np.empty((self.n_rows, 3), np.int32)
        for i in range(self.preds.shape[0]):
            lo, hi = int(self.starts[i]), int(self.starts[i + 1])
            out[lo:hi, 0] = self._sub_parts[i].slice_()
            out[lo:hi, 1] = self.preds[i]
            out[lo:hi, 2] = self._obj_parts[i].slice_()
        _note_decode(3 * self.n_rows)
        return out

    def _pred_pos(self, p: int) -> int | None:
        i = int(np.searchsorted(self.preds, p))
        if i >= self.preds.shape[0] or self.preds[i] != p:
            return None
        return i

    # -- accessor surface (decode-on-slice) --------------------------------
    def pred_slice(self, p: int) -> np.ndarray:
        i = self._pred_pos(p)
        if i is None:
            return np.empty((0, 3), np.int32)
        subs, objs = self._partition(i)
        out = np.empty((subs.shape[0], 3), np.int32)
        out[:, 0] = subs
        out[:, 1] = p
        out[:, 2] = objs
        return out

    def pred_subjects(self, p: int) -> np.ndarray:
        i = self._pred_pos(p)
        if i is None:
            return np.empty((0,), np.int32)
        return self._partition(i)[0]

    def pred_count(self, p: int) -> int:
        i = self._pred_pos(p)
        return 0 if i is None else int(self.starts[i + 1] - self.starts[i])

    def pred_objects_sorted(self, p: int) -> np.ndarray:
        arr = self._objsort_cache.get(int(p))
        if arr is None:
            i = self._pred_pos(p)
            objs = self._partition(i)[1] if i is not None \
                else np.empty((0,), np.int64)
            arr = np.sort(objs.astype(np.int64))
            self._objsort_cache[int(p)] = arr
        return arr

    def entities_of_class(self, class_id: int) -> np.ndarray:
        ents = self._ents_cache.get(class_id)
        if ents is None:
            i = self._pred_pos(self.type_id)
            if i is None:
                ents = np.empty((0,), np.int32)
            else:
                subs, objs = self._partition(i)
                ents = subs[objs == class_id].astype(np.int32)
            self._ents_cache[class_id] = ents
        return ents

    def classes(self) -> np.ndarray:
        if self._classes_cache is None:
            i = self._pred_pos(self.type_id)
            self._classes_cache = np.unique(self._partition(i)[1]) \
                if i is not None else np.empty((0,), np.int64)
        return self._classes_cache

    def class_properties(self, class_id: int) -> np.ndarray:
        props = self._props_cache.get(class_id)
        if props is None:
            ents = self.entities_of_class(class_id)
            out = []
            for i, p in enumerate(self.preds.tolist()):
                if p == self.type_id or p == self.instance_of_id:
                    continue
                subs = self._partition(i)[0]
                if ents.shape[0] and in_sorted(subs, ents).any():
                    out.append(p)
            props = np.asarray(out, dtype=self.preds.dtype)
            self._props_cache[class_id] = props
        return props

    def object_matrix(self, class_id: int, props, strict: bool = False
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Same semantics as the plain index, but the join streams ONE
        predicate partition at a time into the (|C|, |SP|) output --
        transient decode is bounded by the largest single partition, not
        the sum over SP."""
        props = np.asarray(list(props), dtype=np.int32)
        ents = self.entities_of_class(class_id)
        if ents.size == 0 or props.size == 0:
            return ents[:0], np.empty((0, props.size), np.int32)
        objmat = np.full((ents.size, props.size), -1, dtype=np.int32)
        counts = np.zeros((ents.size, props.size), np.int64)
        for j, p in enumerate(props.tolist()):
            i = self._pred_pos(p)
            if i is None:
                continue
            subs, objs = self._partition(i)
            idx = np.searchsorted(ents, subs)
            idx_c = np.minimum(idx, ents.size - 1)
            hit = (idx < ents.size) & (ents[idx_c] == subs)
            ei = idx_c[hit]
            counts[:, j] += np.bincount(ei, minlength=ents.size)
            objmat[ei, j] = objs[hit]
        complete = (counts == 1).all(axis=1)
        if strict and not complete.all():
            bad = ents[~complete]
            raise ValueError(
                f"{bad.size} entities of class {class_id} violate the "
                "complete-molecule/functional-property assumption")
        return ents[complete], objmat[complete]

    def labeled_edge_count(self, class_id: int, props=None) -> int:
        ents = self.entities_of_class(class_id)
        if ents.shape[0] == 0:
            return 0
        if props is not None:
            pids = [int(p) for p in props]
        else:
            pids = [int(p) for p in self.preds.tolist()
                    if p != self.type_id]
        total = 0
        for p in pids:
            i = self._pred_pos(p)
            if i is not None:
                total += int(in_sorted(self._partition(i)[0], ents).sum())
        return total

    # -- mutation migrates to the plain tier -------------------------------
    def filtered(self, keep: np.ndarray) -> GraphIndex:
        out = GraphIndex.__new__(GraphIndex)
        GraphIndex.__init__(out, self.rows[keep], self.type_id,
                            self.instance_of_id, _presorted=True)
        return out

    def merged(self, new_rows: np.ndarray) -> GraphIndex:
        plain = GraphIndex.__new__(GraphIndex)
        GraphIndex.__init__(plain, self.rows, self.type_id,
                            self.instance_of_id, _presorted=True)
        return plain.merged(new_rows)


# -- the compressed store -----------------------------------------------------

class CompressedTripleStore(TripleStore):
    """Triple store holding its rows ONLY in compressed form.

    ``_spo`` is virtualized: reads decode (cached until
    :meth:`release_decoded`), writes re-compress -- so every inherited
    ``TripleStore`` method works unchanged, paying a transient decode
    when it genuinely needs the flat array.  The hot read paths
    (class/schema/object-matrix/selectivity probes) ride the
    :class:`CompressedGraphIndex` and never materialize the graph.
    """

    def __init__(self, dictionary=None, spo=None, *,
                 presorted: bool = False,
                 max_resident: int | None = 8) -> None:
        self._max_resident = max_resident
        self._cspo: _CompressedRows | None = None
        self._dec_spo: np.ndarray | None = None
        super().__init__(dictionary, spo, presorted=presorted)

    @property
    def is_compressed(self) -> bool:
        return True

    # -- virtualized _spo --------------------------------------------------
    @property
    def _spo(self) -> np.ndarray:
        if self._dec_spo is None:
            self._dec_spo = self._cspo.decode() if self._cspo is not None \
                else np.empty((0, 3), np.int32)
        return self._dec_spo

    @_spo.setter
    def _spo(self, rows: np.ndarray) -> None:
        rows = np.ascontiguousarray(rows, np.int32).reshape(-1, 3)
        self._cspo = _CompressedRows(rows, SPO_PERM)
        # keep the freshly-given rows as the decode cache: setters are
        # always followed by reads in the inherited mutation paths
        self._dec_spo = rows

    def release_decoded(self) -> None:
        """Drop the decoded ``spo`` cache (and the index's resident
        partitions): back to compressed-only residency."""
        self._dec_spo = None
        if self._index is not None and \
                isinstance(self._index, CompressedGraphIndex):
            self._index.release_resident()

    def release_transients(self) -> None:
        """Stream boundary: drop the decoded ``spo`` cache, resident
        partitions, and per-class decode caches (see
        :meth:`CompressedGraphIndex.release_transients`)."""
        self._dec_spo = None
        if self._index is not None and \
                isinstance(self._index, CompressedGraphIndex):
            self._index.release_transients()

    # -- index tier --------------------------------------------------------
    @property
    def index(self) -> CompressedGraphIndex:
        if self._index is None:
            self._index = CompressedGraphIndex(
                self._spo, self.TYPE, self.INSTANCE_OF,
                max_resident=self._max_resident)
            self._dec_spo = None     # index build decoded nothing extra
        return self._index

    @property
    def n_triples(self) -> int:
        return self._cspo.n if self._cspo is not None else 0

    def copy(self) -> "CompressedTripleStore":
        new = CompressedTripleStore.__new__(CompressedTripleStore)
        new.dict = self.dict
        new.TYPE = self.TYPE
        new.INSTANCE_OF = self.INSTANCE_OF
        new._max_resident = self._max_resident
        new._cspo = self._cspo        # immutable once packed: shareable
        new._dec_spo = None
        new._index = self._index
        return new

    # -- storage accounting ------------------------------------------------
    def substrate_nbytes(self, include_dict: bool = True) -> int:
        total = self._cspo.nbytes if self._cspo is not None else 0
        total += self.index.nbytes()
        if include_dict and hasattr(self.dict, "nbytes"):
            total += self.dict.nbytes()
        return total


def compress_store(store: TripleStore, *, max_resident: int | None = 8,
                   compact_dict: bool = True) -> CompressedTripleStore:
    """Compress a plain store into the bit-packed tier.

    The dictionary is front-coded by default (term ids preserved, so the
    compressed store answers the exact same id-level queries); pass
    ``compact_dict=False`` to share the original mutable ``TermDict``
    (e.g. when other live stores keep minting into it).
    """
    d = store.dict
    if compact_dict and not isinstance(d, CompactTermDict):
        d = CompactTermDict.from_dict(d)
    out = CompressedTripleStore(d, store.spo, presorted=True,
                                max_resident=max_resident)
    return out


# one reset clears the decode counters together with the sweep/query
# counters (core.sweep.reset_trace_stats is the bench-wide reset hook)
from .sweep import register_stats_reset  # noqa: E402

register_stats_reset(reset_decode_stats)

"""Algorithm 3 -- RDF graph factorization (the RDF-F problem, Def. 4.10).

Given a class C and a property set SP (output of E.FSP / G.FSP), every group
of entities sharing one object tuple over SP is replaced by a *compact RDF
molecule* (Def. 4.9): a fresh surrogate entity ``sg`` carrying

    (sg p_i o_i)  for every p_i in SP,     (sg type C),

while each original entity ``s`` keeps one ``(s instanceOf sg)`` edge and
all of its non-SP triples.  The transformation is lossless under the
Def. 4.11 axioms (see ``axioms.py``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import numpy as np

from .star import row_groups
from .triples import TripleStore


@dataclasses.dataclass
class FactorizationResult:
    graph: TripleStore                 # G'
    mu_n: dict[int, int]               # entity id -> surrogate id (partial map)
    surrogates: np.ndarray             # surrogate ids, one per star pattern
    class_id: int
    props: tuple[int, ...]
    # size accounting (paper §5 metrics)
    n_triples_before: int
    n_triples_after: int
    nle_before: int                    # labeled edges of C (props + instanceOf)
    nle_after: int
    nn_before: int
    nn_after: int
    # object tuple of each star pattern, aligned with ``surrogates``
    # (rows over sorted ``props``) -- lets repro.api build its incremental
    # tuple -> surrogate maps without rescanning the factorized graph
    star_objects: np.ndarray | None = None

    @property
    def pct_savings_triples(self) -> float:
        if self.n_triples_before == 0:
            return 0.0
        return 100.0 * (self.n_triples_before - self.n_triples_after) \
            / self.n_triples_before

    @property
    def pct_savings_nle(self) -> float:
        """%Savings over the class's labeled edges (paper Table 5)."""
        if self.nle_before == 0:
            return 0.0
        return 100.0 * (self.nle_before - self.nle_after) / self.nle_before

    @property
    def pct_savings_size(self) -> float:
        """Savings over graph size = nodes + edges (paper Fig. 9)."""
        before = self.nn_before + self.nle_before
        after = self.nn_after + self.nle_after
        if before == 0:
            return 0.0
        return 100.0 * (before - after) / before


def _class_nle_nodes(store: TripleStore, class_id: int) -> tuple[int, int]:
    """(NLE, NN) restricted to the class: ALL labeled edges whose subject is
    an entity (or surrogate) of C -- including ``type``, ``instanceOf`` and
    auxiliary links -- and the nodes they touch.

    Calibration note: the paper's Table 1b gives NLE(D1, Observation) =
    24,142,314 for 4,092,492 observations (~5.9 edges each: property,
    procedure, generatedBy, time, result, type) and ~2.95 edges per
    measurement (value, unit, type), i.e. type edges count toward NLE.  With
    this definition the headline numbers reproduce exactly: Measurement/A8
    savings -> 66.6% as AMI/AM -> 0 (3n -> n + 3*AMI edges) and
    Observation/A4 -> -16.67% when AMI == AM (6n -> 7n edges)."""
    ents = store.entities_of_class(class_id)
    # surrogates are entities of C too after factorization (sg type C);
    # instanceOf subjects are the original entities.
    inst_subj = store.spo[store.spo[:, 1] == store.INSTANCE_OF, 0]
    subjects = np.union1d(ents, inst_subj)
    mask = np.isin(store.spo[:, 0], subjects)
    nle = int(mask.sum())
    touched = store.spo[mask]
    nodes = np.unique(np.concatenate([touched[:, 0], touched[:, 2]]))
    return nle, int(nodes.shape[0])


def apply_molecule_map(spo: np.ndarray, mu_keys: np.ndarray,
                       mu_vals: np.ndarray, props_arr: np.ndarray,
                       class_id: int, type_id: int,
                       instance_of_id: int) -> np.ndarray:
    """Vectorized lines 8-29 of Algorithm 3: rewrite the edge set under a
    (sorted) entity -> surrogate map ``mu``.

    The ``(s type C)`` edge of a mapped entity becomes ``(s instanceOf
    sg)`` + ``(sg type C)``; SP edges move to the surrogate ``(sg p o)``;
    every other edge -- including type edges naming OTHER classes -- is
    untouched.  (The seed rewrote all type edges, which merged the type
    sets of multi-typed entities onto their shared surrogate: an entity of
    classes C and D grouped with a C-only entity leaked ``type D`` to the
    latter under axiom closure.  Only the class under factorization may
    move -- Def. 4.9's compact molecule carries ``sg type C`` alone.)
    Shared by full factorization and the incremental
    ``repro.api.Compactor.update`` path (which maps only the newly
    inserted entities).
    """
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    in_mu = np.isin(s, mu_keys)
    mu_of_s = np.zeros_like(s)
    idx = np.searchsorted(mu_keys, s[in_mu])
    mu_of_s[in_mu] = mu_vals[idx]

    is_ctype = (p == type_id) & (o == class_id)
    in_sp = np.isin(p, props_arr)

    keep_mask = ~in_mu | (~is_ctype & ~in_sp)     # lines 19-27: untouched
    kept = spo[keep_mask]

    # lines 11-14: (s type C) -> (s instanceOf sg) + (sg type C)
    tm = in_mu & is_ctype
    inst_edges = np.stack([s[tm],
                           np.full(tm.sum(), instance_of_id, np.int32),
                           mu_of_s[tm]], axis=1)
    sg_type_edges = np.stack([mu_of_s[tm], p[tm], o[tm]], axis=1)

    # lines 15-18: SP edges -> (sg p o)
    sm = in_mu & in_sp
    sg_prop_edges = np.stack([mu_of_s[sm], p[sm], o[sm]], axis=1)

    return np.concatenate(
        [kept, inst_edges, sg_type_edges, sg_prop_edges], axis=0)


def _factorize(store: TripleStore, class_id: int, props: Sequence[int],
               surrogate_prefix: str = "repro:sg",
               surrogate_start: int = 0) -> FactorizationResult:
    """Algorithm 3 for one (class, SP) pair; returns G' and mu_N.

    ``surrogate_start`` offsets the surrogate ordinals so incremental
    re-factorization (``repro.api.Compactor.update``) can mint fresh
    names that never collide with an earlier pass.
    """
    props_arr = np.asarray(sorted(int(p) for p in props), dtype=np.int32)
    ents, objmat = store.object_matrix(class_id, props_arr)
    nle_before, nn_before = _class_nle_nodes(store, class_id)

    # -- lines 2-7: group entities by object tuple, mint surrogates --------
    # (one bulk TermDict.ids() allocation, not a per-group id() loop)
    inv, counts, rep = row_groups(objmat)
    n_groups = int(counts.shape[0])
    cname = store.dict.term(class_id)
    surrogate_ids = store.dict.ids(
        [f"{surrogate_prefix}/{cname}/{surrogate_start + g}"
         for g in range(n_groups)]).astype(np.int32)
    mu = dict(zip(ents.tolist(), surrogate_ids[inv].tolist()))

    # -- lines 8-29: rebuild the edge set, vectorized ----------------------
    new_spo = apply_molecule_map(store.spo, ents, surrogate_ids[inv],
                                 props_arr, class_id, store.TYPE,
                                 store.INSTANCE_OF)
    gprime = TripleStore.from_ids(store.dict, new_spo)  # dedups (set union)

    nle_after, nn_after = _class_nle_nodes(gprime, class_id)
    return FactorizationResult(
        graph=gprime, mu_n=mu, surrogates=surrogate_ids,
        class_id=class_id, props=tuple(int(x) for x in props_arr),
        n_triples_before=store.n_triples, n_triples_after=gprime.n_triples,
        nle_before=nle_before, nle_after=nle_after,
        nn_before=nn_before, nn_after=nn_after,
        star_objects=objmat[rep] if n_groups else
        np.empty((0, props_arr.size), np.int32))


def factorize(store: TripleStore, class_id: int, props: Sequence[int],
              surrogate_prefix: str = "repro:sg") -> FactorizationResult:
    """Deprecated shim: use ``repro.api.Compactor`` (explicit plans go
    through ``CompactionPlan.explicit`` + ``Compactor.execute``)."""
    warnings.warn(
        "repro.core.factorize() is deprecated; use repro.api.Compactor "
        "(CompactionPlan.explicit for caller-chosen property sets)",
        DeprecationWarning, stacklevel=2)
    return _factorize(store, class_id, props,
                      surrogate_prefix=surrogate_prefix)


def factorize_classes(store: TripleStore,
                      plans: Sequence[tuple[int, Sequence[int]]],
                      surrogate_prefix: str = "repro:sg"
                      ) -> tuple[TripleStore, list[FactorizationResult]]:
    """Factorize several (class, SP) plans sequentially (paper §5 factorizes
    Observation and Measurement independently).  This is the transactional
    execution primitive of ``repro.api.Compactor``: the input store is
    never mutated, so a failure at any step leaves the caller's graph
    untouched."""
    g = store
    results = []
    for class_id, props in plans:
        res = _factorize(g, class_id, props,
                         surrogate_prefix=surrogate_prefix)
        results.append(res)
        g = res.graph
    return g, results

"""Algorithm 3 -- RDF graph factorization (the RDF-F problem, Def. 4.10).

Given a class C and a property set SP (output of E.FSP / G.FSP), every group
of entities sharing one object tuple over SP is replaced by a *compact RDF
molecule* (Def. 4.9): a fresh surrogate entity ``sg`` carrying

    (sg p_i o_i)  for every p_i in SP,     (sg type C),

while each original entity ``s`` keeps one ``(s instanceOf sg)`` edge and
all of its non-SP triples.  The transformation is lossless under the
Def. 4.11 axioms (see ``axioms.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .star import row_groups
from .triples import TripleStore


@dataclasses.dataclass
class FactorizationResult:
    graph: TripleStore                 # G'
    mu_n: dict[int, int]               # entity id -> surrogate id (partial map)
    surrogates: np.ndarray             # surrogate ids, one per star pattern
    class_id: int
    props: tuple[int, ...]
    # size accounting (paper §5 metrics)
    n_triples_before: int
    n_triples_after: int
    nle_before: int                    # labeled edges of C (props + instanceOf)
    nle_after: int
    nn_before: int
    nn_after: int

    @property
    def pct_savings_triples(self) -> float:
        if self.n_triples_before == 0:
            return 0.0
        return 100.0 * (self.n_triples_before - self.n_triples_after) \
            / self.n_triples_before

    @property
    def pct_savings_nle(self) -> float:
        """%Savings over the class's labeled edges (paper Table 5)."""
        if self.nle_before == 0:
            return 0.0
        return 100.0 * (self.nle_before - self.nle_after) / self.nle_before

    @property
    def pct_savings_size(self) -> float:
        """Savings over graph size = nodes + edges (paper Fig. 9)."""
        before = self.nn_before + self.nle_before
        after = self.nn_after + self.nle_after
        if before == 0:
            return 0.0
        return 100.0 * (before - after) / before


def _class_nle_nodes(store: TripleStore, class_id: int) -> tuple[int, int]:
    """(NLE, NN) restricted to the class: ALL labeled edges whose subject is
    an entity (or surrogate) of C -- including ``type``, ``instanceOf`` and
    auxiliary links -- and the nodes they touch.

    Calibration note: the paper's Table 1b gives NLE(D1, Observation) =
    24,142,314 for 4,092,492 observations (~5.9 edges each: property,
    procedure, generatedBy, time, result, type) and ~2.95 edges per
    measurement (value, unit, type), i.e. type edges count toward NLE.  With
    this definition the headline numbers reproduce exactly: Measurement/A8
    savings -> 66.6% as AMI/AM -> 0 (3n -> n + 3*AMI edges) and
    Observation/A4 -> -16.67% when AMI == AM (6n -> 7n edges)."""
    ents = store.entities_of_class(class_id)
    # surrogates are entities of C too after factorization (sg type C);
    # instanceOf subjects are the original entities.
    inst_subj = store.spo[store.spo[:, 1] == store.INSTANCE_OF, 0]
    subjects = np.union1d(ents, inst_subj)
    mask = np.isin(store.spo[:, 0], subjects)
    nle = int(mask.sum())
    touched = store.spo[mask]
    nodes = np.unique(np.concatenate([touched[:, 0], touched[:, 2]]))
    return nle, int(nodes.shape[0])


def factorize(store: TripleStore, class_id: int, props: Sequence[int],
              surrogate_prefix: str = "repro:sg") -> FactorizationResult:
    """Apply Algorithm 3 for one (class, SP) pair; returns G' and mu_N."""
    props_arr = np.asarray(sorted(int(p) for p in props), dtype=np.int32)
    ents, objmat = store.object_matrix(class_id, props_arr)
    nle_before, nn_before = _class_nle_nodes(store, class_id)

    # -- lines 2-7: group entities by object tuple, mint surrogates --------
    inv, counts, rep = row_groups(objmat)
    n_groups = int(counts.shape[0])
    surrogate_ids = np.empty((n_groups,), dtype=np.int32)
    cname = store.dict.term(class_id)
    for g in range(n_groups):
        surrogate_ids[g] = store.dict.id(
            f"{surrogate_prefix}/{cname}/{g}")
    mu = dict(zip(ents.tolist(), surrogate_ids[inv].tolist()))
    mu_arr_keys = ents
    mu_arr_vals = surrogate_ids[inv]

    # -- lines 8-29: rebuild the edge set, vectorized ----------------------
    spo = store.spo
    s, p, o = spo[:, 0], spo[:, 1], spo[:, 2]
    in_mu = np.isin(s, mu_arr_keys)
    mu_of_s = np.zeros_like(s)
    idx = np.searchsorted(mu_arr_keys, s[in_mu])
    mu_of_s[in_mu] = mu_arr_vals[idx]

    is_type = p == store.TYPE
    in_sp = np.isin(p, props_arr)

    keep_mask = ~in_mu | (~is_type & ~in_sp)      # lines 19-27: untouched
    kept = spo[keep_mask]

    # lines 11-14: type edges -> (s instanceOf sg) + (sg type o)
    tm = in_mu & is_type
    inst_edges = np.stack([s[tm],
                           np.full(tm.sum(), store.INSTANCE_OF, np.int32),
                           mu_of_s[tm]], axis=1)
    sg_type_edges = np.stack([mu_of_s[tm], p[tm], o[tm]], axis=1)

    # lines 15-18: SP edges -> (sg p o)
    sm = in_mu & in_sp
    sg_prop_edges = np.stack([mu_of_s[sm], p[sm], o[sm]], axis=1)

    new_spo = np.concatenate(
        [kept, inst_edges, sg_type_edges, sg_prop_edges], axis=0)
    gprime = TripleStore.from_ids(store.dict, new_spo)  # dedups (set union)

    nle_after, nn_after = _class_nle_nodes(gprime, class_id)
    return FactorizationResult(
        graph=gprime, mu_n=mu, surrogates=surrogate_ids,
        class_id=class_id, props=tuple(int(x) for x in props_arr),
        n_triples_before=store.n_triples, n_triples_after=gprime.n_triples,
        nle_before=nle_before, nle_after=nle_after,
        nn_before=nn_before, nn_after=nn_after)


def factorize_classes(store: TripleStore,
                      plans: Sequence[tuple[int, Sequence[int]]]
                      ) -> tuple[TripleStore, list[FactorizationResult]]:
    """Factorize several (class, SP) plans sequentially (paper §5 factorizes
    Observation and Measurement independently)."""
    g = store
    results = []
    for class_id, props in plans:
        res = factorize(g, class_id, props)
        results.append(res)
        g = res.graph
    return g, results

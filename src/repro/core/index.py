"""Indexed graph substrate: per-predicate CSR slices over sorted triples.

Production RDF engines (k2-triples, compressed vertical partitioning) win
by organizing the dictionary-encoded triples *per predicate*, so that
star-shaped joins become index slices instead of full-graph scans.  The
seed ``TripleStore`` answered every access -- ``entities_of_class``,
``object_matrix``, ``labeled_edge_count`` -- with O(|G|) ``np.isin`` /
``np.unique`` passes, and the greedy FSP descent re-ran them per (class,
candidate) pair: the dominant cost of detection on anything larger than
the worked examples.

``GraphIndex`` stores one extra copy of the triples, row-sorted by
``(predicate, subject, object)``, with a CSR offset table over the
predicate column:

* ``pred_slice(p)``       -- all ``(s, p, o)`` rows of predicate ``p``,
  sorted by ``(s, o)``: a vertical partition, O(log P) to locate.
* ``entities_of_class``   -- filter of the ``rdf:type`` slice; subjects
  come out sorted-unique for free (cached per class).
* ``object_matrix``       -- per-property slice joins against the sorted
  entity vector via ``searchsorted`` (no full-graph ``isin``).
* ``merged(rows)``        -- incremental merge-on-append: new rows are
  merged into the sorted order with a vectorized two-way merge
  (``searchsorted`` + fancy indexing), O(n + m log n) instead of a full
  re-sort, and per-class caches survive when untouched.

The index is immutable: ``merged`` returns a new ``GraphIndex`` sharing
nothing mutable with its parent except lazily-filled caches that remain
valid for both.  ``TripleStore`` builds one lazily and carries it across
``copy()`` / ``add_ids`` / ``restrict_subjects``.
"""
from __future__ import annotations

import numpy as np

# column permutations: spo rows are stored (s, p, o); sort keys differ
SPO_PERM = (0, 1, 2)      # TripleStore.spo canonical order
PSO_PERM = (1, 0, 2)      # GraphIndex row order

_KEY_DTYPE = np.dtype([("a", np.int32), ("b", np.int32), ("c", np.int32)])


def _key_view(rows: np.ndarray, perm) -> np.ndarray:
    """Structured (void) view of (n, 3) int32 rows under column order
    ``perm`` -- lexicographically comparable/searchable as one key."""
    arr = np.ascontiguousarray(rows[:, list(perm)], dtype=np.int32)
    return arr.view(_KEY_DTYPE).ravel()


def sort_unique(rows: np.ndarray, perm=SPO_PERM) -> np.ndarray:
    """Sort (n, 3) rows by the ``perm`` column order and drop duplicates.
    Unlike ``np.unique(axis=0)`` the key order is configurable."""
    rows = np.ascontiguousarray(rows, dtype=np.int32).reshape(-1, 3)
    if rows.shape[0] <= 1:
        return rows
    key = _key_view(rows, perm)
    order = np.argsort(key, kind="stable")
    rows = rows[order]
    keep = np.empty(rows.shape[0], bool)
    keep[0] = True
    np.any(rows[1:] != rows[:-1], axis=1, out=keep[1:])
    return rows[keep]


def setdiff_rows(new: np.ndarray, old: np.ndarray, perm=SPO_PERM
                 ) -> np.ndarray:
    """Rows of ``new`` absent from ``old`` (both sorted-unique under
    ``perm``); order of ``new`` preserved.  O(m log n)."""
    if new.shape[0] == 0 or old.shape[0] == 0:
        return new
    return new[~in_sorted(_key_view(new, perm), _key_view(old, perm))]


def merge_disjoint(old: np.ndarray, new: np.ndarray, perm=SPO_PERM
                   ) -> np.ndarray:
    """Two-way merge of disjoint row sets, each sorted-unique under
    ``perm``.  Vectorized: one ``searchsorted`` + two fancy writes --
    O(n + m log n), no re-sort, no dedup pass."""
    if new.shape[0] == 0:
        return old
    if old.shape[0] == 0:
        return new
    pos = np.searchsorted(_key_view(old, perm), _key_view(new, perm))
    out = np.empty((old.shape[0] + new.shape[0], 3), np.int32)
    new_at = pos + np.arange(new.shape[0])
    old_mask = np.ones(out.shape[0], bool)
    old_mask[new_at] = False
    out[new_at] = new
    out[old_mask] = old
    return out


def csr_take(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Flat gather indices for concatenated CSR extents: the segmented
    expansion ``[starts[i], starts[i] + counts[i])`` for every i, as one
    index vector (``arange`` minus each segment's running offset).  The
    shared idiom behind every segmented gather in this codebase --
    object-matrix extraction, instanceOf-CSR member emission, and the
    query engine's subject joins."""
    counts = np.asarray(counts)
    total = int(counts.sum())
    within = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                          counts)
    return np.repeat(starts, counts) + within


def in_sorted(values: np.ndarray, sorted_ref: np.ndarray) -> np.ndarray:
    """Membership of ``values`` in a sorted-unique 1-D ``sorted_ref``
    via binary search -- the index-join replacement for ``np.isin``
    (which re-sorts its second argument on every call)."""
    if sorted_ref.shape[0] == 0:
        return np.zeros(values.shape[0], bool)
    idx = np.searchsorted(sorted_ref, values)
    idx_c = np.minimum(idx, sorted_ref.shape[0] - 1)
    return (idx < sorted_ref.shape[0]) & (sorted_ref[idx_c] == values)


class GraphIndex:
    """Immutable per-predicate CSR index over an (n, 3) triple array."""

    __slots__ = ("rows", "preds", "starts", "type_id", "instance_of_id",
                 "_ents_cache", "_props_cache", "_classes_cache",
                 "_objsort_cache")

    def __init__(self, spo: np.ndarray, type_id: int, instance_of_id: int,
                 *, _presorted: bool = False) -> None:
        rows = np.ascontiguousarray(spo, dtype=np.int32).reshape(-1, 3)
        if not _presorted and rows.shape[0] > 1:
            order = np.argsort(_key_view(rows, PSO_PERM), kind="stable")
            rows = rows[order]
        self.rows = rows
        self.type_id = int(type_id)
        self.instance_of_id = int(instance_of_id)
        if rows.shape[0]:
            self.preds, first = np.unique(rows[:, 1], return_index=True)
            self.starts = np.append(first, rows.shape[0])
        else:
            self.preds = np.empty((0,), np.int32)
            self.starts = np.zeros((1,), np.int64)
        self._ents_cache: dict[int, np.ndarray] = {}
        self._props_cache: dict[int, np.ndarray] = {}
        self._classes_cache: np.ndarray | None = None
        self._objsort_cache: dict[int, np.ndarray] = {}

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])

    # -- slices ------------------------------------------------------------
    def pred_slice(self, p: int) -> np.ndarray:
        """All rows with predicate ``p``, sorted by (s, o).  A view."""
        i = int(np.searchsorted(self.preds, p))
        if i >= self.preds.shape[0] or self.preds[i] != p:
            return self.rows[:0]
        return self.rows[self.starts[i]:self.starts[i + 1]]

    def pred_subjects(self, p: int) -> np.ndarray:
        """Subject column of one predicate's partition (non-decreasing).
        The accessor the compressed tier can answer by decoding ONE
        delta-packed column -- callers must prefer it over slicing
        ``rows`` directly."""
        return self.pred_slice(p)[:, 0]

    # -- storage accounting ------------------------------------------------
    def nbytes(self) -> int:
        """Resident bytes of the index arrays (the uncompressed-tier
        denominator of the bytes-per-triple bench column)."""
        return int(self.rows.nbytes) + int(self.preds.nbytes) \
            + int(self.starts.nbytes)

    # -- selectivity -------------------------------------------------------
    def pred_count(self, p: int) -> int:
        """Row count of a predicate's vertical partition: the size of
        the slice a raw ground-arm scan pays -- a planner cost input."""
        i = int(np.searchsorted(self.preds, p))
        if i >= self.preds.shape[0] or self.preds[i] != p:
            return 0
        return int(self.starts[i + 1] - self.starts[i])

    def pred_objects_sorted(self, p: int) -> np.ndarray:
        """Sorted object column of one predicate (cached): two binary
        searches answer any equality or range selectivity probe."""
        arr = self._objsort_cache.get(int(p))
        if arr is None:
            arr = np.sort(self.pred_slice(p)[:, 2].astype(np.int64))
            self._objsort_cache[int(p)] = arr
        return arr

    def pred_object_count(self, p: int, o: int) -> int:
        """Triples matching ``(?s p o)`` -- the ground-arm selectivity
        numerator, O(log) off the sorted-object cache."""
        arr = self.pred_objects_sorted(p)
        return int(np.searchsorted(arr, o, side="right")
                   - np.searchsorted(arr, o, side="left"))

    # -- class / schema ----------------------------------------------------
    def entities_of_class(self, class_id: int) -> np.ndarray:
        """Sorted-unique subjects with ``(s, type, class_id)``.  The type
        slice is (s, o)-sorted and triple-deduped, so filtering by object
        keeps subjects strictly increasing: no ``np.unique`` needed."""
        ents = self._ents_cache.get(class_id)
        if ents is None:
            ts = self.pred_slice(self.type_id)
            ents = ts[ts[:, 2] == class_id, 0]
            self._ents_cache[class_id] = ents
        return ents

    def classes(self) -> np.ndarray:
        if self._classes_cache is None:
            ts = self.pred_slice(self.type_id)
            self._classes_cache = np.unique(ts[:, 2])
        return self._classes_cache

    def class_properties(self, class_id: int) -> np.ndarray:
        """Sorted property ids with >= 1 subject in class C, excluding
        ``type`` / ``instanceOf`` -- one membership probe per vertical
        partition instead of a full-graph scan."""
        props = self._props_cache.get(class_id)
        if props is None:
            ents = self.entities_of_class(class_id)
            out = []
            for i, p in enumerate(self.preds.tolist()):
                if p == self.type_id or p == self.instance_of_id:
                    continue
                subs = self.rows[self.starts[i]:self.starts[i + 1], 0]
                if ents.shape[0] and in_sorted(subs, ents).any():
                    out.append(p)
            props = np.asarray(out, dtype=self.preds.dtype)
            self._props_cache[class_id] = props
        return props

    # -- joins -------------------------------------------------------------
    def object_matrix(self, class_id: int, props, strict: bool = False
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Entities x objects matrix via ONE fused segmented gather.

        Semantics match the scan-based ``TripleStore.object_matrix``:
        entities violating the complete-molecule / functional-property
        assumption (§4.3 (a)/(b)) are excluded (``strict=True`` raises).
        All requested predicates' CSR extents are located at once and
        their rows pulled in a single fancy-index over the sorted layout,
        followed by one combined subject join and one flat ``bincount``
        -- O(sum_p |G_p| log |C|) work with O(|SP|) python overhead
        instead of O(|SP|) sequential per-predicate joins.
        """
        props = np.asarray(list(props), dtype=np.int32)
        ents = self.entities_of_class(class_id)
        if ents.size == 0 or props.size == 0:
            return ents[:0], np.empty((0, props.size), np.int32)
        objmat = np.full((ents.size, props.size), -1, dtype=np.int32)
        counts = np.zeros((ents.size, props.size), np.int64)
        # locate every predicate's extent in the offset table at once
        pi = np.searchsorted(self.preds, props)
        pi_c = np.minimum(pi, self.preds.shape[0] - 1)
        present = (pi < self.preds.shape[0]) & (self.preds[pi_c] == props)
        starts = np.where(present, self.starts[pi_c], 0)
        lengths = np.where(present, self.starts[pi_c + 1] - starts, 0)
        total = int(lengths.sum())
        if total:
            # segmented gather: concatenated per-predicate extents become
            # one row-index vector (start offset + within-segment rank)
            col = np.repeat(np.arange(props.size), lengths)
            sub = self.rows[csr_take(starts, lengths)]
            idx = np.searchsorted(ents, sub[:, 0])
            idx_c = np.minimum(idx, ents.size - 1)
            hit = (idx < ents.size) & (ents[idx_c] == sub[:, 0])
            ei, cj = idx_c[hit], col[hit]
            counts = np.bincount(
                ei * props.size + cj,
                minlength=ents.size * props.size,
            ).reshape(ents.size, props.size)
            objmat[ei, cj] = sub[hit, 2]
        complete = (counts == 1).all(axis=1)
        if strict and not complete.all():
            bad = ents[~complete]
            raise ValueError(
                f"{bad.size} entities of class {class_id} violate the "
                "complete-molecule/functional-property assumption")
        return ents[complete], objmat[complete]

    def labeled_edge_count(self, class_id: int, props=None) -> int:
        """NLE restricted to class C (paper §5): membership counts per
        vertical partition instead of a full-graph ``isin``."""
        ents = self.entities_of_class(class_id)
        if ents.shape[0] == 0:
            return 0
        if props is not None:
            pids = [int(p) for p in props]
        else:
            pids = [int(p) for p in self.preds.tolist() if p != self.type_id]
        total = 0
        for p in pids:
            sl = self.pred_slice(p)
            if sl.shape[0]:
                total += int(in_sorted(sl[:, 0], ents).sum())
        return total

    # -- incremental maintenance --------------------------------------------
    def filtered(self, keep: np.ndarray) -> "GraphIndex":
        """New index over ``rows[keep]`` -- a row-subset of a sorted array
        stays sorted, so this is O(n) with no re-sort (caches are dropped:
        the caller decides which classes survive a removal)."""
        out = GraphIndex.__new__(GraphIndex)
        GraphIndex.__init__(out, self.rows[keep], self.type_id,
                            self.instance_of_id, _presorted=True)
        return out

    def merged(self, new_rows: np.ndarray) -> "GraphIndex":
        """New index over ``rows + new_rows`` without a full re-sort.

        ``new_rows`` may be unsorted and overlap existing rows; they are
        locally sorted/deduped (O(m log m)), subtracted, and merged into
        the (p, s, o) order in one vectorized pass.  Caches carry over for
        classes provably untouched by the appended rows.
        """
        nr = sort_unique(new_rows, PSO_PERM)
        nr = setdiff_rows(nr, self.rows, PSO_PERM)
        out = GraphIndex.__new__(GraphIndex)
        GraphIndex.__init__(
            out, merge_disjoint(self.rows, nr, PSO_PERM),
            self.type_id, self.instance_of_id, _presorted=True)
        if nr.shape[0] == 0:
            out._ents_cache = dict(self._ents_cache)
            out._props_cache = dict(self._props_cache)
            out._classes_cache = self._classes_cache
            return out
        touched_classes = set(
            nr[nr[:, 1] == self.type_id, 2].tolist())
        new_subjects = np.unique(nr[:, 0])
        for cid, ents in self._ents_cache.items():
            if cid in touched_classes:
                continue
            out._ents_cache[cid] = ents
            # property sets stay valid only if no appended row's subject
            # is an entity of the class (new preds on members invalidate)
            if cid in self._props_cache and \
                    not in_sorted(new_subjects, ents).any():
                out._props_cache[cid] = self._props_cache[cid]
        if not touched_classes and self._classes_cache is not None:
            out._classes_cache = self._classes_cache
        return out

"""Core of the paper's contribution: frequent-star-pattern detection and
RDF graph factorization (Karim, Vidal & Auer 2020).

The stable public surface is ``repro.api`` (``Compactor`` with pluggable
detectors and execution backends); the ``gfsp`` / ``efsp`` / ``factorize``
free functions re-exported here are deprecated shims kept for
compatibility."""
from .triples import TermDict, TripleStore, RDF_TYPE, INSTANCE_OF  # noqa: F401
from .index import GraphIndex, in_sorted, merge_disjoint, sort_unique  # noqa: F401
from .star import (ami, multiplicities, num_edges, evaluate_subset,  # noqa: F401
                   star_groups, row_groups, StarSweepResult)
from .sweep import (SweepWorkspace, HostSweepWorkspace,  # noqa: F401
                    DeviceSweepWorkspace, ShardedSweepWorkspace, pick_child)
from .gfsp import gfsp, FSPResult  # noqa: F401
from .efsp import efsp, build_subgraphs_dict  # noqa: F401
from .factorize import factorize, factorize_classes, FactorizationResult  # noqa: F401
from .fgraph import DeleteStats, FactorizedGraph, MoleculeTable  # noqa: F401
from .axioms import expand, semantic_triples, match_star  # noqa: F401

"""Star patterns and the paper's counting formulas (Defs 4.4 - 4.8).

Given a class ``C`` with property set ``S`` and a candidate subset
``SP = {p_1..p_n}``:

* ``M(o_1..o_n | G)``   -- class multiplicity (Def. 4.5): number of distinct
  entities of C whose objects over SP equal the tuple ``(o_1..o_n)``.
* ``MI = 1/M``          -- class multiplicity inverse (Def. 4.6).
* ``AMI_G(SP|C)``       -- multiplicity of star patterns (Def. 4.7):
  ``ceil( sum over matching entities of MI )``.  With complete molecules and
  functional properties this equals the number of *distinct object tuples*,
  i.e. the number of star patterns over SP.
* ``#Edges(SP, C, G)``  -- the FSP-detection objective (Def. 4.8):

      AMI_G(SP|C) * (|SP| + 1)  +  AM_G(C) * |S - SP|

  the edge count of the graph after factorizing SP (each star pattern costs
  ``|SP|`` object edges + 1 ``instanceOf``-side edge) plus the untouched
  edges of the remaining properties.

NOTE (fidelity): the normative objective is Def. 4.8, which is consistent
with Figures 3 and 7 of the paper (15 / 8 edges for the worked example).
The prose walkthrough of Algorithm 1 quotes slightly different intermediate
numbers (16 / 17 / 11); those are inconsistent with Def. 4.8 and with
Figure 3, so we follow the definition.  Both of our algorithm
implementations therefore optimize the exact Def. 4.8 objective, and -- as
the paper reports -- E.FSP and G.FSP return identical frequent star
patterns.

Both a numpy host path and a jax path are provided.  The jax path works on
fixed-shape object matrices and is the building block for the Pallas-
accelerated and shard_map-distributed sweeps.  The ``use_kernel=`` flags
on the device helpers are primitive-level knobs: pipeline code selects
them once via ``repro.api.backends`` (``DeviceBackend(use_kernel=...)`` /
``ShardedBackend``) instead of threading booleans through call chains.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .triples import TripleStore

# ---------------------------------------------------------------------------
# host (numpy) path
# ---------------------------------------------------------------------------


def row_groups(objmat: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Group identical rows of an (n, k) int matrix.

    Returns ``(group_of_row, group_counts, representative_row_index)``:
    ``group_of_row[i]`` is the group id of row i, ``group_counts[g]`` the
    multiplicity M of group g, ``representative_row_index[g]`` one row index
    instantiating group g.
    """
    n = objmat.shape[0]
    if n == 0:
        z = np.empty((0,), np.int64)
        return z, z, z
    # unique over rows via a contiguous void view (fast lexicographic unique)
    arr = np.ascontiguousarray(objmat.astype(np.int32, copy=False))
    void = arr.view([("", arr.dtype)] * arr.shape[1]).ravel()
    _, rep, inv, counts = np.unique(
        void, return_index=True, return_inverse=True, return_counts=True)
    return inv.astype(np.int64), counts.astype(np.int64), rep.astype(np.int64)


def multiplicities(objmat: np.ndarray) -> np.ndarray:
    """Per-entity class multiplicity M (Def. 4.5) over the object matrix."""
    inv, counts, _ = row_groups(objmat)
    return counts[inv]


def ami(objmat: np.ndarray) -> int:
    """Multiplicity of star patterns AMI (Def. 4.7) = #distinct object rows.

    ``ceil(sum_i 1/M_i)`` equals the number of groups exactly (each group of
    size M contributes M * (1/M) = 1), so we count groups directly; the ceil
    of Def. 4.7 is a no-op under the summation aggregation used by the paper.
    """
    if objmat.shape[0] == 0:
        return 0
    _, counts, _ = row_groups(objmat)
    return int(counts.shape[0])


def num_edges(ami_value: int, am: int, n_sp: int, n_s: int) -> int:
    """#Edges(SP, C, G) -- Def. 4.8 / Formula 1."""
    return int(ami_value) * (n_sp + 1) + int(am) * (n_s - n_sp)


def num_edges_batch(amis, am: int, n_sp, n_s: int) -> np.ndarray:
    """Vectorized Def. 4.8 over aligned candidate arrays.

    ``amis`` and ``n_sp`` are (C,) arrays (per-candidate AMI and |SP'|);
    returns (C,) int64 #Edges -- the host-side reduction of a candidate
    batch, replacing the per-candidate ``num_edges`` Python loop.
    """
    amis = np.asarray(amis, np.int64)
    n_sp = np.asarray(n_sp, np.int64)
    return amis * (n_sp + 1) + int(am) * (int(n_s) - n_sp)


@dataclasses.dataclass(frozen=True)
class StarSweepResult:
    """Evaluation of one candidate property subset."""

    props: tuple[int, ...]
    ami: int
    am: int
    n_total_props: int
    edges: int

    @property
    def is_single_pattern(self) -> bool:
        return self.ami == 1


def evaluate_subset(store: TripleStore, class_id: int,
                    props: Sequence[int], n_total_props: int,
                    am: int | None = None) -> StarSweepResult:
    """Compute AMI and #Edges for one (class, SP) candidate."""
    props = tuple(int(p) for p in props)
    ents, objmat = store.object_matrix(class_id, props)
    if am is None:
        am = int(store.entities_of_class(class_id).shape[0])
    a = ami(objmat)
    return StarSweepResult(
        props=props, ami=a, am=am, n_total_props=n_total_props,
        edges=num_edges(a, am, len(props), n_total_props))


def star_groups(store: TripleStore, class_id: int, props: Sequence[int]
                ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Materialized star patterns over SP: list of (entities, object_row).

    Each element is one star pattern (Def. 4.4): the entities matching it and
    the shared object tuple.  This is what Algorithm 3 consumes.
    """
    props = np.asarray(list(props), dtype=np.int32)
    ents, objmat = store.object_matrix(class_id, props)
    inv, counts, rep = row_groups(objmat)
    out = []
    order = np.argsort(inv, kind="stable")
    boundaries = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    sorted_ents = ents[order]
    for g in range(counts.shape[0]):
        members = sorted_ents[boundaries[g]:boundaries[g + 1]]
        out.append((members, objmat[rep[g]]))
    return out


# ---------------------------------------------------------------------------
# jax path (fixed-shape; device-friendly)
# ---------------------------------------------------------------------------

def _jax():
    import jax  # local import: host-only users never pay for it
    import jax.numpy as jnp
    return jax, jnp


def ami_device(objmat, valid=None, use_kernel: bool = True):
    """AMI on device: #distinct rows of ``objmat`` (n, k) int32.

    ``valid``: optional (n,) bool mask (rows excluded from counting) --
    needed by the bucketed/distributed sweeps where buffers are padded.
    The mask is applied inside ``kernels.ops.row_signature`` (one shared
    sentinel convention); here we only subtract the sentinel segment.

    Strategy (TPU-idiomatic group-by): hash each row to a 64-bit signature
    (two uint32 lanes, Pallas kernel when available), lexsort, count segment
    boundaries.  Collision probability over two independent 32-bit mixes is
    ~n^2 / 2^64 -- negligible for any realistic shard.
    """
    jax, jnp = _jax()
    from repro.kernels import ops as kops
    sig = kops.row_signature(objmat, valid=valid,
                             use_kernel=use_kernel)  # (n, 2) uint32
    sig_sorted, _ = kops.sort_signatures(sig)
    _, n_groups = kops.seg_boundaries(sig_sorted, use_kernel=use_kernel)
    if valid is not None:
        has_sentinel = jnp.any(~valid)
        return n_groups - has_sentinel.astype(jnp.int32)
    return n_groups


def ami_device_batch(mats, valid=None, use_kernel: bool = True):
    """AMI for a whole candidate stack: (C, N, K) int32 -> (C,) int32.

    One signature launch (candidate axis = Pallas grid axis), one batched
    per-candidate sort, one batched segment count -- the building block of
    ``core.sweep.sweep_candidates``.  ``valid`` is (N,) (shared bucket
    padding) or (C, N); each candidate's sentinel segment is subtracted
    independently, so the padded-row convention of :func:`ami_device`
    holds per candidate.
    """
    jax, jnp = _jax()
    from repro.kernels import ops as kops
    sig = kops.row_signature(mats, valid=valid,
                             use_kernel=use_kernel)   # (C, N, 2)
    sig_sorted, _ = kops.sort_signatures(sig)
    _, n_groups = kops.seg_boundaries(sig_sorted,
                                      use_kernel=use_kernel)  # (C,)
    if valid is not None:
        has_sentinel = jnp.any(~valid, axis=-1)       # () or (C,)
        return n_groups - has_sentinel.astype(jnp.int32)
    return n_groups


def multiplicities_device(objmat, valid=None, use_kernel: bool = True):
    """Per-row multiplicity M on device (sort + segment length + unsort).

    ``valid``: optional padding mask, same convention as :func:`ami_device`
    (invalid rows collapse into one sentinel group whose multiplicity the
    caller must ignore)."""
    jax, jnp = _jax()
    from repro.kernels import ops as kops
    n = objmat.shape[0]
    sig = kops.row_signature(objmat, valid=valid, use_kernel=use_kernel)
    sig_sorted, order = kops.sort_signatures(sig)
    new_seg, _ = kops.seg_boundaries(sig_sorted, use_kernel=use_kernel)
    seg_id = jnp.cumsum(new_seg) - 1                      # group of sorted row
    seg_count = jnp.zeros((n,), jnp.int32).at[seg_id].add(1)
    m_sorted = seg_count[seg_id]
    inv_order = jnp.argsort(order)
    return m_sorted[inv_order]


def edges_formula_device(ami_value, am, n_sp, n_s):
    jax, jnp = _jax()
    return ami_value * (n_sp + 1) + am * (n_s - n_sp)

# NOTE: the gather-based per-shape drop-one sweep that used to live here
# (``sweep_drop_one_device``) is superseded by the shape-bucketed,
# column-masked sweep in ``core.sweep`` (one compile per power-of-two
# bucket instead of one per (n, k) pair); ``core.distributed.sweep_drop_one``
# remains as the shard_map-facing variant.

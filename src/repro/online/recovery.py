"""Durable snapshot checkpoints + crash recovery for the online service.

A durable service root looks like::

    <root>/wal/seg_00000000.wal ...     append-only journal (online.wal)
    <root>/ckpt/step_00000012/          one checkpoint per applied batch
        manifest.json                   counts, meta, drift, file sha1s
        terms.bin / terms_len.npy       dictionary prefix, allocation order
        spo.npy                         packed triple ids
        table_<cid>_{surrogates,objects}.npy

Checkpoints use the same atomic discipline as ``repro.ckpt``: stage
into ``step_<n>.tmp``, write the manifest LAST, then one
``os.replace`` publishes the whole directory.  A reader never sees a
half-written checkpoint, and validation (manifest parses + every file
present with a matching sha1) falls back to the previous step if the
newest one is damaged.  ``step`` is ``applied_seq + 1`` so a fresh
service (nothing applied, ``applied_seq == -1``) checkpoints as step 0.

:func:`recover` rebuilds a live :class:`OnlineCompactionService`:
restore the latest valid checkpoint, replay the WAL -- every ``MINT``
in allocation order first (asserting exact id reproduction against the
checkpoint prefix), queue every ``BATCH`` past the checkpoint's
``applied_seq`` -- then re-apply logged ``APPLY`` groups under the
exact pre-crash coalescing.  Surrogate names are deterministic
(``repro:sg/<class>/<ordinal>``) and ``TermDict.ids`` is get-or-mint,
so re-applying a batch whose mints were already journaled reproduces
identical ids; the recovered run's digest matches an uninterrupted run
over the same submissions.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time

import numpy as np

from repro.api.snapshot import GraphSnapshot
from repro.core.triples import TermDict

from .wal import DurableWAL, IngestBatch


class RecoveryError(RuntimeError):
    """The journal contradicts the checkpoint (ids fail to reproduce)."""


def _sha1(path: str) -> str:
    h = hashlib.sha1()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _dir_bytes(path: str) -> int:
    return sum(os.path.getsize(os.path.join(path, n))
               for n in os.listdir(path))


@dataclasses.dataclass
class RestoredCheckpoint:
    """One valid checkpoint, fully loaded."""

    step: int
    path: str
    applied_seq: int
    n_terms: int
    snapshot: GraphSnapshot
    drift: dict
    nbytes: int


@dataclasses.dataclass
class RecoveryReport:
    """What one :func:`recover` call did (also exported to metrics)."""

    checkpoint_step: int
    checkpoint_bytes: int
    applied_seq: int
    n_terms_checkpoint: int
    mints_replayed: int
    batches_pending: int
    batches_skipped: int       # journaled but already inside the checkpoint
    apply_runs_replayed: int
    truncated_bytes: int
    dropped_segments: int
    replay_ms: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class SnapshotCheckpointer:
    """Atomic-rename checkpoint store for ``GraphSnapshot`` + service
    state (dictionary prefix, drift counters, applied seq)."""

    def __init__(self, root: str, *, keep: int = 3) -> None:
        self.root = root
        self.keep = int(keep)
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.root):
            if n.startswith("step_") and not n.endswith(".tmp"):
                try:
                    out.append(int(n[5:13]))
                except ValueError:
                    continue
        return sorted(out)

    # -- write -------------------------------------------------------------
    def write(self, *, snapshot: GraphSnapshot, applied_seq: int,
              n_terms: int, drift: dict, fire=None) -> str:
        """Serialize one checkpoint; returns the published directory.

        ``fire`` is the fault-injection hook (site ``checkpoint.write``
        trips after staging, before the atomic publish -- a crash there
        leaves only ``.tmp`` garbage and the previous checkpoint
        intact)."""
        step = int(applied_seq) + 1
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays, meta = snapshot.to_state()
        d = snapshot.store.dict
        terms = [d.term(i) for i in range(int(n_terms))]
        raw = [t.encode("utf-8") for t in terms]
        files: dict[str, str] = {}
        with open(os.path.join(tmp, "terms.bin"), "wb") as f:
            f.write(b"".join(raw))
            f.flush()
            os.fsync(f.fileno())
        np.save(os.path.join(tmp, "terms_len.npy"),
                np.asarray([len(r) for r in raw], np.int64))
        for key, arr in arrays.items():
            np.save(os.path.join(tmp, f"{key}.npy"),
                    np.ascontiguousarray(arr))
        for name in sorted(os.listdir(tmp)):
            files[name] = _sha1(os.path.join(tmp, name))
        manifest = {"applied_seq": int(applied_seq),
                    "n_terms": int(n_terms),
                    "meta": meta, "drift": drift, "files": files,
                    "created_unix": time.time()}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if fire is not None:
            fire("checkpoint.write")
        if os.path.exists(final):          # idempotent re-checkpoint
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        for n in os.listdir(self.root):
            if n.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, n),
                              ignore_errors=True)

    # -- read --------------------------------------------------------------
    def validate(self, step: int) -> dict | None:
        """Manifest of ``step`` if the checkpoint is complete and every
        file hash matches; ``None`` for damaged/partial checkpoints."""
        path = self._step_dir(step)
        mpath = os.path.join(path, "manifest.json")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            for name, digest in manifest["files"].items():
                if _sha1(os.path.join(path, name)) != digest:
                    return None
        except (OSError, ValueError, KeyError):
            return None
        return manifest

    def latest_valid(self) -> int | None:
        for step in reversed(self.steps()):
            if self.validate(step) is not None:
                return step
        return None

    def restore(self, step: int) -> RestoredCheckpoint:
        manifest = self.validate(step)
        if manifest is None:
            raise RecoveryError(f"checkpoint step {step} is damaged")
        path = self._step_dir(step)
        lens = np.load(os.path.join(path, "terms_len.npy"))
        with open(os.path.join(path, "terms.bin"), "rb") as f:
            blob = f.read()
        offs = np.concatenate([[0], np.cumsum(lens)])
        terms = [blob[offs[i]:offs[i + 1]].decode("utf-8")
                 for i in range(len(lens))]
        dictionary = TermDict.from_terms(terms)
        arrays = {}
        for name in manifest["files"]:
            if name.endswith(".npy") and name != "terms_len.npy":
                arrays[name[:-4]] = np.load(os.path.join(path, name))
        snapshot = GraphSnapshot.from_state(dictionary, arrays,
                                            manifest["meta"])
        return RestoredCheckpoint(
            step=step, path=path,
            applied_seq=int(manifest["applied_seq"]),
            n_terms=int(manifest["n_terms"]), snapshot=snapshot,
            drift=manifest["drift"], nbytes=_dir_bytes(path))

    def restore_latest(self) -> RestoredCheckpoint | None:
        step = self.latest_valid()
        return None if step is None else self.restore(step)


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------

def wal_dir(root: str) -> str:
    return os.path.join(root, "wal")


def ckpt_dir(root: str) -> str:
    return os.path.join(root, "ckpt")


def has_state(root: str) -> bool:
    """True if ``root`` holds at least one valid checkpoint."""
    if not os.path.isdir(ckpt_dir(root)):
        return False
    return SnapshotCheckpointer(ckpt_dir(root)).latest_valid() is not None


def recover(root: str, *, wal_kwargs: dict | None = None,
            keep: int = 3, **service_kwargs):
    """Rebuild a live service from ``root`` after a crash.

    Restores the latest valid checkpoint, replays the journal (mints
    with exact-id assertions, then the pending batch suffix), re-applies
    journaled ``APPLY`` groups under the original coalescing, and
    returns the service with ``last_recovery`` set.  ``service_kwargs``
    must match the pre-crash configuration (detector, backend,
    thresholds) -- they are not persisted.
    """
    from .service import OnlineCompactionService

    t0 = time.perf_counter()
    ck = SnapshotCheckpointer(ckpt_dir(root), keep=keep)
    restored = ck.restore_latest()
    if restored is None:
        raise FileNotFoundError(f"no valid checkpoint under {root}")
    d = restored.snapshot.store.dict
    wal = DurableWAL(wal_dir(root), **(wal_kwargs or {}))
    mints_replayed = 0
    skipped = 0
    pending: list[IngestBatch] = []
    apply_runs: list[list[int]] = []
    max_seq = restored.applied_seq
    for kind, rec in wal.replay():
        if kind == "mint":
            for tid, term in rec:
                if tid < len(d):
                    if d.term(tid) != term:
                        raise RecoveryError(
                            f"mint replay diverged at id {tid}: journal "
                            f"{term!r} vs checkpoint {d.term(tid)!r}")
                    continue
                got = d.id(term)
                if got != tid:
                    raise RecoveryError(
                        f"mint replay out of order: {term!r} journaled "
                        f"as {tid}, re-minted as {got}")
                mints_replayed += 1
        elif kind == "batch":
            max_seq = max(max_seq, rec.seq)
            if rec.seq > restored.applied_seq:
                pending.append(rec)
            else:
                skipped += 1
        else:                                   # "apply"
            runs = [s for s in rec if s > restored.applied_seq]
            if runs:
                apply_runs.append(runs)
    svc = OnlineCompactionService(
        restored.snapshot, wal=wal, checkpointer=ck, **service_kwargs)
    svc.drift.load_state(restored.drift)
    svc.queue.restore(pending, next_seq=max_seq + 1)
    svc._applied_seq = restored.applied_seq
    # re-apply the suffix the pre-crash process had already committed,
    # group by group; whatever remains queued was never applied anywhere
    # and drains under normal coalescing
    runs_replayed = 0
    applied = restored.applied_seq
    for run in apply_runs:
        run = [s for s in run if s > applied]
        if not run:
            continue                # duplicate from a prior recovery
        svc.apply_exact(run)
        applied = run[-1]
        runs_replayed += 1
    report = RecoveryReport(
        checkpoint_step=restored.step,
        checkpoint_bytes=restored.nbytes,
        applied_seq=restored.applied_seq,
        n_terms_checkpoint=restored.n_terms,
        mints_replayed=mints_replayed,
        batches_pending=len(pending), batches_skipped=skipped,
        apply_runs_replayed=runs_replayed,
        truncated_bytes=wal.truncated_bytes,
        dropped_segments=wal.dropped_segments,
        replay_ms=(time.perf_counter() - t0) * 1e3)
    svc.last_recovery = report
    svc.metrics.observe("recovery.checkpoint_bytes",
                        report.checkpoint_bytes)
    svc.metrics.observe("recovery.replay_ms", report.replay_ms)
    svc.metrics.observe("recovery.batches_replayed",
                        report.batches_pending)
    svc.metrics.observe("recovery.mints_replayed", report.mints_replayed)
    return svc

"""Write-ahead ingest queue: edits are durable-in-queue until applied.

Edits enter as id-encoded batches (the service encodes terms at submit
time, so a queued batch is replayable against any snapshot sharing the
dictionary).  The head batch stays in the queue until the service has
built AND swapped the successor snapshot -- ``mark_applied`` is the
commit point -- so a crash or a failed apply between ``peek`` and the
swap never loses writes: the next ``step`` sees the same head again.
Apply order is strictly FIFO (``mark_applied`` refuses anything but the
head), which is what makes replays deterministic.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

_EMPTY3 = np.empty((0, 3), np.int32)
_EMPTY1 = np.empty((0,), np.int64)


@dataclasses.dataclass(frozen=True)
class IngestBatch:
    """One queued edit batch, id-encoded over the shared dictionary."""

    seq: int
    inserts: np.ndarray         # (n, 3) int32 triple ids
    delete_triples: np.ndarray  # (m, 3) int32 triple ids
    delete_entities: np.ndarray  # (k,) int64 entity ids

    @property
    def n_edits(self) -> int:
        return int(self.inserts.shape[0] + self.delete_triples.shape[0]
                   + self.delete_entities.shape[0])

    @property
    def empty(self) -> bool:
        return self.n_edits == 0


class IngestQueue:
    """FIFO write-ahead queue of :class:`IngestBatch` entries."""

    def __init__(self) -> None:
        self._batches: deque[IngestBatch] = deque()
        self._next_seq = 0
        self.n_applied = 0

    def append(self, inserts=None, delete_triples=None,
               delete_entities=None) -> IngestBatch:
        batch = IngestBatch(
            seq=self._next_seq,
            inserts=(np.asarray(inserts, np.int32).reshape(-1, 3)
                     if inserts is not None else _EMPTY3),
            delete_triples=(np.asarray(delete_triples,
                                       np.int32).reshape(-1, 3)
                            if delete_triples is not None else _EMPTY3),
            delete_entities=(np.asarray(delete_entities,
                                        np.int64).reshape(-1)
                             if delete_entities is not None else _EMPTY1))
        self._next_seq += 1
        self._batches.append(batch)
        return batch

    def peek(self) -> IngestBatch | None:
        """The head batch, NOT removed -- it leaves only via
        :meth:`mark_applied` after its snapshot swapped in."""
        return self._batches[0] if self._batches else None

    def peek_coalesced(self, max_batches: int | None = None
                       ) -> list[IngestBatch]:
        """Maximal coalescible head run, NOT removed.

        Adjacent insert-only batches merge into one apply; a batch
        carrying deletes may terminate the run (inside a batch inserts
        apply before deletes, so ``inserts(0..i) then deletes(i)``
        preserves the FIFO-apply semantics) but can never be followed.
        Commit the run with :meth:`mark_applied_through` -- the batches
        stay write-ahead until then, and a failed merged apply reruns
        the identical run.
        """
        run: list[IngestBatch] = []
        for b in self._batches:
            run.append(b)
            if b.delete_triples.shape[0] or b.delete_entities.shape[0]:
                break
            if max_batches is not None and len(run) >= max_batches:
                break
        return run

    def mark_applied_through(self, seqs) -> None:
        """Commit a contiguous head run, in order (each drop goes
        through :meth:`mark_applied`, so the strict-head discipline --
        and its out-of-order error -- is unchanged)."""
        for s in seqs:
            self.mark_applied(int(s))

    def mark_applied(self, seq: int) -> None:
        """Commit point: drop the head batch (and only the head)."""
        if not self._batches or self._batches[0].seq != seq:
            head = self._batches[0].seq if self._batches else None
            raise ValueError(f"mark_applied({seq}) out of order "
                             f"(head is {head})")
        self._batches.popleft()
        self.n_applied += 1

    @property
    def depth(self) -> int:
        return len(self._batches)

    def __len__(self) -> int:
        return len(self._batches)

    def __bool__(self) -> bool:
        return bool(self._batches)

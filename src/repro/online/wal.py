"""Write-ahead ingest: in-memory queue + append-only on-disk journal.

Edits enter as id-encoded batches (the service encodes terms at submit
time, so a queued batch is replayable against any snapshot sharing the
dictionary).  The head batch stays in the queue until the service has
built AND swapped the successor snapshot -- ``mark_applied`` is the
commit point -- so a crash or a failed apply between ``peek`` and the
swap never loses writes: the next ``step`` sees the same head again.
Apply order is strictly FIFO (``mark_applied`` refuses anything but the
head), which is what makes replays deterministic.

:class:`DurableWAL` extends the write-ahead discipline across process
death.  Segments (``seg_<n>.wal``) hold CRC32-framed records::

    magic  b"FSPWAL01"                                  (per segment)
    record [type u8][payload_len u32][crc32 u32][payload]

Three record types share one sequential log: ``MINT`` (dictionary-tail
term mints, in allocation order -- ids are minted at ``submit()`` and
at apply/redetect time, and recovery must replay every mint before any
batch so replayed ids match exactly), ``BATCH`` (one
:class:`IngestBatch`: seq + the three id arrays) and ``APPLY`` (the
seq group one committed step applied, so recovery re-applies the
suffix under the exact pre-crash coalescing).  Because the log is
sequential and recovery truncates at the FIRST invalid frame (torn
tail), any crash leaves a consistent prefix of the allocation order --
later fsyncs persist earlier appends for free.  Segment GC drops
segments wholly covered by a checkpoint: every batch seq applied and
every mint id below the checkpointed dictionary length.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
import time
import zlib
from collections import deque
from typing import Iterable, Iterator

import numpy as np

_EMPTY3 = np.empty((0, 3), np.int32)
_EMPTY1 = np.empty((0,), np.int64)


@dataclasses.dataclass(frozen=True)
class IngestBatch:
    """One queued edit batch, id-encoded over the shared dictionary."""

    seq: int
    inserts: np.ndarray         # (n, 3) int32 triple ids
    delete_triples: np.ndarray  # (m, 3) int32 triple ids
    delete_entities: np.ndarray  # (k,) int64 entity ids

    @property
    def n_edits(self) -> int:
        return int(self.inserts.shape[0] + self.delete_triples.shape[0]
                   + self.delete_entities.shape[0])

    @property
    def empty(self) -> bool:
        return self.n_edits == 0


class IngestQueue:
    """FIFO write-ahead queue of :class:`IngestBatch` entries."""

    def __init__(self) -> None:
        self._batches: deque[IngestBatch] = deque()
        self._next_seq = 0
        self.n_applied = 0

    def append(self, inserts=None, delete_triples=None,
               delete_entities=None) -> IngestBatch:
        batch = IngestBatch(
            seq=self._next_seq,
            inserts=(np.asarray(inserts, np.int32).reshape(-1, 3)
                     if inserts is not None else _EMPTY3),
            delete_triples=(np.asarray(delete_triples,
                                       np.int32).reshape(-1, 3)
                            if delete_triples is not None else _EMPTY3),
            delete_entities=(np.asarray(delete_entities,
                                        np.int64).reshape(-1)
                             if delete_entities is not None else _EMPTY1))
        self._next_seq += 1
        self._batches.append(batch)
        return batch

    def restore(self, batches: Iterable[IngestBatch], *,
                next_seq: int | None = None, n_applied: int = 0) -> None:
        """Reload the pending suffix after recovery.

        ``batches`` must be ascending by seq; ``next_seq`` must exceed
        every seq the journal has ever handed out (replayed OR already
        applied) so a post-recovery ``append`` never collides with a
        surviving WAL record.
        """
        if self._batches or self._next_seq:
            raise ValueError("restore() requires a fresh queue")
        last = -1
        for b in batches:
            if b.seq <= last:
                raise ValueError(f"restore out of order: {b.seq} "
                                 f"after {last}")
            last = b.seq
            self._batches.append(b)
        self._next_seq = (next_seq if next_seq is not None else last + 1)
        if self._next_seq <= last:
            raise ValueError(f"next_seq {self._next_seq} collides with "
                             f"restored seq {last}")
        self.n_applied = int(n_applied)

    def peek(self) -> IngestBatch | None:
        """The head batch, NOT removed -- it leaves only via
        :meth:`mark_applied` after its snapshot swapped in."""
        return self._batches[0] if self._batches else None

    def peek_coalesced(self, max_batches: int | None = None
                       ) -> list[IngestBatch]:
        """Maximal coalescible head run, NOT removed.

        Adjacent insert-only batches merge into one apply; a batch
        carrying deletes may terminate the run (inside a batch inserts
        apply before deletes, so ``inserts(0..i) then deletes(i)``
        preserves the FIFO-apply semantics) but can never be followed.
        Commit the run with :meth:`mark_applied_through` -- the batches
        stay write-ahead until then, and a failed merged apply reruns
        the identical run.
        """
        run: list[IngestBatch] = []
        for b in self._batches:
            run.append(b)
            if b.delete_triples.shape[0] or b.delete_entities.shape[0]:
                break
            if max_batches is not None and len(run) >= max_batches:
                break
        return run

    def mark_applied_through(self, seqs) -> None:
        """Commit a contiguous head run, in order (each drop goes
        through :meth:`mark_applied`, so the strict-head discipline --
        and its out-of-order error -- is unchanged)."""
        for s in seqs:
            self.mark_applied(int(s))

    def mark_applied(self, seq: int) -> None:
        """Commit point: drop the head batch (and only the head)."""
        if not self._batches or self._batches[0].seq != seq:
            head = self._batches[0].seq if self._batches else None
            raise ValueError(f"mark_applied({seq}) out of order "
                             f"(head is {head})")
        self._batches.popleft()
        self.n_applied += 1

    @property
    def depth(self) -> int:
        return len(self._batches)

    def __len__(self) -> int:
        return len(self._batches)

    def __bool__(self) -> bool:
        return bool(self._batches)


# -- on-disk journal ---------------------------------------------------------

WAL_MAGIC = b"FSPWAL01"
REC_MINT = 1
REC_BATCH = 2
REC_APPLY = 3
_HEADER = struct.Struct("<BII")          # type, payload_len, crc32


def _frame(rec_type: int, payload: bytes) -> bytes:
    return _HEADER.pack(rec_type, len(payload),
                        zlib.crc32(payload) & 0xFFFFFFFF) + payload


def _encode_mints(mints: list[tuple[int, str]]) -> bytes:
    parts = [struct.pack("<I", len(mints))]
    for tid, term in mints:
        raw = term.encode("utf-8")
        parts.append(struct.pack("<II", int(tid), len(raw)))
        parts.append(raw)
    return b"".join(parts)


def _decode_mints(payload: bytes) -> list[tuple[int, str]]:
    (n,) = struct.unpack_from("<I", payload, 0)
    pos, out = 4, []
    for _ in range(n):
        tid, ln = struct.unpack_from("<II", payload, pos)
        pos += 8
        out.append((tid, payload[pos:pos + ln].decode("utf-8")))
        pos += ln
    if pos != len(payload):
        raise ValueError("mint payload length mismatch")
    return out


def _encode_apply(seqs: list[int]) -> bytes:
    return struct.pack("<I", len(seqs)) \
        + struct.pack(f"<{len(seqs)}q", *[int(s) for s in seqs])


def _decode_apply(payload: bytes) -> list[int]:
    (n,) = struct.unpack_from("<I", payload, 0)
    if len(payload) != 4 + 8 * n:
        raise ValueError("apply payload length mismatch")
    return list(struct.unpack_from(f"<{n}q", payload, 4))


def _encode_batch(batch: IngestBatch) -> bytes:
    ins = np.ascontiguousarray(batch.inserts, np.int32)
    delt = np.ascontiguousarray(batch.delete_triples, np.int32)
    dele = np.ascontiguousarray(batch.delete_entities, np.int64)
    return (struct.pack("<qIII", int(batch.seq), ins.shape[0],
                        delt.shape[0], dele.shape[0])
            + ins.tobytes() + delt.tobytes() + dele.tobytes())


def _decode_batch(payload: bytes) -> IngestBatch:
    seq, n_ins, n_delt, n_dele = struct.unpack_from("<qIII", payload, 0)
    pos = 20
    expect = pos + n_ins * 12 + n_delt * 12 + n_dele * 8
    if expect != len(payload):
        raise ValueError("batch payload length mismatch")
    ins = np.frombuffer(payload, np.int32, n_ins * 3, pos).reshape(-1, 3)
    pos += n_ins * 12
    delt = np.frombuffer(payload, np.int32, n_delt * 3, pos).reshape(-1, 3)
    pos += n_delt * 12
    dele = np.frombuffer(payload, np.int64, n_dele, pos)
    return IngestBatch(seq=int(seq), inserts=ins, delete_triples=delt,
                       delete_entities=dele)


@dataclasses.dataclass
class _SegmentStats:
    """Per-segment GC bookkeeping (maintained on scan AND append)."""

    max_seq: int = -1
    max_mint_id: int = -1

    def note(self, rec_type: int, payload: bytes) -> None:
        if rec_type == REC_BATCH:
            (seq,) = struct.unpack_from("<q", payload, 0)
            self.max_seq = max(self.max_seq, int(seq))
        elif rec_type == REC_APPLY:
            seqs = _decode_apply(payload)
            if seqs:
                self.max_seq = max(self.max_seq, max(seqs))
        else:
            for tid, _ in _decode_mints(payload):
                self.max_mint_id = max(self.max_mint_id, int(tid))


def _scan_segment(path: str) -> tuple[int, int, _SegmentStats]:
    """Validate one segment; return (valid_end, file_size, stats).

    ``valid_end`` is the byte offset of the longest valid record
    prefix; anything past it is a torn tail (or corruption) to be
    truncated.  A bad magic invalidates the whole file
    (``valid_end == 0``).
    """
    with open(path, "rb") as f:
        data = f.read()
    stats = _SegmentStats()
    if not data.startswith(WAL_MAGIC):
        return 0, len(data), stats
    pos = len(WAL_MAGIC)
    while pos < len(data):
        if pos + _HEADER.size > len(data):
            break
        rec_type, ln, crc = _HEADER.unpack_from(data, pos)
        end = pos + _HEADER.size + ln
        if rec_type not in (REC_MINT, REC_BATCH, REC_APPLY) \
                or end > len(data):
            break
        payload = data[pos + _HEADER.size:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        try:
            stats.note(rec_type, payload)
        except Exception:
            break               # framed fine but payload malformed
        pos = end
    return pos, len(data), stats


class DurableWAL:
    """Append-only segmented journal for mints and ingest batches.

    Opening the journal validates every segment in order and truncates
    at the first invalid frame -- the recovered log is always the
    longest valid prefix of what was written (``truncated_bytes`` /
    ``dropped_segments`` report what was cut).  ``fsync_policy``:

    * ``"every_batch"`` -- fsync after each :meth:`append_batch` (mint
      records ride the next batch's fsync; the log is sequential, so a
      later fsync persists every earlier append);
    * ``"interval"`` -- flush always, fsync at most once per
      ``fsync_interval_s``.

    The appender is single-threaded (the service's writer loop) but
    :meth:`gc` may run from the checkpoint writer thread, hence the
    lock around segment bookkeeping.
    """

    def __init__(self, root: str, *, fsync_policy: str = "every_batch",
                 fsync_interval_s: float = 1.0,
                 segment_max_bytes: int = 4 << 20,
                 clock=time.monotonic) -> None:
        if fsync_policy not in ("every_batch", "interval"):
            raise ValueError(f"unknown fsync policy {fsync_policy!r}")
        self.root = root
        self.fsync_policy = fsync_policy
        self.fsync_interval_s = float(fsync_interval_s)
        self.segment_max_bytes = int(segment_max_bytes)
        self._clock = clock
        self._last_sync = clock()
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)
        self.truncated_bytes = 0
        self.dropped_segments = 0
        self._segments: list[str] = []           # full paths, in order
        self._stats: dict[str, _SegmentStats] = {}
        self._open_scan()
        self._fh = open(self._segments[-1], "ab")
        if self._fh.tell() == 0:
            self._fh.write(WAL_MAGIC)
            self._fh.flush()

    # -- open / scan -------------------------------------------------------
    def _seg_path(self, n: int) -> str:
        return os.path.join(self.root, f"seg_{n:08d}.wal")

    def _open_scan(self) -> None:
        names = sorted(n for n in os.listdir(self.root)
                       if n.startswith("seg_") and n.endswith(".wal"))
        paths = [os.path.join(self.root, n) for n in names]
        for i, path in enumerate(paths):
            valid_end, size, stats = _scan_segment(path)
            self._segments.append(path)
            self._stats[path] = stats
            if valid_end < size:
                self.truncated_bytes += size - valid_end
                with open(path, "r+b") as f:
                    f.truncate(valid_end)
                # everything after the corruption was written later:
                # keeping it would break the prefix property
                for later in paths[i + 1:]:
                    with open(later, "rb") as f:
                        self.truncated_bytes += len(f.read())
                    os.remove(later)
                    self.dropped_segments += 1
                break
        if not self._segments:
            self._segments.append(self._seg_path(0))
            self._stats[self._segments[0]] = _SegmentStats()

    # -- append ------------------------------------------------------------
    def _maybe_rotate(self) -> None:
        if self._fh.tell() < self.segment_max_bytes:
            return
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._fh.close()
        last = os.path.basename(self._segments[-1])
        n = int(last[4:12]) + 1
        with self._lock:
            path = self._seg_path(n)
            self._segments.append(path)
            self._stats[path] = _SegmentStats()
        self._fh = open(path, "ab")
        self._fh.write(WAL_MAGIC)

    def _append(self, rec_type: int, payload: bytes) -> None:
        self._maybe_rotate()
        self._fh.write(_frame(rec_type, payload))
        with self._lock:
            self._stats[self._segments[-1]].note(rec_type, payload)

    def append_mints(self, mints: list[tuple[int, str]]) -> None:
        """Journal dictionary-tail mints, in allocation order.  Must be
        called BEFORE the batch (or checkpoint) that references the
        ids -- recovery replays the log sequentially."""
        if not mints:
            return
        self._append(REC_MINT, _encode_mints(mints))
        self._fh.flush()

    def append_batch(self, batch: IngestBatch) -> None:
        self._append(REC_BATCH, _encode_batch(batch))
        self._fh.flush()
        if self.fsync_policy == "every_batch":
            os.fsync(self._fh.fileno())
            self._last_sync = self._clock()
        elif self._clock() - self._last_sync >= self.fsync_interval_s:
            os.fsync(self._fh.fileno())
            self._last_sync = self._clock()

    def append_applied(self, seqs: list[int]) -> None:
        """Journal one committed apply run (the coalesced seq group).
        Recovery re-applies logged groups EXACTLY as the pre-crash
        process grouped them -- coalescing changes drift accounting and
        with it re-detection decisions and mint order, so replaying a
        suffix under a different grouping would diverge from the
        uninterrupted run's id assignment."""
        self._append(REC_APPLY, _encode_apply(list(seqs)))
        self._fh.flush()

    def sync(self) -> None:
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._last_sync = self._clock()

    # -- replay ------------------------------------------------------------
    def replay(self) -> Iterator[tuple[str, object]]:
        """Yield ``("mint", [(id, term), ...])`` and ``("batch",
        IngestBatch)`` in write order.  Only call on a freshly opened
        journal (open-time scan already truncated any torn tail)."""
        self._fh.flush()
        with self._lock:
            segments = list(self._segments)
        for path in segments:
            with open(path, "rb") as f:
                data = f.read()
            pos = len(WAL_MAGIC)
            while pos + _HEADER.size <= len(data):
                rec_type, ln, _ = _HEADER.unpack_from(data, pos)
                payload = data[pos + _HEADER.size:pos + _HEADER.size + ln]
                pos += _HEADER.size + ln
                if rec_type == REC_MINT:
                    yield "mint", _decode_mints(payload)
                elif rec_type == REC_APPLY:
                    yield "apply", _decode_apply(payload)
                else:
                    yield "batch", _decode_batch(payload)

    # -- GC ----------------------------------------------------------------
    def gc(self, applied_seq: int, n_terms: int) -> int:
        """Drop segments wholly covered by a checkpoint at
        ``applied_seq`` / ``n_terms`` dictionary entries.  The active
        segment always survives.  Returns segments removed."""
        removed = 0
        with self._lock:
            keep = []
            for path in self._segments[:-1]:
                st = self._stats[path]
                if st.max_seq <= applied_seq and st.max_mint_id < n_terms:
                    os.remove(path)
                    del self._stats[path]
                    removed += 1
                else:
                    keep.append(path)
            self._segments = keep + [self._segments[-1]]
        return removed

    # -- misc --------------------------------------------------------------
    @property
    def n_segments(self) -> int:
        with self._lock:
            return len(self._segments)

    def nbytes(self) -> int:
        self._fh.flush()
        with self._lock:
            return sum(os.path.getsize(p) for p in self._segments
                       if os.path.exists(p))

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()

"""Per-class support-drift tracking: WHICH classes deserve re-detection.

Def. 4.8 makes payoff a live quantity.  Two decay modes matter online:

* **raw residue growth** -- inserts land entities whose object tuples
  do not match any existing molecule (they stay raw, or mint fresh
  low-support surrogates).  The class's raw-typed population grows past
  the compacted baseline, which is exactly the signal that a *new*
  frequent star pattern may have emerged (or the old SP stopped being
  the best one).
* **support drift from deletes** -- membership exits, payoff-sweep
  decompactions and invalidated molecules shrink AMI/AM and push
  molecules toward the Fig. 7 overhead regime.

Both are tracked *incrementally*: update deltas (``UpdateReport.
per_class``) and delete deltas (``DeleteStats.per_class``) accumulate
into per-class counters, and the raw residue is one cached index probe
per touched class (``entities_of_class`` minus the class's molecule
count -- absorbed entities carry no direct ``type`` edge, so the
difference IS the raw population).  ``dirty_classes`` never scans
triples and never touches untouched classes; the re-detection loop it
feeds re-evaluates ONLY what it returns.
"""
from __future__ import annotations

from repro.core.fgraph import DeleteStats, FactorizedGraph


def raw_residue(fg: FactorizedGraph, class_id: int) -> int:
    """Raw-typed entity count of a class in G': entities still carrying
    a direct ``type`` edge (surrogates included; members excluded --
    their type edge moved to the molecule), minus the molecule count."""
    cid = int(class_id)
    n = int(fg.store.entities_of_class(cid).shape[0])
    t = fg.tables.get(cid)
    return n - (t.n_molecules if t is not None else 0)


class DriftTracker:
    """Accumulates per-class drift and decides the dirty set.

    A class is *dirty* when, since its last (re-)detection:

    * its raw residue grew by >= ``raw_residue_threshold`` entities, or
    * its accumulated support-drift count (membership exits +
      decompacted entities + removed molecules + online-minted
      surrogates, which start life at the sub-payoff end) reached
      ``support_drift_threshold``.

    ``prime`` captures baselines from a fresh snapshot;
    ``note_redetected`` re-baselines exactly the classes a redetect pass
    considered, so drift in other classes keeps accumulating.

    A class whose re-detection keeps landing on a hill-climb-rejected
    plan (the realized-edges guard in ``CompactionPlanner.redetect``)
    **backs off exponentially**: each rejection doubles its effective
    thresholds (capped at ``2**max_backoff``), so the service stops
    paying a full sweep every pass for a class whose drift pattern keeps
    proposing the same regressive re-plan.  An accepted re-detection
    resets the backoff to zero.
    """

    def __init__(self, *, raw_residue_threshold: int = 8,
                 support_drift_threshold: int = 4,
                 max_backoff: int = 6) -> None:
        self.raw_residue_threshold = int(raw_residue_threshold)
        self.support_drift_threshold = int(support_drift_threshold)
        self.max_backoff = int(max_backoff)
        self._baseline: dict[int, int] = {}      # cid -> residue at detect
        self._support_drift: dict[int, int] = {}  # cid -> accumulated decay
        self._touched: set[int] = set()           # cids edited since prime
        self._backoff: dict[int, int] = {}        # cid -> rejection count

    # -- lifecycle ---------------------------------------------------------
    def prime(self, fg: FactorizedGraph) -> None:
        """Baseline every class of a freshly detected snapshot."""
        self._baseline = {int(c): raw_residue(fg, int(c))
                          for c in fg.store.classes().tolist()}
        self._support_drift = {}
        self._touched = set()

    def note_redetected(self, fg: FactorizedGraph, class_ids,
                        rejected: bool = False) -> None:
        """Re-baseline the classes a redetect pass just considered.

        ``rejected=True`` marks a pass the realized-edges hill-climb
        guard refused: the classes' backoff levels increment (their
        effective thresholds double, up to ``2**max_backoff``), so a
        class that keeps proposing a regressive re-plan must accumulate
        exponentially more drift before being re-evaluated.  An accepted
        pass resets the backoff."""
        for c in class_ids:
            cid = int(c)
            self._baseline[cid] = raw_residue(fg, cid)
            self._support_drift.pop(cid, None)
            self._touched.discard(cid)
            if rejected:
                self._backoff[cid] = min(self._backoff.get(cid, 0) + 1,
                                         self.max_backoff)
            else:
                self._backoff.pop(cid, None)

    def state_dict(self) -> dict:
        """JSON-serializable counter state (for checkpoints).  Residue
        baselines are part of the re-detection decision, so recovery
        must restore them exactly or the replayed drift decisions -- and
        with them the recovered digest -- could diverge."""
        return {
            "baseline": {str(k): v for k, v in self._baseline.items()},
            "support_drift": {str(k): v
                              for k, v in self._support_drift.items()},
            "touched": sorted(self._touched),
            "backoff": {str(k): v for k, v in self._backoff.items()},
        }

    def load_state(self, state: dict) -> None:
        self._baseline = {int(k): int(v)
                          for k, v in state["baseline"].items()}
        self._support_drift = {int(k): int(v)
                               for k, v in state["support_drift"].items()}
        self._touched = {int(c) for c in state["touched"]}
        self._backoff = {int(k): int(v)
                         for k, v in state["backoff"].items()}

    # -- incremental feeds -------------------------------------------------
    def observe_update(self, report) -> None:
        """Fold one ``UpdateReport`` in: touched classes join the watch
        set; online-minted surrogates count toward support drift (they
        start at the sub-payoff end until later batches reuse them)."""
        for cid in report.touched_classes:
            self._touched.add(int(cid))
        for cid, d in report.per_class.items():
            self._touched.add(int(cid))
            n = int(d.get("new_surrogates", 0))
            if n:
                self._support_drift[int(cid)] = \
                    self._support_drift.get(int(cid), 0) + n

    def observe_delete(self, stats: DeleteStats) -> None:
        """Fold one ``DeleteStats`` in: exits, decompactions and removed
        molecules all witness support decay of their class."""
        for cid, d in stats.per_class.items():
            n = int(d.get("exits", 0)) + int(d.get("decompacted", 0)) \
                + int(d.get("molecules_removed", 0))
            if n:
                cid = int(cid)
                self._touched.add(cid)
                self._support_drift[cid] = \
                    self._support_drift.get(cid, 0) + n

    # -- the decision ------------------------------------------------------
    def support_drift(self, class_id: int) -> int:
        return self._support_drift.get(int(class_id), 0)

    def residue_growth(self, fg: FactorizedGraph, class_id: int) -> int:
        cid = int(class_id)
        return raw_residue(fg, cid) - self._baseline.get(cid, 0)

    def backoff(self, class_id: int) -> int:
        """Consecutive rejected re-detections of a class (capped)."""
        return self._backoff.get(int(class_id), 0)

    def dirty_classes(self, fg: FactorizedGraph) -> list[int]:
        """Classes whose accumulated drift crossed a threshold -- the
        ONLY classes the re-detection loop will re-evaluate.  Probes
        touched classes exclusively (cached index lookups), so the check
        itself is proportional to the edited set, not the graph.
        Per-class thresholds scale by ``2**backoff``: repeatedly
        rejected classes need exponentially more drift to go dirty."""
        dirty = []
        for cid in sorted(self._touched):
            scale = 1 << self.backoff(cid)
            if self.support_drift(cid) \
                    >= self.support_drift_threshold * scale \
                    or self.residue_growth(fg, cid) \
                    >= self.raw_residue_threshold * scale:
                dirty.append(cid)
        return dirty

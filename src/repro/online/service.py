"""The online compaction service: single writer, snapshot-swap commits.

Loop shape (one :meth:`OnlineCompactionService.step`):

    head = queue.peek()                      # write-ahead: stays queued
    snapshot' = planner.apply_update/delete  # build successor, no mutation
    self._snapshot = snapshot'               # THE swap: one atomic store
    queue.mark_applied(head.seq)             # commit point
    dirty = drift.dirty_classes(...)         # incremental counters
    planner.redetect(snapshot', dirty)       # ONLY drifted classes
                                             # (fault.retry-wrapped)

The swap is a single Python attribute assignment, so readers that
grabbed ``service.snapshot`` before it keep a fully consistent
(immutable) world view -- queries never block on recompaction and never
see torn state.  The write-ahead ordering (apply -> swap -> mark)
means a failure anywhere leaves the head batch queued and the old
snapshot live: nothing is lost, the step just reruns.

**Durability** (optional): give the service a
:class:`~repro.online.wal.DurableWAL` and a
:class:`~repro.online.recovery.SnapshotCheckpointer` (or open it via
:meth:`OnlineCompactionService.durable`) and the write-ahead discipline
extends across process death: every dictionary-tail mint and every
batch is journaled at ``submit`` time, every committed apply run is
journaled with its coalescing, and every ``checkpoint_every`` applied
batches the full snapshot state checkpoints on a background thread
(atomic rename; the journal GCs segments a checkpoint covers).
``repro.online.recovery.recover`` rebuilds the exact pre-crash state.
Named fault-injection sites (``dist.fault.SITES``) are threaded
through the loop so a seeded :class:`~repro.dist.fault.FaultPlan` can
crash any point of the lifecycle deterministically.

Re-detection is the expensive part, so it is wrapped in
``dist.fault.retry`` (decorrelated jitter + a ``retry_deadline_s``
budget so a slow pass cannot block the writer unboundedly) with a
``dist.fault.Monitor`` heartbeat: retries land in the
``fault.retries`` channel, dead heartbeats in ``fault.dead_workers``,
and if every attempt fails the dirty classes simply STAY dirty
(counters intact) while ingest continues -- availability over
freshness.

Every step feeds the accumulator metrics channels (``queue.depth``,
``ingest.batch_ms``, ``ingest.unknown_deletes``, ``redetect.ms``,
``redetect.dirty_classes``, ``swap.count``, ``checkpoint.bytes``,
``savings.<class>``, ...): per-batch last value plus running
summaries, exported by :meth:`metrics_summary` and
``launch/serve.py --online``.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

import numpy as np

from repro.api.snapshot import (CompactionPlanner, DeleteReport,
                                GraphSnapshot, RedetectReport, UpdateReport)
from repro.core.fgraph import FactorizedGraph
from repro.core.triples import TripleStore
from repro.dist import fault

from .drift import DriftTracker
from .metrics import MetricsHub
from .wal import DurableWAL, IngestBatch, IngestQueue


@dataclasses.dataclass
class BatchReport:
    """Everything one ``step`` did: the applied batch, the swap(s), and
    any re-detection it triggered."""

    seq: int
    epoch_before: int
    epoch_after: int
    latency_ms: float
    update: UpdateReport | None = None
    delete: DeleteReport | None = None
    redetect: RedetectReport | None = None


class OnlineCompactionService:
    """Write-ahead ingest + drift-tracked re-detection over snapshots.

    ``source`` may be a plain :class:`TripleStore` (compacted once at
    construction), an existing :class:`GraphSnapshot`, or a bare
    :class:`FactorizedGraph` (wrapped at epoch 0).  All writes go
    through :meth:`submit` (term- or id-level) and apply in FIFO order
    via :meth:`step` / :meth:`drain`; the service is single-writer but
    any number of readers may hold :attr:`snapshot` concurrently.
    """

    def __init__(self, source, *,
                 detector: str = "gfsp", backend: str = "host",
                 planner: CompactionPlanner | None = None,
                 min_predicted_savings: int = 1,
                 drift: DriftTracker | None = None,
                 raw_residue_threshold: int = 8,
                 support_drift_threshold: int = 4,
                 max_backoff: int = 6,
                 metrics: MetricsHub | None = None,
                 monitor: fault.Monitor | None = None,
                 redetect_deadline_s: float = 30.0,
                 retry_attempts: int = 3, retry_base_s: float = 0.01,
                 retry_deadline_s: float | None = 60.0,
                 retry_sleep=None,
                 auto_redetect: bool = True,
                 recompress_threshold: int | None = None,
                 coalesce: bool = True,
                 max_coalesce: int | None = None,
                 wal: DurableWAL | None = None,
                 checkpointer=None,
                 checkpoint_every: int = 8,
                 checkpoint_async: bool = True,
                 fault_plan: fault.FaultPlan | None = None) -> None:
        self.planner = planner or CompactionPlanner(
            detector, backend,
            min_predicted_savings=min_predicted_savings)
        if isinstance(source, GraphSnapshot):
            snap = source
        elif isinstance(source, FactorizedGraph):
            snap = GraphSnapshot(fgraph=source, epoch=0)
        elif isinstance(source, TripleStore):
            snap, _ = self.planner.run(source)
        else:
            raise TypeError(f"cannot serve from {type(source).__name__}")
        self._snapshot = snap
        self.queue = IngestQueue()
        self.drift = drift or DriftTracker(
            raw_residue_threshold=raw_residue_threshold,
            support_drift_threshold=support_drift_threshold,
            max_backoff=max_backoff)
        self.drift.prime(snap.fgraph)
        self.metrics = metrics or MetricsHub()
        # pre-register the soak's gate channels so a clean run exports
        # them with count 0 instead of omitting them
        for ch in ("fault.retries", "fault.dead_workers",
                   "ingest.unknown_deletes", "ingest.recompressions"):
            self.metrics.channel(ch)
        self.monitor = monitor or fault.Monitor(
            deadline_s=redetect_deadline_s,
            on_dead=lambda w: self.metrics.observe(
                "fault.dead_workers", 1),
            on_straggler=lambda w: self.metrics.observe(
                "redetect.stragglers", 1))
        self.retry_attempts = int(retry_attempts)
        self.retry_base_s = float(retry_base_s)
        self.retry_deadline_s = retry_deadline_s
        self._retry_sleep = retry_sleep if retry_sleep is not None \
            else time.sleep
        self._retry_rng = random.Random(0)
        self.auto_redetect = bool(auto_redetect)
        # background recompression of the mutable tail (ROADMAP 3'):
        # mutation migrates a compressed-tier store to the plain tier
        # (apply_update decodes once instead of repacking per batch);
        # once ``recompress_threshold`` ingested rows have accumulated
        # on the plain form, the step re-packs it off the hot path
        self.recompress_threshold = (None if recompress_threshold is None
                                     else int(recompress_threshold))
        self._plain_tail = 0
        self.coalesce = bool(coalesce)
        self.max_coalesce = max_coalesce
        self.swap_count = 0
        self._swap_lock = threading.Lock()
        self._redetect_step = 0
        # -- durability ----------------------------------------------------
        self.wal = wal
        self.checkpointer = checkpointer
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_async = bool(checkpoint_async)
        self.fault_plan = fault_plan
        self.last_recovery = None
        self._applied_seq = -1
        self._since_checkpoint = 0
        self._ckpt_thread: threading.Thread | None = None
        self._ckpt_error: BaseException | None = None
        # every dict id below this is journaled (or checkpoint-covered);
        # construction mints (initial compaction) are covered by the
        # initial checkpoint ``durable()`` writes, never by the WAL
        self._minted_upto = len(snap.store.dict) if wal is not None else 0

    @classmethod
    def durable(cls, root: str, source=None, *, wal_kwargs=None,
                keep: int = 3, **kwargs) -> "OnlineCompactionService":
        """Open-or-recover a durable service rooted at ``root``.

        With a valid checkpoint under ``root`` this is
        :func:`repro.online.recovery.recover` (``source`` is ignored;
        ``kwargs`` must match the pre-crash configuration).  Otherwise
        ``source`` seeds a fresh service whose initial compacted state
        is checkpointed immediately -- the armed ``fault_plan`` (if
        any) only goes live after that, so chaos targets the ingest
        lifecycle, not construction.
        """
        from .recovery import (SnapshotCheckpointer, ckpt_dir, has_state,
                               recover, wal_dir)
        if has_state(root):
            return recover(root, wal_kwargs=wal_kwargs, keep=keep,
                           **kwargs)
        if source is None:
            raise FileNotFoundError(
                f"no durable state under {root} and no source given")
        plan = kwargs.pop("fault_plan", None)
        svc = cls(source,
                  wal=DurableWAL(wal_dir(root), **(wal_kwargs or {})),
                  checkpointer=SnapshotCheckpointer(ckpt_dir(root),
                                                    keep=keep),
                  **kwargs)
        svc.checkpoint(wait=True)
        svc.fault_plan = plan
        return svc

    # -- read side ---------------------------------------------------------
    @property
    def snapshot(self) -> GraphSnapshot:
        """The live snapshot.  Reading this is the entire consistency
        protocol: one atomic attribute load of an immutable object."""
        return self._snapshot

    @property
    def fgraph(self) -> FactorizedGraph:
        return self._snapshot.fgraph

    @property
    def applied_seq(self) -> int:
        """Highest committed batch seq (-1 before the first apply)."""
        return self._applied_seq

    def metrics_summary(self) -> dict[str, dict]:
        return self.metrics.summary()

    # -- durability plumbing -----------------------------------------------
    def _fire(self, site: str) -> None:
        if self.fault_plan is not None:
            self.fault_plan.fire(site)

    def _log_mints(self) -> None:
        """Journal every dictionary id minted since the last call, in
        allocation order (submit-time term mints AND apply/redetect-time
        surrogate mints share the one append-only id space)."""
        if self.wal is None:
            return
        d = self._snapshot.store.dict
        n = len(d)
        if n > self._minted_upto:
            self.wal.append_mints(
                [(i, d.term(i)) for i in range(self._minted_upto, n)])
            self._minted_upto = n

    def checkpoint(self, *, wait: bool = False) -> None:
        """Checkpoint the current state (snapshot + dictionary prefix +
        drift counters + applied seq).  Serialization runs on a
        background thread unless ``checkpoint_async=False`` -- every
        array it touches is immutable, so the writer loop keeps going.
        A damaged in-flight write surfaces on the next call (or
        :meth:`close`); the previous checkpoint on disk stays valid."""
        if self.checkpointer is None:
            raise RuntimeError("service has no checkpointer")
        self._join_checkpoint()
        self._log_mints()
        if self.wal is not None:
            self.wal.sync()
        snap = self._snapshot
        args = (snap, self._applied_seq, len(snap.store.dict),
                self.drift.state_dict())
        self._since_checkpoint = 0
        if self.checkpoint_async:
            self._ckpt_thread = threading.Thread(
                target=self._write_checkpoint, args=(*args, False),
                daemon=True)
            self._ckpt_thread.start()
            if wait:
                self._join_checkpoint()
        else:
            self._write_checkpoint(*args, True)

    def _write_checkpoint(self, snap, applied_seq, n_terms, drift_state,
                          reraise) -> None:
        try:
            path = self.checkpointer.write(
                snapshot=snap, applied_seq=applied_seq, n_terms=n_terms,
                drift=drift_state, fire=self._fire)
            from .recovery import _dir_bytes
            self.metrics.observe("checkpoint.bytes", _dir_bytes(path))
            self.metrics.observe("checkpoint.count", 1)
            if self.wal is not None:
                removed = self.wal.gc(applied_seq, n_terms)
                if removed:
                    self.metrics.observe("wal.segments_gcd", removed)
        except BaseException as e:
            self.metrics.observe("checkpoint.failures", 1)
            if reraise:
                raise
            self._ckpt_error = e

    def _join_checkpoint(self) -> None:
        t = self._ckpt_thread
        if t is not None:
            t.join()
            self._ckpt_thread = None
        err, self._ckpt_error = self._ckpt_error, None
        if err is not None:
            raise err

    def close(self) -> None:
        """Flush the journal and wait for any in-flight checkpoint."""
        self._join_checkpoint()
        if self.wal is not None:
            self.wal.close()

    # -- write side --------------------------------------------------------
    def submit(self, inserts=None, delete_triples=None,
               delete_entities=None) -> IngestBatch:
        """Enqueue one edit batch (write-ahead; applied by :meth:`step`).

        Term-level input is id-encoded HERE against the shared
        dictionary: insert terms mint ids (append-only, so encoding
        ahead of apply is safe), delete terms use ``lookup`` -- a term
        the graph has never seen cannot name an existing triple, so
        unknown deletes drop out as no-ops, counted in the
        ``ingest.unknown_deletes`` channel (a growing count means the
        caller's view of the dictionary has skewed).  With a WAL
        attached, the minted tail and the batch are journaled before
        ``submit`` returns; a crash at the ``wal.append`` site means
        the batch was never accepted (the caller re-submits).
        """
        self._fire("wal.append")
        d = self._snapshot.store.dict
        unknown = 0
        term_level_delete = False
        if inserts is not None and not isinstance(inserts, np.ndarray):
            trips = list(inserts)
            inserts = (d.ids([t for spo in trips for t in spo])
                       .reshape(-1, 3) if trips else None)
        if delete_triples is not None and \
                not isinstance(delete_triples, np.ndarray):
            term_level_delete = True
            rows = []
            for s, p, o in delete_triples:
                ids3 = (d.lookup(s), d.lookup(p), d.lookup(o))
                if None not in ids3:
                    rows.append(ids3)
                else:
                    unknown += 1
            delete_triples = np.asarray(rows, np.int32).reshape(-1, 3) \
                if rows else None
        if delete_entities is not None and \
                not isinstance(delete_entities, np.ndarray):
            term_level_delete = True
            ids = [d.lookup(e) for e in delete_entities]
            unknown += sum(1 for i in ids if i is None)
            ids = [i for i in ids if i is not None]
            delete_entities = np.asarray(ids, np.int64) if ids else None
        if term_level_delete:
            self.metrics.observe("ingest.unknown_deletes", unknown)
        batch = self.queue.append(inserts=inserts,
                                  delete_triples=delete_triples,
                                  delete_entities=delete_entities)
        if self.wal is not None:
            self._log_mints()
            self.wal.append_batch(batch)
        self.metrics.observe("queue.depth", self.queue.depth)
        return batch

    def _swap(self, snap: GraphSnapshot) -> None:
        self._snapshot = snap          # the atomic commit
        with self._swap_lock:
            self.swap_count += 1
        self.metrics.observe("swap.count", self.swap_count)

    def step(self) -> BatchReport | None:
        """Apply the head batch -- or, with coalescing on, the maximal
        head run of insert-only batches (plus at most one terminating
        delete-carrying batch) merged into ONE apply: build the
        successor snapshot, swap, commit the run, then re-detect
        drifted classes."""
        if self.coalesce:
            batches = self.queue.peek_coalesced(self.max_coalesce)
        else:
            head = self.queue.peek()
            batches = [head] if head is not None else []
        if not batches:
            return None
        return self._apply_run(batches)

    def apply_exact(self, seqs) -> BatchReport:
        """Apply EXACTLY the head run ``seqs`` as one merged step.

        The recovery path re-applying a journaled ``APPLY`` group: the
        grouping must match the pre-crash coalescing or drift
        accounting (and with it re-detection and mint order) would
        diverge from the uninterrupted run."""
        want = [int(s) for s in seqs]
        head = list(self.queue.peek_coalesced(len(want)))
        got = [b.seq for b in head[:len(want)]]
        if got != want:
            raise ValueError(f"apply_exact({want}) does not match the "
                             f"queue head run {got}")
        return self._apply_run(head[:len(want)])

    def _apply_run(self, batches: list[IngestBatch]) -> BatchReport:
        t0 = time.perf_counter()
        snap = self._snapshot
        epoch_before = snap.epoch
        # Merge the run: inserts concatenate in FIFO order; only the
        # LAST batch of a coalesced run may carry deletes (peek_coalesced
        # guarantees it), and within a batch inserts apply before
        # deletes, so one insert-then-delete apply is order-preserving.
        last = batches[-1]
        inserts = (batches[0].inserts if len(batches) == 1
                   else np.concatenate([b.inserts for b in batches]))
        self._fire("apply")
        upd = dele = None
        if inserts.shape[0]:
            snap, upd = self.planner.apply_update(snap, inserts)
        if last.delete_triples.shape[0] or last.delete_entities.shape[0]:
            snap, dele = self.planner.apply_delete(
                snap,
                triples=(last.delete_triples
                         if last.delete_triples.shape[0] else None),
                entities=(last.delete_entities
                          if last.delete_entities.shape[0] else None))
        self._log_mints()                  # surrogate mints, pre-swap
        self._fire("pre_swap")
        if snap is not self._snapshot:
            self._swap(snap)
        self._fire("post_swap")
        if self.wal is not None:
            self.wal.append_applied([b.seq for b in batches])
        # commit point: swap landed; drop the whole run in order
        self.queue.mark_applied_through([b.seq for b in batches])
        self._applied_seq = last.seq
        self.metrics.observe("ingest.coalesced_batches", len(batches))
        if upd is not None:
            self.drift.observe_update(upd)
        if dele is not None:
            self.drift.observe_delete(dele.stats)
        latency = (time.perf_counter() - t0) * 1e3
        self.metrics.observe("ingest.batch_ms", latency)
        self.metrics.observe("queue.depth", self.queue.depth)
        red = None
        if self.auto_redetect:
            dirty = self.drift.dirty_classes(self._snapshot.fgraph)
            if dirty:
                red = self.redetect(dirty)
        self._plain_tail += int(inserts.shape[0])
        self._maybe_recompress()
        # checkpoint LAST: a checkpoint between commit and this step's
        # re-detection would restore to a state whose redetect never
        # re-runs (the batch is already inside the checkpoint), silently
        # diverging from the uninterrupted run's mint order
        if self.checkpointer is not None:
            self._since_checkpoint += len(batches)
            if self._since_checkpoint >= self.checkpoint_every:
                self.checkpoint()
        return BatchReport(seq=last.seq, epoch_before=epoch_before,
                           epoch_after=self._snapshot.epoch,
                           latency_ms=latency, update=upd, delete=dele,
                           redetect=red)

    def _maybe_recompress(self) -> None:
        """Re-pack the plain mutable tail once it outgrows the
        threshold: build the compressed store off the hot path (the
        writer is between batches; readers keep the old snapshot) and
        swap it under the unchanged molecule tables.  ``compact_dict=
        False`` is mandatory -- the WAL journals dictionary mints by id,
        so the shared dict *object* must survive the repack."""
        if self.recompress_threshold is None \
                or self._plain_tail < self.recompress_threshold:
            return
        snap = self._snapshot
        store = snap.fgraph.store
        if getattr(store, "is_compressed", False):
            self._plain_tail = 0
            return
        t0 = time.perf_counter()
        packed = store.compressed(compact_dict=False)
        self._swap(snap.next(snap.fgraph.with_store(packed)))
        self._plain_tail = 0
        self.metrics.observe("ingest.recompressions", 1)
        self.metrics.observe("ingest.recompress_ms",
                             (time.perf_counter() - t0) * 1e3)

    def drain(self, max_batches: int | None = None) -> list[BatchReport]:
        """Apply queued batches FIFO until empty (or ``max_batches``)."""
        out: list[BatchReport] = []
        while self.queue and (max_batches is None
                              or len(out) < max_batches):
            rep = self.step()
            if rep is None:     # pragma: no cover - queue raced empty
                break
            out.append(rep)
        return out

    # -- re-detection ------------------------------------------------------
    def redetect(self, class_ids) -> RedetectReport | None:
        """Re-detect ONLY ``class_ids``, retried on failure.

        The pass runs against the current snapshot under
        ``dist.fault.retry`` (decorrelated jitter, overall
        ``retry_deadline_s`` budget) with Monitor heartbeats; on success
        the successor swaps in and the drift baselines reset.  If every
        attempt fails the old snapshot stays live, the ingest queue is
        untouched, and the classes remain dirty -- the next batch will
        trigger another try.
        """
        cids = [int(c) for c in class_ids]
        if not cids:
            return None

        def attempt():
            self._fire("redetect")
            self._redetect_step += 1
            self.monitor.record("redetect", self._redetect_step)
            out = self.planner.redetect(self._snapshot, cids)
            self.monitor.record("redetect", self._redetect_step)
            self.monitor.check()
            return out

        try:
            snap, report = fault.retry(
                attempt, attempts=self.retry_attempts,
                base_s=self.retry_base_s, sleep=self._retry_sleep,
                deadline_s=self.retry_deadline_s, rng=self._retry_rng,
                on_retry=lambda a, d, e: self.metrics.observe(
                    "fault.retries", 1))()
        except fault.InjectedFault:
            raise               # injection models process death
        except Exception:
            # exhausted: stay on the old snapshot, keep the drift
            # counters -- re-detection is an optimization, never a
            # correctness requirement
            self.metrics.observe("redetect.failures", 1)
            return None
        if snap is not self._snapshot:     # rejected passes don't swap
            self._log_mints()
            self._swap(snap)
        # re-baseline either way: the decision was made against this
        # state; drift re-accumulates before the classes go dirty again
        # -- but a rejected pass also bumps the classes' backoff, so
        # repeat offenders need exponentially more drift to re-trigger
        self.drift.note_redetected(snap.fgraph, report.considered,
                                   rejected=report.rejected)
        self.metrics.observe("redetect.ms", report.exec_time_ms)
        self.metrics.observe("redetect.dirty_classes", len(cids))
        self.metrics.observe("redetect.descents", report.descents)
        term = snap.store.dict.term
        for cid, saving in report.per_class_savings.items():
            self.metrics.observe(f"savings.{term(cid)}", saving)
        return report

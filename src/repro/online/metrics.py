"""Accumulator-channel metrics: per-batch value + running summary.

One :class:`Channel` per measured quantity (queue depth, batch latency,
recompaction latency, per-class savings, swap count, ...): ``observe``
records the latest value and folds it into the running count / total /
min / max, so a dashboard (or the bench snapshot) can read both "what
happened this batch" and "how has it gone overall" off the same surface
without the service keeping history lists.  The hub is just a name ->
channel map with auto-vivification; channels are cheap enough that
callers never pre-register.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Channel:
    """One metric stream: last observed value plus running aggregates."""

    name: str
    last: float = 0.0
    count: int = 0
    total: float = 0.0
    min: float = 0.0
    max: float = 0.0

    def observe(self, value: float) -> None:
        v = float(value)
        self.last = v
        if self.count == 0:
            self.min = self.max = v
        else:
            self.min = min(self.min, v)
            self.max = max(self.max, v)
        self.count += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {"last": self.last, "count": self.count,
                "total": self.total, "min": self.min, "max": self.max,
                "mean": self.mean}


class MetricsHub:
    """Name -> :class:`Channel` map with observe-creates semantics."""

    def __init__(self) -> None:
        self.channels: dict[str, Channel] = {}

    def channel(self, name: str) -> Channel:
        ch = self.channels.get(name)
        if ch is None:
            ch = self.channels[name] = Channel(name=name)
        return ch

    def observe(self, name: str, value: float) -> Channel:
        ch = self.channel(name)
        ch.observe(value)
        return ch

    def summary(self) -> dict[str, dict]:
        return {name: ch.summary()
                for name, ch in sorted(self.channels.items())}

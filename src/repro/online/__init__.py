"""Online compaction: the long-running service over snapshot swaps.

The paper's factorization is a one-shot batch transform, but Def. 4.8
makes compaction payoff a *live* quantity: inserts and deletes drift
molecule support, and the compact form decays unless frequent star
patterns are re-detected as the graph changes.  This package keeps a
:class:`~repro.api.snapshot.GraphSnapshot` continuously compacted:

* :mod:`~repro.online.wal` -- a write-ahead ingest queue batching triple
  inserts / deletes; a batch stays queued until its successor snapshot
  has swapped in, so a failed apply never loses writes;
* :mod:`~repro.online.drift` -- per-class support-drift tracking (raw-
  residue growth and sub-payoff counters maintained incrementally from
  ``UpdateReport`` / ``DeleteStats`` deltas), deciding WHICH classes are
  worth re-detecting;
* :mod:`~repro.online.metrics` -- accumulator channels (per-batch value
  + running summary) for queue depth, batch/recompaction latency,
  per-class savings, swap count;
* :mod:`~repro.online.service` -- the single-writer loop tying them
  together: drain a batch, swap the successor snapshot atomically,
  re-detect ONLY the drifted classes through the candidate-batched
  sweep engine (wrapped in ``dist.fault`` retry so a failed or
  straggling re-detection never loses the queue).

Readers (``repro.serving.GraphQueryService``) hold the service's
snapshot handle and never block on any of this.
"""
from .drift import DriftTracker  # noqa: F401
from .metrics import Channel, MetricsHub  # noqa: F401
from .recovery import (RecoveryError, RecoveryReport,  # noqa: F401
                       SnapshotCheckpointer, recover)
from .service import BatchReport, OnlineCompactionService  # noqa: F401
from .wal import DurableWAL, IngestBatch, IngestQueue  # noqa: F401

"""Least-squares calibration of the BGP planner's cost constants.

The planner's three cost formulas (:func:`repro.query.bgp.planner.
plan_star`) are linear in six per-operation constants -- per molecule
row, per residual entity, per emitted row, per scanned triple, per
off-SP pair, per mixed-slot molecule row.  That linearity makes the
constants fittable: run workloads under pinned strategies, record the
feature totals the formulas would charge alongside the observed warm
wall time, and solve the (regularized, non-negative) least-squares
system

    observed_ms  ~=  features @ constants.

``benchmarks.run bgp_matrix`` does exactly this over the BENCH grid's
sensor shape and reports the fitted model next to the committed
defaults; the defaults in :class:`~repro.query.bgp.planner.CostModel`
are a normalized fit (``c_mol == 1``) from that harness.

The fit is intentionally crude -- ordinary ridge solve with negative
coefficients clipped to a floor -- because the planner only consumes
the *ordering* the constants induce, not their absolute scale.
"""
from __future__ import annotations

import time
from collections.abc import Sequence

import numpy as np

from repro.core.fgraph import FactorizedGraph

from .algebra import BGPQuery
from .exec import deferral_eligible
from .planner import CostModel, _star_estimates, plan_bgp

#: per-star evaluation modes a feature vector can describe
MODES = ("deferred", "factorized", "raw")


def star_features(fg: FactorizedGraph, query: BGPQuery, si: int,
                  mode: str, cache: dict | None = None,
                  mixed_partners: int = 0) -> np.ndarray:
    """The 6-vector ``f`` with ``predicted cost = model.as_array() @ f``
    for evaluating star ``si`` under ``mode`` -- the same quantities
    :func:`plan_star` charges, exposed so a fit can replay them."""
    star = query.stars[si]
    filters = [f for f in query.filters if f.var in star.variables]
    est = _star_estimates(fg, star, filters, cache)
    f = np.zeros(len(CostModel.FEATURES))
    if mode == "deferred":
        f[0] = est["ami"]
        f[1] = est["raw_pop"]
        f[2] = est["mol_rows"]
        f[5] = mixed_partners * est["mol_rows"]
    elif mode == "factorized":
        f[0] = est["ami"]
        f[1] = est["raw_pop"]
        f[2] = est["est_rows"]
        f[4] = est["off_sp_pairs"]
    elif mode == "raw":
        f[2] = est["est_rows"]
        f[3] = (est["n_sem"] + est["scan"]
                + sum(fg.store.index.pred_count(p)
                      for p, _ in star.var_arms))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return f


def query_features(fg: FactorizedGraph, query: BGPQuery, strategy: str,
                   cache: dict | None = None) -> np.ndarray:
    """Feature total for a whole query under a pinned ``strategy`` --
    per star, the mode that strategy would actually execute (pinned
    ``"factorized"`` still defers when sound, mirroring the engine).
    Deferred stars sharing a variable with a non-deferred partner get
    their mixed-partner count, so the ``mix`` column is identified by
    exactly the queries that pay the granularity crossing."""
    if strategy == "raw":
        modes = ["raw"] * len(query.stars)
    else:
        modes = []
        for star in query.stars:
            filters = [f for f in query.filters
                       if f.var in star.variables]
            modes.append("deferred"
                         if deferral_eligible(fg, star, filters,
                                              cache=cache)
                         else "factorized")
    var_sets = [set(s.variables) for s in query.stars]
    total = np.zeros(len(CostModel.FEATURES))
    for si in range(len(query.stars)):
        mixed = 0
        if modes[si] == "deferred":
            mixed = sum(1 for j in range(len(query.stars))
                        if j != si and modes[j] != "deferred"
                        and var_sets[si] & var_sets[j])
        total += star_features(fg, query, si, modes[si], cache,
                               mixed_partners=mixed)
    return total


def collect_samples(engine, workloads: dict[str, Sequence[BGPQuery]],
                    strategies: Sequence[str] = ("raw", "factorized"),
                    ) -> list[tuple[np.ndarray, float]]:
    """(feature total, observed warm ms) per (workload x pinned
    strategy) cell.  Pinned strategies only: the sample must pair a
    KNOWN evaluation mode with its latency, and ``"auto"`` would fold
    the very model being fitted into the data."""
    fg = engine.fgraph
    cache: dict = {}
    samples: list[tuple[np.ndarray, float]] = []
    for queries in workloads.values():
        for strategy in strategies:
            feats = sum((query_features(fg, q, strategy, cache)
                         for q in queries),
                        np.zeros(len(CostModel.FEATURES)))
            for q in queries:                       # warm the caches
                engine.query_bgp(q, strategy=strategy, backend="host")
            t0 = time.perf_counter()
            for q in queries:
                engine.query_bgp(q, strategy=strategy, backend="host")
            samples.append((feats, (time.perf_counter() - t0) * 1e3))
    return samples


def fit_cost_model(samples: Sequence[tuple[np.ndarray, float]],
                   prior: CostModel | None = None, l2: float = 0.5,
                   floor: float = 0.05, normalize: bool = True
                   ) -> CostModel:
    """Prior-centered ridge least squares over ``samples``.

    The observed latencies identify the constants only up to what the
    workload mix exercises -- a feature column no sampled query pays
    for (or pays for only collinearly with another) would otherwise
    collapse to an arbitrary value and wreck planning everywhere else.
    So the solve is regularized toward ``prior`` (default: the current
    :class:`CostModel` defaults), after rescaling the prior to the
    sample's millisecond units by a 1-d projection.  ``l2`` trades
    data against prior in the max-normalized feature space;
    non-positive coefficients are clipped to ``floor`` x the largest
    (a cost cannot be a credit -- genuinely small positive constants
    pass through untouched); the result is scaled so ``c_mol == 1``
    when ``normalize`` -- the planner compares costs, only ratios
    matter.
    """
    prior = prior if prior is not None else CostModel()
    A = np.stack([f for f, _ in samples])
    y = np.array([ms for _, ms in samples])
    scale = A.max(axis=0)
    scale[scale == 0] = 1.0
    An = A / scale
    # project the abstract-unit prior onto millisecond units
    c0 = prior.as_array()
    pred0 = A @ c0
    alpha = float(pred0 @ y) / (float(pred0 @ pred0) or 1.0)
    b0 = alpha * c0 * scale
    k = An.shape[1]
    b, *_ = np.linalg.lstsq(An.T @ An + l2 * np.eye(k),
                            An.T @ y + l2 * b0, rcond=None)
    c = b / scale
    c = np.where(c > 0, c, floor * np.abs(c).max())
    if normalize and c[0] > 0:
        c = c / c[0]
    return CostModel.from_array(c)


def calibration_report(engine, workloads: dict[str, Sequence[BGPQuery]],
                       ) -> dict:
    """Collect, fit, and summarize -- the dict lands in the BENCH
    snapshot next to the bgp matrix so drift in the fitted constants
    is visible across commits."""
    samples = collect_samples(engine, workloads)
    fitted = fit_cost_model(samples)
    pred = np.stack([f for f, _ in samples]) @ fitted.as_array()
    obs = np.array([ms for _, ms in samples])
    denom = float(np.abs(obs).sum()) or 1.0
    return {
        "n_samples": len(samples),
        "fitted": {k: round(float(v), 4)
                   for k, v in zip(CostModel.FEATURES,
                                   fitted.as_array())},
        "committed": {k: round(float(v), 4)
                      for k, v in zip(CostModel.FEATURES,
                                      CostModel().as_array())},
        # scale-free fit quality: predicted cost is in abstract units,
        # so compare after matching total mass
        "rel_l1_error": round(float(
            np.abs(pred * (denom / (np.abs(pred).sum() or 1.0))
                   - obs).sum() / denom), 4),
    }

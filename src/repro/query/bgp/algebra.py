"""BGP algebra: multi-star basic graph patterns with FILTER constraints.

A *basic graph pattern* here is a conjunction of star patterns linked by
shared variables -- the query mixes the k2-triples / Compressed Vertical
Partitioning papers evaluate (PAPERS.md), and the shape the paper's
compaction makes cheap:

    ?o  type Observation . ?o procedure ?s . ?o samplingTime t7 .
    ?s  type Sensor      . ?s model m3 .
    FILTER(?v < val/9)

Variables are strings starting with ``"?"``; everything else in an arm
is a dictionary id (the serving layer translates terms).  A
:class:`Filter` compares a variable's *dictionary id* against a constant
with one of ``== != < <= > >=`` -- range semantics are id-order
semantics, which the synthetic generators make meaningful by minting
ordered value terms (``val/0 < val/1 < ...`` by insertion).  Every
evaluation strategy applies the same comparison, so parity between
strategies never depends on the dictionary order being "semantic".

:class:`BGPBindings` is the answer relation: one named column per query
variable, set semantics (``canonical()`` sorts-and-dedups, so digests
are strategy-order-independent -- same contract as ``star.Bindings``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_OPS = ("==", "!=", "<", "<=", ">", ">=")


def is_var(term) -> bool:
    return isinstance(term, str) and term.startswith("?")


@dataclasses.dataclass(frozen=True)
class StarPattern:
    """One star of a BGP: a subject *variable* plus arms whose objects
    are either ground ids or variables (+ an optional class)."""

    subject: str
    arms: tuple[tuple[int, int | str], ...]
    class_id: int | None = None

    def __post_init__(self):
        if not is_var(self.subject):
            raise ValueError(f"star subject must be a ?var, got "
                             f"{self.subject!r}")
        norm = []
        for p, o in self.arms:
            if is_var(o):
                norm.append((int(p), str(o)))
            else:
                norm.append((int(p), int(o)))
        object.__setattr__(self, "arms", tuple(norm))

    @property
    def ground_arms(self) -> list[tuple[int, int]]:
        return [(p, o) for p, o in self.arms if not is_var(o)]

    @property
    def var_arms(self) -> list[tuple[int, str]]:
        return [(p, o) for p, o in self.arms if is_var(o)]

    @property
    def variables(self) -> tuple[str, ...]:
        """Variables in first-occurrence order, subject first."""
        out = [self.subject]
        for _, o in self.arms:
            if is_var(o) and o not in out:
                out.append(o)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class Filter:
    """``FILTER(?v op value)`` over dictionary ids."""

    var: str
    op: str
    value: int

    def __post_init__(self):
        if not is_var(self.var):
            raise ValueError(f"filter target must be a ?var, got "
                             f"{self.var!r}")
        if self.op not in _OPS:
            raise ValueError(f"unknown filter op {self.op!r} "
                             f"(one of {_OPS})")
        object.__setattr__(self, "value", int(self.value))

    def apply(self, col: np.ndarray) -> np.ndarray:
        """Vectorized boolean mask of the constraint over an id column --
        the same comparison whether ``col`` holds one object per *entity*
        (raw / expanded evaluation) or one object per *molecule* (the
        pushed-down form: one comparison answers every member)."""
        v = self.value
        if self.op == "==":
            return col == v
        if self.op == "!=":
            return col != v
        if self.op == "<":
            return col < v
        if self.op == "<=":
            return col <= v
        if self.op == ">":
            return col > v
        return col >= v


@dataclasses.dataclass(frozen=True)
class BGPQuery:
    """A conjunction of star patterns plus filters."""

    stars: tuple[StarPattern, ...]
    filters: tuple[Filter, ...] = ()

    def __post_init__(self):
        if not self.stars:
            raise ValueError("BGP needs at least one star")
        bound = set()
        for s in self.stars:
            bound.update(s.variables)
        for f in self.filters:
            if f.var not in bound:
                raise ValueError(f"filter on unbound variable {f.var!r}")

    @property
    def variables(self) -> tuple[str, ...]:
        """All query variables, first-occurrence order across stars --
        the canonical output column order every strategy projects to."""
        out: list[str] = []
        for s in self.stars:
            for v in s.variables:
                if v not in out:
                    out.append(v)
        return tuple(out)

    def filters_on(self, var: str) -> list[Filter]:
        return [f for f in self.filters if f.var == var]


@dataclasses.dataclass
class BGPBindings:
    """Answer relation: one named column per query variable."""

    columns: tuple[str, ...]
    rows: np.ndarray                 # (R, C) int64

    def __post_init__(self):
        self.columns = tuple(self.columns)
        self.rows = np.asarray(self.rows, np.int64).reshape(
            -1, len(self.columns))

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])

    def column(self, var: str) -> np.ndarray:
        return self.rows[:, self.columns.index(var)]

    def canonical(self) -> np.ndarray:
        """Sorted-unique rows under the fixed column order -- set
        semantics, strategy-order-independent (digest input)."""
        if self.rows.shape[0] == 0:
            return self.rows
        return np.unique(self.rows, axis=0)

    def same_as(self, other: "BGPBindings") -> bool:
        if self.columns != other.columns:
            return False
        a, b = self.canonical(), other.canonical()
        return a.shape == b.shape and bool((a == b).all())
